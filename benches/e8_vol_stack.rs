//! E8 — Figure 2: the cost of VOL plugin indirection.
//!
//! Microbenchmarks the access-library operation path for the native
//! backend vs the forwarding plugin (client-side decompose + scatter/
//! gather + server-local plugin), per §4.1's observation that "this model
//! introduces an extra forwarding plugin which also introduces additional
//! overhead" — quantifying the per-op price and where parallelism buys it
//! back. Reports wall time (real code path) and simulated time (testbed).
//!
//! Run: `cargo bench --bench e8_vol_stack`

use skyhook_map::config::ClusterConfig;
use skyhook_map::dataset::{Dataspace, Hyperslab};
use skyhook_map::simnet::CostParams;
use skyhook_map::store::Cluster;
use skyhook_map::util::bench::{black_box, report, Bench};
use skyhook_map::util::rng::Xoshiro256;
use skyhook_map::vol::{vol_registry, ForwardingBackend, NativeBackend, VolFile};

fn native_file() -> VolFile {
    VolFile::open(Box::new(NativeBackend::new(CostParams::paper_testbed())))
}

fn fwd_file(osds: usize) -> VolFile {
    let cluster = Cluster::new(
        &ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        },
        vol_registry(),
    );
    VolFile::open(Box::new(ForwardingBackend::new(cluster)))
}

fn main() {
    let space = Dataspace::new(&[512, 512]).unwrap();
    let chunk = [128u64, 128];
    let data: Vec<f32> = {
        let mut rng = Xoshiro256::new(3);
        (0..space.numel()).map(|_| rng.f32()).collect()
    };

    let b = Bench::new().warmup(1).samples(8);

    // Whole-dataset write+read, wall clock.
    let mut results = Vec::new();
    results.push(b.run_bytes("native write 1MiB", 1 << 20, || {
        let mut f = native_file();
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        black_box(());
    }));
    for osds in [1usize, 4] {
        results.push(b.run_bytes(
            &format!("forwarding write 1MiB ({osds} OSDs)"),
            1 << 20,
            || {
                let mut f = fwd_file(osds);
                f.create_dataset("d", &space, &chunk).unwrap();
                f.write_all("d", &data).unwrap();
                black_box(());
            },
        ));
    }
    report("E8a: dataset create+write, wall clock", &results);

    // Small-op latency: read a 4x4 hyperslab 200 times.
    let mut results = Vec::new();
    {
        let mut f = native_file();
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        let slab = Hyperslab::new(&[100, 100], &[4, 4]).unwrap();
        results.push(b.run_items("native 4x4 reads", 200, || {
            for _ in 0..200 {
                black_box(f.read("d", &slab).unwrap());
            }
        }));
    }
    {
        let mut f = fwd_file(4);
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        let slab = Hyperslab::new(&[100, 100], &[4, 4]).unwrap();
        results.push(b.run_items("forwarding 4x4 reads (pushdown)", 200, || {
            for _ in 0..200 {
                black_box(f.read("d", &slab).unwrap());
            }
        }));
    }
    report("E8b: small hyperslab read latency, wall clock", &results);

    // Simulated per-op overhead on the calibrated testbed.
    let mut f_native = native_file();
    f_native.create_dataset("d", &space, &chunk).unwrap();
    f_native.write_all("d", &data).unwrap();
    let mut f_fwd = fwd_file(4);
    f_fwd.create_dataset("d", &space, &chunk).unwrap();
    f_fwd.write_all("d", &data).unwrap();
    let slab = Hyperslab::new(&[10, 10], &[8, 8]).unwrap();
    let t0 = f_native.now();
    for _ in 0..100 {
        f_native.read("d", &slab).unwrap();
    }
    let native_sim = (f_native.now() - t0) / 100.0;
    let t0 = f_fwd.now();
    for _ in 0..100 {
        f_fwd.read("d", &slab).unwrap();
    }
    let fwd_sim = (f_fwd.now() - t0) / 100.0;
    println!(
        "\nE8c: simulated per-op read latency: native {:.1}µs vs forwarding {:.1}µs \
         ({:.1}x — the network hop + plugin cost, repaid by scale-out in E1/E6)",
        native_sim * 1e6,
        fwd_sim * 1e6,
        fwd_sim / native_sim
    );

    println!("\ne8_vol_stack OK");
}
