//! E8 — Figure 2: the cost of VOL plugin indirection.
//!
//! Microbenchmarks the access-library operation path for the native
//! backend vs the forwarding plugin (client-side decompose + scatter/
//! gather + server-local plugin), per §4.1's observation that "this model
//! introduces an extra forwarding plugin which also introduces additional
//! overhead" — quantifying the per-op price and where parallelism buys it
//! back. Reports wall time (real code path) and simulated time (testbed).
//!
//! Run: `cargo bench --bench e8_vol_stack`

use skyhook_map::config::ClusterConfig;
use skyhook_map::dataset::{Dataspace, Hyperslab};
use skyhook_map::simnet::CostParams;
use skyhook_map::skyhook::{CmpOp, Predicate};
use skyhook_map::store::Cluster;
use skyhook_map::util::bench::{black_box, report, Bench};
use skyhook_map::util::rng::Xoshiro256;
use skyhook_map::vol::{
    vol_registry, ForwardingBackend, NativeBackend, VolBackend, VolFile, VolPolicy,
};
use std::sync::Arc;

fn native_file() -> VolFile {
    VolFile::open(Box::new(NativeBackend::new(CostParams::paper_testbed())))
}

fn fwd_file(osds: usize) -> VolFile {
    let cluster = Cluster::new(
        &ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        },
        vol_registry(),
    );
    VolFile::open(Box::new(ForwardingBackend::new(cluster)))
}

fn main() {
    let space = Dataspace::new(&[512, 512]).unwrap();
    let chunk = [128u64, 128];
    let data: Vec<f32> = {
        let mut rng = Xoshiro256::new(3);
        (0..space.numel()).map(|_| rng.f32()).collect()
    };

    let b = Bench::new().warmup(1).samples(8);

    // Whole-dataset write+read, wall clock.
    let mut results = Vec::new();
    results.push(b.run_bytes("native write 1MiB", 1 << 20, || {
        let mut f = native_file();
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        black_box(());
    }));
    for osds in [1usize, 4] {
        results.push(b.run_bytes(
            &format!("forwarding write 1MiB ({osds} OSDs)"),
            1 << 20,
            || {
                let mut f = fwd_file(osds);
                f.create_dataset("d", &space, &chunk).unwrap();
                f.write_all("d", &data).unwrap();
                black_box(());
            },
        ));
    }
    report("E8a: dataset create+write, wall clock", &results);

    // Small-op latency: read a 4x4 hyperslab 200 times.
    let mut results = Vec::new();
    {
        let mut f = native_file();
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        let slab = Hyperslab::new(&[100, 100], &[4, 4]).unwrap();
        results.push(b.run_items("native 4x4 reads", 200, || {
            for _ in 0..200 {
                black_box(f.read("d", &slab).unwrap());
            }
        }));
    }
    {
        let mut f = fwd_file(4);
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write_all("d", &data).unwrap();
        let slab = Hyperslab::new(&[100, 100], &[4, 4]).unwrap();
        results.push(b.run_items("forwarding 4x4 reads (pushdown)", 200, || {
            for _ in 0..200 {
                black_box(f.read("d", &slab).unwrap());
            }
        }));
    }
    report("E8b: small hyperslab read latency, wall clock", &results);

    // Simulated per-op overhead on the calibrated testbed.
    let mut f_native = native_file();
    f_native.create_dataset("d", &space, &chunk).unwrap();
    f_native.write_all("d", &data).unwrap();
    let mut f_fwd = fwd_file(4);
    f_fwd.create_dataset("d", &space, &chunk).unwrap();
    f_fwd.write_all("d", &data).unwrap();
    let slab = Hyperslab::new(&[10, 10], &[8, 8]).unwrap();
    let t0 = f_native.now();
    for _ in 0..100 {
        f_native.read("d", &slab).unwrap();
    }
    let native_sim = (f_native.now() - t0) / 100.0;
    let t0 = f_fwd.now();
    for _ in 0..100 {
        f_fwd.read("d", &slab).unwrap();
    }
    let fwd_sim = (f_fwd.now() - t0) / 100.0;
    println!(
        "\nE8c: simulated per-op read latency: native {:.1}µs vs forwarding {:.1}µs \
         ({:.1}x — the network hop + plugin cost, repaid by scale-out in E1/E6)",
        native_sim * 1e6,
        fwd_sim * 1e6,
        fwd_sim / native_sim
    );

    // E8d: plan-compiled filtered reads (zone-map pruning + cost-based
    // offload) vs the static pre-planner rule. Two identical clusters so
    // the A/B timelines don't queue behind each other. Left half of the
    // dataset holds values in [0,1), one hot chunk holds [10,11); the
    // predicate `v >= 10` makes every cold chunk provably dead, so the
    // planner fetches exactly the hot chunk while the static rule
    // fetches every existing one.
    let mut rng = Xoshiro256::new(11);
    let cold: Vec<f32> = (0..512 * 256).map(|_| rng.f32()).collect();
    let hot: Vec<f32> = (0..128 * 128).map(|_| 10.0 + rng.f32()).collect();
    let seeded = |cold: &[f32], hot: &[f32]| {
        let c = Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            vol_registry(),
        );
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        f.create_dataset("d", &space, &chunk).unwrap();
        f.write("d", &Hyperslab::new(&[0, 0], &[512, 256]).unwrap(), cold)
            .unwrap();
        f.write("d", &Hyperslab::new(&[0, 256], &[128, 128]).unwrap(), hot)
            .unwrap();
        c
    };
    let whole = Hyperslab::whole(&space);
    let pred = Predicate::cmp("v", CmpOp::Ge, 10.0);

    let mut planned = ForwardingBackend::new(seeded(&cold, &hot));
    let tp = planned.read_slab_where(0.0, "d", &whole, &pred).unwrap();
    let mut baseline =
        ForwardingBackend::new(seeded(&cold, &hot)).with_policy(VolPolicy::Static);
    let tb = baseline.read_slab_where(0.0, "d", &whole, &pred).unwrap();

    assert_eq!(tp.value.len(), tb.value.len());
    for (a, b) in tp.value.iter().zip(&tb.value) {
        assert_eq!(a.to_bits(), b.to_bits(), "planned vs static diverged");
    }
    let (ps, bs) = (planned.stats(), baseline.stats());
    assert!(
        ps.chunks_fetched < bs.chunks_fetched,
        "planner must fetch strictly fewer chunks: {} vs {}",
        ps.chunks_fetched,
        bs.chunks_fetched
    );
    assert_eq!(ps.chunks_fetched, 1, "only the hot chunk survives pruning");
    assert!(
        tp.finish < tb.finish,
        "planner must be strictly faster: {:.6}s vs {:.6}s",
        tp.finish,
        tb.finish
    );
    println!(
        "\nE8d: filtered whole-dataset read, planned vs static (sim): \
         chunks fetched {} vs {} (pruned {}, {} KiB skipped), \
         simulated {:.1}µs vs {:.1}µs ({:.1}x)",
        ps.chunks_fetched,
        bs.chunks_fetched,
        ps.chunks_pruned,
        ps.bytes_skipped / 1024,
        tp.finish * 1e6,
        tb.finish * 1e6,
        tb.finish / tp.finish
    );
    // Machine-readable snapshot line for scripts/bench.sh (BENCH_vol.json).
    println!(
        "E8D_JSON {{\"planned_chunks\": {}, \"static_chunks\": {}, \
         \"chunks_pruned\": {}, \"bytes_skipped\": {}, \
         \"planned_sim_s\": {:.9}, \"static_sim_s\": {:.9}}}",
        ps.chunks_fetched, bs.chunks_fetched, ps.chunks_pruned, ps.bytes_skipped, tp.finish, tb.finish
    );

    println!("\ne8_vol_stack OK");
}
