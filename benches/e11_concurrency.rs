//! E11 — the concurrent serving layer under load.
//!
//! Four parts:
//!   (a) concurrency sweep 1 → 1024 client threads through the router:
//!       wall-clock tail latency (p50/p99), throughput, shed count.
//!   (b) admission under a deliberately tiny gate: every request sheds
//!       with the typed `Overloaded` error while the pool is drained,
//!       and all credits are back once the burst ends.
//!   (c) the saturation-boundary flip, asserted hard: the same query
//!       that the planner pushes down on an idle cluster flips to
//!       client-side execution when ~1k tracked in-flight queries pile
//!       onto the OSDs (plan-time `queue_depth` inflates
//!       `osd_saturation`), and flips back when the load drains.
//!   (d) shared-scan batching: a barrier-started burst of identical
//!       client-side queries serves most fetches from the single-flight
//!       scan cache (`router.shared_scan_hits` > 0).
//!
//! Run: `cargo bench --bench e11_concurrency`

use skyhook_map::config::Config;
use skyhook_map::coordinator::{QueryGateConfig, Request, Response, Router};
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::stats::percentile;
use skyhook_map::Error;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;
use std::time::Instant;

fn reference_query(dataset: &str) -> Query {
    Query::scan(dataset)
        .filter(Predicate::cmp("val", CmpOp::Gt, 40.0))
        .aggregate(AggFunc::Mean, "val")
}

/// Build a stack and seed one dataset.
fn stack(osds: usize, rows: usize, target: u64, dataset: &str) -> Stack {
    let cfg = Config::from_text(&format!(
        "[cluster]\nosds = {osds}\nreplicas = 1\n[driver]\nworkers = 4\n"
    ))
    .unwrap();
    let s = Stack::build(&cfg).unwrap();
    s.driver
        .write_table(
            dataset,
            &gen::sensor_table(rows, 11),
            Layout::Col,
            &PartitionSpec::with_target(target),
            None,
        )
        .unwrap();
    s
}

/// (a) Sweep client-thread counts through a router sized to admit 1k.
fn sweep() {
    let s = stack(8, 100_000, 64 * 1024, "sweep");
    let router = Router::with_gates(
        Arc::clone(&s.driver),
        8,
        QueryGateConfig {
            global_credits: 1024,
            tenant_credits: 1024,
            admit_timeout: Duration::from_secs(2),
        },
    );
    let mut rows = Vec::new();
    for threads in [1usize, 8, 64, 256, 1024] {
        let total = threads.max(128);
        let per = total / threads;
        let lat = Mutex::new(Vec::with_capacity(total));
        let shed = AtomicUsize::new(0);
        let barrier = Barrier::new(threads);
        let t0 = Instant::now();
        std::thread::scope(|sc| {
            for t in 0..threads {
                let (router, lat, shed, barrier) = (&router, &lat, &shed, &barrier);
                sc.spawn(move || {
                    barrier.wait();
                    for _ in 0..per {
                        let q0 = Instant::now();
                        match router.handle(Request::Query {
                            query: reference_query("sweep"),
                            force_mode: None,
                            tenant: Some(format!("t{}", t % 8)),
                        }) {
                            Ok(Response::Query(_)) => {
                                lat.lock().unwrap().push(q0.elapsed().as_secs_f64());
                            }
                            Ok(_) => unreachable!(),
                            Err(Error::Overloaded(_)) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("serving error: {e}"),
                        }
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let mut l = lat.into_inner().unwrap();
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let done = l.len();
        assert_eq!(
            done + shed.load(Ordering::Relaxed),
            per * threads,
            "every request must complete or shed -- none may hang"
        );
        rows.push(vec![
            threads.to_string(),
            done.to_string(),
            shed.load(Ordering::Relaxed).to_string(),
            format!("{:.2}", percentile(&l, 0.50) * 1e3),
            format!("{:.2}", percentile(&l, 0.99) * 1e3),
            format!("{:.0}", done as f64 / wall),
        ]);
    }
    assert_eq!(
        router.query_credits_available(),
        1024,
        "all query credits restored after the sweep"
    );
    table(
        "E11a: concurrency sweep (planner-chosen mode, 8 OSDs)",
        &["threads", "done", "shed", "p50 ms", "p99 ms", "req/s"],
        &rows,
    );
}

/// (b) Tiny gate: drained pool sheds every request, typed; then heals.
fn admission() {
    let s = stack(4, 20_000, 64 * 1024, "gate");
    let router = Router::with_gates(
        Arc::clone(&s.driver),
        4,
        QueryGateConfig {
            global_credits: 8,
            tenant_credits: 8,
            admit_timeout: Duration::from_millis(1),
        },
    );
    // Drain the whole global pool, then throw a 64-thread burst at it:
    // all 64 must shed with the typed error within the bounded wait.
    let holds: Vec<_> = (0..8).map(|_| router.query_gate().admit(None).unwrap()).collect();
    let rejected = AtomicUsize::new(0);
    let barrier = Barrier::new(64);
    std::thread::scope(|sc| {
        for _ in 0..64 {
            let (router, rejected, barrier) = (&router, &rejected, &barrier);
            sc.spawn(move || {
                barrier.wait();
                match router.handle(Request::Query {
                    query: reference_query("gate"),
                    force_mode: None,
                    tenant: None,
                }) {
                    Err(Error::Overloaded(msg)) => {
                        assert!(msg.contains("pool"), "error names the pool: {msg}");
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                    other => panic!(
                        "expected Overloaded while the pool is drained, got {:?}",
                        other.as_ref().map(|_| "Ok").map_err(|e| e.to_string())
                    ),
                }
            });
        }
    });
    assert_eq!(rejected.load(Ordering::Relaxed), 64);
    drop(holds);
    assert_eq!(router.query_credits_available(), 8, "credits restored");
    // Healed: the same request is admitted and runs.
    let r = router
        .handle(Request::Query {
            query: reference_query("gate"),
            force_mode: None,
            tenant: Some("t0".into()),
        })
        .unwrap();
    let Response::Query(_) = r else { panic!() };
    println!(
        "\nE11b: drained gate shed 64/64 with typed Overloaded, \
         credits restored to 8/8, post-drain query admitted"
    );
}

/// (c) The hard assert: live contention flips the offload boundary.
fn boundary_flip() {
    // Few, large objects: at idle the selective aggregate is a clear
    // pushdown win (move ~bytes_result instead of ~512 KiB/object).
    let s = stack(4, 200_000, 512 * 1024, "flip");
    let q = reference_query("flip");

    let idle = s.driver.execute(&q, None).unwrap().stats;
    assert!(
        idle.objects_pushdown > idle.objects_client,
        "idle cluster must favor pushdown: {}p/{}c",
        idle.objects_pushdown,
        idle.objects_client
    );

    // Pile ~1k tracked in-flight queries onto the OSDs. The next plan
    // snapshots mean_inflight into CostParams::queue_depth, inflating
    // osd_saturation -- server CPU is now contended, shipping wins.
    let objects = s.cluster.list_objects();
    let mut load = Vec::with_capacity(1024);
    for i in 0..1024 {
        load.push(s.cluster.track_inflight(&objects[i % objects.len()]));
    }
    assert!(s.cluster.mean_inflight() >= 128.0);
    let busy = s.driver.execute(&q, None).unwrap().stats;
    assert!(
        busy.objects_client > busy.objects_pushdown,
        "saturated cluster must flip client-ward: {}p/{}c",
        busy.objects_pushdown,
        busy.objects_client
    );

    // Drain the load: the boundary flips back.
    drop(load);
    assert_eq!(s.cluster.mean_inflight(), 0.0);
    let drained = s.driver.execute(&q, None).unwrap().stats;
    assert!(
        drained.objects_pushdown > drained.objects_client,
        "drained cluster must favor pushdown again: {}p/{}c",
        drained.objects_pushdown,
        drained.objects_client
    );
    println!(
        "\nE11c: boundary flip -- idle {}p/{}c, 1k in-flight {}p/{}c, drained {}p/{}c",
        idle.objects_pushdown,
        idle.objects_client,
        busy.objects_pushdown,
        busy.objects_client,
        drained.objects_pushdown,
        drained.objects_client
    );
}

/// (d) Shared-scan batching across a barrier-started identical burst.
fn shared_scans() {
    let s = stack(4, 150_000, 64 * 1024, "shared");
    let router = Router::new(Arc::clone(&s.driver), 4);
    // Client-forced so every sub-query takes the fetch path the scan
    // cache fronts. Overlap is what creates hits, so retry the burst a
    // few times rather than assume the scheduler always interleaves.
    let mut hits = 0;
    for _round in 0..5 {
        let barrier = Barrier::new(32);
        std::thread::scope(|sc| {
            for _ in 0..32 {
                let (router, barrier) = (&router, &barrier);
                sc.spawn(move || {
                    barrier.wait();
                    let r = router
                        .handle(Request::Query {
                            query: reference_query("shared"),
                            force_mode: Some(ExecMode::ClientSide),
                            tenant: None,
                        })
                        .unwrap();
                    let Response::Query(qr) = r else { panic!() };
                    // Bit-identical answer whether served from the cache
                    // or fetched directly.
                    assert!((qr.aggregates[0] - 70.0).abs() < 40.0);
                });
            }
        });
        hits = router.metrics.counter("router.shared_scan_hits");
        if hits > 0 {
            break;
        }
    }
    assert!(hits > 0, "overlapping identical scans must share fetches");
    println!("\nE11d: 32-thread identical burst served {hits} scans from the shared cache");
}

fn main() {
    sweep();
    admission();
    boundary_flip();
    shared_scans();
    println!("\ne11_concurrency OK");
}
