//! E6 — Figure 3 / §2 goal 2: scale-out of the storage tier and the
//! driver's worker pool.
//!
//! Fixed workload (full-scan aggregate + selective filter over 400k
//! rows), sweeping (a) OSD count with workers fixed, (b) worker count
//! with OSDs fixed. Reports simulated makespan and speedup vs the
//! 1-node/1-worker baseline. Expected: near-linear OSD scaling for the
//! storage-bound scan until the per-object op overhead floor; worker
//! scaling matters for client-side execution, not pushdown.
//!
//! Run: `cargo bench --bench e6_scaleout`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;

fn run_case(osds: usize, workers: usize, mode: ExecMode, batch: &skyhook_map::dataset::Batch) -> f64 {
    let cfg = Config::from_text(&format!(
        "[cluster]\nosds = {osds}\nreplicas = 1\n[driver]\nworkers = {workers}\n"
    ))
    .unwrap();
    let stack = Stack::build(&cfg).unwrap();
    stack
        .driver
        .write_table(
            "t",
            batch,
            Layout::Col,
            &PartitionSpec::with_target(128 * 1024),
            None,
        )
        .unwrap();
    let q = Query::scan("t")
        .filter(Predicate::cmp("val", CmpOp::Gt, 40.0))
        .aggregate(AggFunc::Mean, "val");
    stack.driver.reset_time();
    stack.driver.execute(&q, Some(mode)).unwrap().stats.sim_seconds
}

fn main() {
    let batch = gen::sensor_table(400_000, 21);

    // (a) OSD scaling, pushdown.
    let mut rows = Vec::new();
    let base = run_case(1, 4, ExecMode::Pushdown, &batch);
    for osds in [1usize, 2, 4, 8, 16] {
        let s = run_case(osds, 4, ExecMode::Pushdown, &batch);
        rows.push(vec![
            osds.to_string(),
            format!("{s:.4}"),
            format!("{:.2}x", base / s),
            format!("{:.0}%", 100.0 * base / s / osds as f64),
        ]);
    }
    table(
        "E6a: OSD scale-out (pushdown scan, 4 workers)",
        &["OSDs", "sim s", "speedup", "efficiency"],
        &rows,
    );

    // (b) Worker scaling, client-side (workers do the compute there).
    let mut rows = Vec::new();
    let base_w = run_case(8, 1, ExecMode::ClientSide, &batch);
    for workers in [1usize, 2, 4, 8] {
        let s = run_case(8, workers, ExecMode::ClientSide, &batch);
        rows.push(vec![
            workers.to_string(),
            format!("{s:.4}"),
            format!("{:.2}x", base_w / s),
        ]);
    }
    table(
        "E6b: worker scale-out (client-side scan, 8 OSDs)",
        &["workers", "sim s", "speedup"],
        &rows,
    );

    // (c) Pushdown insensitivity to workers (compute lives on OSDs).
    let w1 = run_case(8, 1, ExecMode::Pushdown, &batch);
    let w8 = run_case(8, 8, ExecMode::Pushdown, &batch);
    println!(
        "\nE6c: pushdown with 1 vs 8 workers: {w1:.4}s vs {w8:.4}s \
         (compute runs on the storage tier, so workers barely matter)"
    );

    println!("\ne6_scaleout OK");
}
