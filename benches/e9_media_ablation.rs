//! E9 — ablation over storage media (§1 / abstract): "access libraries
//! often implement buffering and data layout that assume that large,
//! single-threaded sequential access ... while this is true for spinning
//! media, it is not true for flash media."
//!
//! Runs the same two workloads — (a) large sequential dataset write,
//! (b) many small parallel random hyperslab reads — under the HDD,
//! paper-testbed and flash cost profiles, native vs forwarding/scale-out,
//! showing how the media shift flips the winner for small parallel I/O.
//!
//! Run: `cargo bench --bench e9_media_ablation`

use skyhook_map::config::{ClusterConfig, CostProfile};
use skyhook_map::dataset::{Dataspace, Hyperslab};
use skyhook_map::skyhook::{CmpOp, Predicate};
use skyhook_map::store::Cluster;
use skyhook_map::util::bench::table;
use skyhook_map::util::rng::Xoshiro256;
use skyhook_map::vol::{vol_registry, ForwardingBackend, NativeBackend, VolBackend, VolFile};
use std::sync::Arc;

fn main() {
    let elems = 1usize << 20; // 4 MiB dataset
    let data: Vec<f32> = {
        let mut r = Xoshiro256::new(5);
        (0..elems).map(|_| r.f32()).collect()
    };
    let space = Dataspace::new(&[elems as u64]).unwrap();
    let chunk = vec![(elems / 128) as u64];

    let mut rows = Vec::new();
    for (profile, label) in [
        (CostProfile::Hdd, "hdd"),
        (CostProfile::PaperTestbed, "paper"),
        (CostProfile::Flash, "flash"),
    ] {
        // Native single node.
        let mut native = VolFile::open(Box::new(NativeBackend::new(profile.params())));
        native.create_dataset("d", &space, &chunk).unwrap();
        let t0 = native.now();
        native.write_all("d", &data).unwrap();
        let native_write = native.now() - t0;
        // Same total bytes two ways: one sequential whole-dataset read
        // on the single native device, vs 1024 random 1024-element (4 KiB)
        // reads spread over 8 OSDs by 8 concurrent sessions.
        let t0 = native.now();
        native.read("d", &Hyperslab::whole(&space)).unwrap();
        let native_seq = native.now() - t0;

        // Forwarding over 8 OSDs.
        let cluster = Cluster::new(
            &ClusterConfig {
                osds: 8,
                replicas: 1,
                profile,
                ..Default::default()
            },
            vol_registry(),
        );
        let mut fwd = VolFile::open(Box::new(ForwardingBackend::new(cluster)));
        fwd.create_dataset("d", &space, &chunk).unwrap();
        let t0 = fwd.now();
        fwd.write_all("d", &data).unwrap();
        let fwd_write = fwd.now() - t0;
        // 1024 x 4 KiB random reads = the same 4 MiB, issued by 8
        // concurrent client sessions (small *parallel* random access).
        let mut rng = Xoshiro256::new(9);
        let mut session_end = [0.0f64; 8];
        for i in 0..1024 {
            let start = rng.range(0, elems - 1025) as u64;
            let s = i % 8;
            let before = fwd.now();
            fwd.read("d", &Hyperslab::new(&[start], &[1024]).unwrap())
                .unwrap();
            session_end[s] += fwd.now() - before;
        }
        let fwd_rand = session_end.iter().cloned().fold(0.0, f64::max);

        rows.push(vec![
            label.to_string(),
            format!("{:.4}", native_write),
            format!("{:.4}", fwd_write),
            format!("{:.4}", native_seq),
            format!("{:.4}", fwd_rand),
            format!("{:.1}x", fwd_rand / native_seq),
            if fwd_rand < native_seq { "parallel-random" } else { "sequential" }.to_string(),
        ]);
    }
    table(
        "E9: media ablation — same 4 MiB, sequential vs small-parallel-random (sim s)",
        &[
            "profile",
            "native write",
            "fwd write",
            "seq read 4MiB",
            "rand read 4MiB",
            "rand/seq",
            "4 MiB read winner",
        ],
        &rows,
    );
    println!(
        "\nexpected shape (abstract/§1): on spinning media the per-op seek cost\n\
         (8 ms) makes small random access ~30x worse than one sequential\n\
         read — the assumption baked into access libraries. On the paper\n\
         testbed the per-op floor is 300 µs and the gap shrinks to ~3x.\n\
         On all-flash the *medium* no longer penalizes random access\n\
         (30 µs/op): the residual gap is the network round-trip, i.e. the\n\
         bottleneck moved from device seek to fabric latency — exactly why\n\
         §1 calls the old buffering/layout assumptions outdated, and why\n\
         server-local (pushdown) access that avoids the round-trips wins."
    );
    // E9b: the cost-based per-chunk offload decision flips with the
    // medium. Same filtered hyperslab read (32 full rows of a 256x4096
    // array, chunked [64,256] → 16 half-chunk pieces, `v < 0.5` ≈ 50%
    // selective) on HDD vs flash clusters. On HDD the 8 ms per-op floor
    // dwarfs the wire, but a half-selective pushdown still halves the
    // result bytes and skips the chunk decode — pushdown wins. On flash
    // the device is so fast that hauling the whole 64 KiB chunk and
    // filtering client-side beats paying the server scan — every chunk
    // flips to client-side.
    let space = Dataspace::new(&[256, 4096]).unwrap();
    let chunk = vec![64u64, 256];
    let data: Vec<f32> = {
        let mut r = Xoshiro256::new(7);
        (0..space.numel()).map(|_| r.f32()).collect()
    };
    let slab = Hyperslab::new(&[16, 0], &[32, 4096]).unwrap();
    let pred = Predicate::cmp("v", CmpOp::Lt, 0.5);
    let mut mixes = Vec::new();
    for (profile, label) in [(CostProfile::Hdd, "hdd"), (CostProfile::Flash, "flash")] {
        let cluster = Cluster::new(
            &ClusterConfig {
                osds: 8,
                replicas: 1,
                profile,
                ..Default::default()
            },
            vol_registry(),
        );
        let mut w = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&cluster))));
        w.create_dataset("e9b", &space, &chunk).unwrap();
        w.write_all("e9b", &data).unwrap();
        let mut fb = ForwardingBackend::new(Arc::clone(&cluster));
        let t = fb.read_slab_where(0.0, "e9b", &slab, &pred).unwrap();
        mixes.push((label, fb.stats(), t.value));
    }
    let (hdd, flash) = (&mixes[0], &mixes[1]);
    assert_eq!(hdd.2.len(), flash.2.len());
    for (a, b) in hdd.2.iter().zip(&flash.2) {
        assert_eq!(a.to_bits(), b.to_bits(), "cost profile changed the answer");
    }
    assert!(
        hdd.1.chunks_pushdown > flash.1.chunks_pushdown,
        "HDD must push more chunks than flash: {} vs {}",
        hdd.1.chunks_pushdown,
        flash.1.chunks_pushdown
    );
    assert!(
        flash.1.chunks_client > hdd.1.chunks_client,
        "flash must read more chunks client-side than HDD: {} vs {}",
        flash.1.chunks_client,
        hdd.1.chunks_client
    );
    println!(
        "\nE9b: per-chunk offload mode mix (16 half-chunk pieces, v<0.5):\n\
         hdd:   {} pushdown / {} client-side\n\
         flash: {} pushdown / {} client-side\n\
         — the same request, the same bytes, a different plan: the cost\n\
         model re-prices the pushdown-vs-fetch boundary per medium.",
        hdd.1.chunks_pushdown, hdd.1.chunks_client, flash.1.chunks_pushdown, flash.1.chunks_client
    );
    println!(
        "E9B_JSON {{\"hdd_pushdown\": {}, \"hdd_client\": {}, \
         \"flash_pushdown\": {}, \"flash_client\": {}}}",
        hdd.1.chunks_pushdown, hdd.1.chunks_client, flash.1.chunks_pushdown, flash.1.chunks_client
    );
    println!("\ne9_media_ablation OK");
}
