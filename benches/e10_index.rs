//! E10-index — the secondary-index access path, validated end to end.
//!
//! Sweeps predicate selectivity over a uniformly-valued column indexed
//! at ingest and records, per cell: the planner's free index-vs-scan
//! choice, probe/posting counters, and the simulated latency of the
//! chosen plan against both forced access paths.
//!
//! The crossover the cost model must get right (paper §4.2; Skyhook
//! arXiv:2204.06074):
//!
//! - **needle** predicates → IndexScan (a handful of postings beat
//!   re-evaluating the filter over every row, even after paying LSM
//!   read amplification on the probe);
//! - **broad** predicates → scan (walking most of the postings list
//!   costs more than the sequential row pass it was meant to avoid).
//!
//! The regime assertions are hard at the extremes: the bench fails if
//! the planner probes in the broad regime, scans in the needle regime,
//! or the chosen plan is slower than the best forced baseline (beyond
//! noise). The middle cells are reported, not pinned — they are the
//! crossover itself.
//!
//! Run: `cargo bench --bench e10_index` (snapshotted into
//! `BENCH_index.json` by `scripts/bench.sh`).

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::Batch;
use skyhook_map::dataset::{Column, DType, Layout, TableSchema};
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AccessForce, AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    // Uniform val in [0, 100): selectivities are arithmetic, so the
    // estimator's uniform-window model is exact and the regime cells
    // are decisive rather than distribution-tail lottery tickets.
    let rows = 200_000usize;
    let ts: Vec<i64> = (0..rows as i64).collect();
    let val: Vec<f32> = (0..rows).map(|i| (i % 10_000) as f32 / 100.0).collect();
    let batch = Batch::new(
        TableSchema::new(&[("ts", DType::I64), ("val", DType::F32)]),
        vec![Column::I64(ts), Column::F32(val)],
    )
    .unwrap();

    // (threshold on val, exact selectivity label, rows matched per 10k).
    let cells: &[(f64, &str, usize)] = &[
        (99.95, "0.0004", 4),
        (99.5, "0.0049", 49),
        (95.0, "0.0499", 499),
        (50.0, "0.4999", 4999),
        (0.0, "0.9999", 9999),
    ];

    let toml = "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n";
    let stack = Stack::build(&Config::from_text(toml).unwrap()).unwrap();
    stack
        .driver
        .write_table(
            "t",
            &batch,
            Layout::Col,
            &PartitionSpec::with_target(512 * 1024).index("val"),
            None,
        )
        .unwrap();

    let mut out = Vec::new();
    for &(thr, sel_label, per_cycle) in cells {
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, thr))
            .aggregate(AggFunc::Count, "val");
        let push = Some(ExecMode::Pushdown);

        stack.driver.reset_time();
        let chosen = stack.driver.execute_with_access(&q, push, None).unwrap();
        stack.driver.reset_time();
        let ix = stack
            .driver
            .execute_with_access(&q, push, Some(AccessForce::Index))
            .unwrap();
        stack.driver.reset_time();
        let scan = stack
            .driver
            .execute_with_access(&q, push, Some(AccessForce::Scan))
            .unwrap();

        // All three paths agree bit-for-bit on the exact count.
        let expect = (per_cycle * (rows / 10_000)) as f64;
        assert_eq!(chosen.aggregates[0], expect, "sel {sel_label}");
        assert_eq!(chosen.aggregates[0].to_bits(), ix.aggregates[0].to_bits());
        assert_eq!(chosen.aggregates[0].to_bits(), scan.aggregates[0].to_bits());
        assert!(ix.stats.index_probes > 0, "forced index must probe");
        assert_eq!(scan.stats.index_probes, 0, "forced scan must not probe");

        out.push(vec![
            sel_label.to_string(),
            chosen.stats.objects.to_string(),
            chosen.stats.index_probes.to_string(),
            chosen.stats.index_postings.to_string(),
            fmt_size(chosen.stats.bytes_moved),
            format!("{:.4}", chosen.stats.sim_seconds),
            format!("{:.4}", ix.stats.sim_seconds),
            format!("{:.4}", scan.stats.sim_seconds),
        ]);

        let best = ix.stats.sim_seconds.min(scan.stats.sim_seconds);
        if thr >= 99.5 {
            // Needle regime: the planner must probe, and the probe must
            // actually be the faster path it was priced as.
            assert!(
                chosen.stats.index_probes > 0,
                "sel {sel_label}: needle regime must pick IndexScan"
            );
            assert!(
                ix.stats.sim_seconds < scan.stats.sim_seconds,
                "sel {sel_label}: forced index {} should beat forced scan {}",
                ix.stats.sim_seconds,
                scan.stats.sim_seconds
            );
        }
        if thr <= 50.0 {
            // Broad regime: postings dominate; the planner must scan.
            assert_eq!(
                chosen.stats.index_probes,
                0,
                "sel {sel_label}: broad regime must pick the scan"
            );
            assert!(
                scan.stats.sim_seconds < ix.stats.sim_seconds,
                "sel {sel_label}: forced scan {} should beat forced index {}",
                scan.stats.sim_seconds,
                ix.stats.sim_seconds
            );
        }
        // Wherever the planner landed, the chosen plan tracks the best
        // forced baseline — the est-vs-actual bar for the probe pricing.
        assert!(
            chosen.stats.sim_seconds <= best * 1.10,
            "sel {sel_label}: chosen {} vs best forced {best}",
            chosen.stats.sim_seconds,
        );
    }

    table(
        "E10-index: index-vs-scan selectivity crossover (count(val) where val > t)",
        &[
            "sel",
            "objects",
            "probes",
            "postings",
            "moved",
            "chosen sim s",
            "index sim s",
            "scan sim s",
        ],
        &out,
    );
    println!(
        "\nexpected shape: needle rows probe (postings ~ matched rows, tiny bytes\n\
         moved), broad rows scan (probes = 0); the `chosen` column tracks\n\
         min(index, scan) in every row, crossing over in the middle cells."
    );
    println!("\ne10_index OK");
}
