//! E1 — Table 1: time to create a 3 GiB dataset, native access library
//! vs forwarding VOL plugin over 1/2/3 (+ more) nodes.
//!
//! Paper (§4.1): native 26.28 s; forwarding 61.12 / 36.07 / 29.34 s for
//! 1/2/3 nodes — the forwarding overhead is offset at 3 nodes. We
//! reproduce the *shape* on the calibrated simulated testbed at 1/32
//! scale and report paper-scale seconds.
//!
//! Run: `cargo bench --bench e1_table1_forwarding`

use skyhook_map::config::ClusterConfig;
use skyhook_map::dataset::{Dataspace, Hyperslab};
use skyhook_map::simnet::{CostParams, SimScale};
use skyhook_map::store::Cluster;
use skyhook_map::util::bench::table;
use skyhook_map::util::rng::Xoshiro256;
use skyhook_map::vol::{vol_registry, ForwardingBackend, NativeBackend, VolFile};

const PAPER_BYTES: u64 = 3 << 30;
const SCALE: f64 = 32.0;

fn main() {
    let scale = SimScale::new(SCALE);
    let elems = (scale.dataset_bytes(PAPER_BYTES) / 4) as usize;
    let mut rng = Xoshiro256::new(1);
    let data: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
    let space = Dataspace::new(&[elems as u64]).unwrap();
    let chunk = vec![(elems / 256) as u64];

    // Native baseline.
    let mut native = VolFile::open(Box::new(NativeBackend::new(CostParams::paper_testbed())));
    native.create_dataset("d", &space, &chunk).unwrap();
    let t0 = native.now();
    native.write_all("d", &data).unwrap();
    let native_s = scale.to_paper_seconds(native.now() - t0);

    let mut rows = vec![vec![
        "native (no plugin)".to_string(),
        "1".to_string(),
        format!("{native_s:.2}"),
        "26.28".to_string(),
        "-".to_string(),
    ]];

    // Forwarding plugin, 1..=6 nodes (paper stops at 3; we extend to show
    // diminishing returns once the client-side serialization dominates).
    let paper = [Some(61.12), Some(36.07), Some(29.34), None, None, None];
    let mut measured = Vec::new();
    for (i, osds) in (1usize..=6).enumerate() {
        let cfg = ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        };
        let cluster = Cluster::new(&cfg, vol_registry());
        let mut fwd = VolFile::open(Box::new(ForwardingBackend::new(cluster)));
        fwd.create_dataset("d", &space, &chunk).unwrap();
        let t0 = fwd.now();
        fwd.write_all("d", &data).unwrap();
        let s = scale.to_paper_seconds(fwd.now() - t0);
        measured.push(s);
        // Spot-check integrity.
        let got = fwd
            .read("d", &Hyperslab::new(&[42], &[8]).unwrap())
            .unwrap();
        assert_eq!(got, &data[42..50]);
        rows.push(vec![
            "forwarding plugin".to_string(),
            osds.to_string(),
            format!("{s:.2}"),
            paper[i].map(|p| format!("{p}")).unwrap_or("-".into()),
            paper[i]
                .map(|p| format!("{:+.1}%", (measured[i] - p) / p * 100.0))
                .unwrap_or("-".into()),
        ]);
    }

    table(
        "E1 / Table 1: create 3 GiB dataset (paper-scale seconds, sim testbed)",
        &["writer", "nodes", "measured (s)", "paper (s)", "error"],
        &rows,
    );

    // Shape assertions (the reproduction criteria).
    let overhead = measured[0] / native_s;
    println!("\nshape checks:");
    println!(
        "  forwarding/1-node = {overhead:.2}x native (paper: 61.12/26.28 = 2.33x)  {}",
        if (1.8..=2.9).contains(&overhead) { "OK" } else { "FAIL" }
    );
    // Strict monotonicity over the paper's 1..3 range; beyond that,
    // random placement imbalance can flatten the curve.
    let monotone = measured[..3].windows(2).all(|w| w[1] < w[0]);
    println!(
        "  makespan decreases over 1..3 nodes: {}",
        if monotone { "OK" } else { "FAIL" }
    );
    let offset3 = measured[2] < 1.25 * native_s;
    // (paper: 29.34 vs 26.28 — 'at least 3 nodes are required ... to
    // offset the forwarding plugin overhead')
    println!(
        "  3 nodes ≈ offsets the overhead ({:.2}s vs native {native_s:.2}s): {}",
        measured[2],
        if offset3 { "OK" } else { "FAIL" }
    );
    let fit_a = {
        // Fit t(n) = a + b/n on nodes 1 and 3 like the paper data.
        (3.0 * measured[2] - measured[0]) / 2.0
    };
    println!("  serial client term a = {fit_a:.2}s (paper fit: 13.45s)");
    assert!(monotone && (1.8..=2.9).contains(&overhead) && offset3);

    // ---- E1b: compiled-kernel estimator ablation ------------------------
    // Table 1's lesson is that server-side work only pays once it is
    // cheap enough; the compiled execution tier is the same argument one
    // level up. Price an eligible filter+aggregate plan with the tier
    // off vs on: the estimated pushdown seconds must drop strictly
    // (min-of-tiers takes the chunked rates) while the client estimate
    // is untouched — the estimator-level half of the E2d ablation.
    {
        use skyhook_map::config::Config;
        use skyhook_map::dataset::metadata;
        use skyhook_map::dataset::partition::PartitionSpec;
        use skyhook_map::dataset::table::gen;
        use skyhook_map::dataset::Layout;
        use skyhook_map::launch::Stack;
        use skyhook_map::skyhook::{plan_costed, AggFunc, CmpOp, Predicate, Query};

        let cfg = Config::from_text("[cluster]\nosds = 6\nreplicas = 1\n").unwrap();
        let stack = Stack::build(&cfg).unwrap();
        stack
            .driver
            .write_table(
                "t",
                &gen::sensor_table(200_000, 17),
                Layout::Col,
                &PartitionSpec::with_target(512 * 1024),
                None,
            )
            .unwrap();
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .aggregate(AggFunc::Mean, "val");
        let (meta, _) = metadata::load_meta(stack.driver.cluster(), 0.0, "t").unwrap();
        let mut est = Vec::new();
        for compiled in [false, true] {
            let mut cost = stack.driver.cluster().cost().clone();
            cost.exec.compiled_tier = compiled;
            let p = plan_costed(&q, &meta, None, true, &cost).unwrap();
            est.push((p.cost.pushdown_s, p.cost.client_s));
            println!(
                "  est {} tier: pushdown {:.4}s  client {:.4}s",
                if compiled { "compiled" } else { "scalar  " },
                p.cost.pushdown_s,
                p.cost.client_s
            );
        }
        assert!(
            est[1].0 < est[0].0,
            "compiled tier must price pushdown strictly cheaper: {est:?}"
        );
        assert!(
            (est[1].1 - est[0].1).abs() < 1e-12,
            "the tier must not move the client estimate: {est:?}"
        );
    }

    println!("\ne1_table1_forwarding OK");
}
