//! E5 — §3.2: composability of access operations.
//!
//! Algebraic aggregates (count/sum/mean/var/min/max) decompose into
//! constant-size partials: pushdown moves O(#objects) bytes. The holistic
//! median does not: the filtered values must travel. Sweeps dataset size
//! and reports bytes moved + simulated latency for both, plus the
//! co-partitioning remedy measured in E7.
//!
//! E5b measures *chained* operator pipelines (the logical-plan IR): a
//! filter→multi-aggregate→group-by chain and a filter→top-k chain, each
//! executed once with every pushable operator offloaded server-side
//! (one `skyhook.exec` pass per object) and once fully client-side.
//! Identical answers are asserted; the bytes-moved ratio is the win of
//! per-operator offload.
//!
//! Run: `cargo bench --bench e5_composability`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, ExecMode, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;
use skyhook_map::skyhook::parse::parse_predicate;

fn main() {
    let mut rows_out = Vec::new();
    for rows in [50_000usize, 100_000, 200_000, 400_000] {
        let cfg = Config::from_text(
            "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
        )
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let batch = gen::sensor_table(rows, 13);
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(256 * 1024),
                None,
            )
            .unwrap();
        let objects = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Count, "val"), None)
            .unwrap()
            .stats
            .objects;

        stack.driver.reset_time();
        let mean = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Mean, "val"), None)
            .unwrap();
        stack.driver.reset_time();
        let median = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Median, "val"), None)
            .unwrap();
        // The §3.2 remedy: a de-composable approximation (mergeable
        // quantile sketch — constant-size partials like the mean).
        stack.driver.reset_time();
        let (approx, bound, sketch_stats) = stack
            .driver
            .approx_quantile("t", "val", 0.5, &skyhook_map::skyhook::Predicate::True)
            .unwrap();

        // Sanity: median of N(50,15) ≈ 50; sketch within its bound.
        assert!((median.aggregates[0] - 50.0).abs() < 1.0);
        assert!((approx - median.aggregates[0]).abs() <= 2.0 * bound);

        rows_out.push(vec![
            rows.to_string(),
            objects.to_string(),
            fmt_size(mean.stats.bytes_moved),
            fmt_size(median.stats.bytes_moved),
            fmt_size(sketch_stats.bytes_moved),
            format!("{:.4}", mean.stats.sim_seconds),
            format!("{:.4}", median.stats.sim_seconds),
            format!(
                "{:.0}x",
                median.stats.bytes_moved as f64 / mean.stats.bytes_moved as f64
            ),
            format!("{:.3}", (approx - median.aggregates[0]).abs()),
        ]);
    }
    table(
        "E5: algebraic (mean) vs holistic (median) aggregate pushdown",
        &[
            "rows",
            "objects",
            "mean bytes",
            "median bytes",
            "sketch bytes",
            "mean sim s",
            "median sim s",
            "median penalty",
            "sketch err",
        ],
        &rows_out,
    );
    println!(
        "\nexpected shape: mean's bytes stay ~O(objects) and flat per row count;\n\
         median's bytes grow linearly with rows. The sketch column is the §3.2\n\
         remedy implemented: a de-composable approximation whose partials are\n\
         constant-size (like the mean) with the measured absolute error shown."
    );

    // ---- E5b: chained-pipeline offload vs client-side ------------------
    let mut chain_out = Vec::new();
    for rows in [100_000usize, 400_000] {
        let cfg = Config::from_text(
            "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
        )
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let batch = gen::sensor_table(rows, 13);
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(256 * 1024),
                None,
            )
            .unwrap();

        // Chain 1: filter → [sum, count, var] by (sensor, flag) — the
        // whole pipeline runs server-side in one exec pass per object.
        let agg_chain = Query::scan("t")
            .filter(parse_predicate("val > 60 && flag == 0").unwrap())
            .group("sensor")
            .group("flag")
            .aggregate(AggFunc::Sum, "val")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Var, "val");
        // Chain 2: filter → project → top-20 by val (distributed top-k:
        // each object ships only its local top 20).
        let topk_chain = Query::scan("t")
            .filter(parse_predicate("val > 60").unwrap())
            .select(&["ts", "val"])
            .top_k("val", true, 20);

        for (name, q) in [("filter→3agg by 2keys", &agg_chain), ("filter→top20", &topk_chain)] {
            stack.driver.reset_time();
            let push = stack.driver.execute(q, Some(ExecMode::Pushdown)).unwrap();
            stack.driver.reset_time();
            let client = stack.driver.execute(q, Some(ExecMode::ClientSide)).unwrap();
            // Identical answers in both modes.
            match (&push.groups, &client.groups) {
                (Some(a), Some(b)) => assert_eq!(a.len(), b.len()),
                _ => assert_eq!(
                    push.rows.as_ref().map(|b| b.nrows()),
                    client.rows.as_ref().map(|b| b.nrows())
                ),
            }
            // The acceptance bar: the offloaded chain moves measurably
            // fewer bytes than client-side execution of the same plan.
            assert!(
                push.stats.bytes_moved * 2 < client.stats.bytes_moved,
                "{name}: pushdown {} vs client {}",
                push.stats.bytes_moved,
                client.stats.bytes_moved
            );
            chain_out.push(vec![
                rows.to_string(),
                name.to_string(),
                fmt_size(push.stats.bytes_moved),
                fmt_size(client.stats.bytes_moved),
                format!(
                    "{:.0}x",
                    client.stats.bytes_moved as f64 / push.stats.bytes_moved.max(1) as f64
                ),
                format!("{:.4}", push.stats.sim_seconds),
                format!("{:.4}", client.stats.sim_seconds),
            ]);
        }
    }
    table(
        "E5b: chained-pipeline per-operator offload vs client-side",
        &[
            "rows",
            "chain",
            "pushdown moved",
            "client moved",
            "reduction",
            "push sim s",
            "client sim s",
        ],
        &chain_out,
    );
    println!(
        "\nexpected shape: the offloaded chain moves O(groups) or O(k) bytes per\n\
         object regardless of row count; client-side execution of the same\n\
         logical plan fetches the needed columns of every object, so its bytes\n\
         grow linearly with rows."
    );
    println!("\ne5_composability OK");
}
