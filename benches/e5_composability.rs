//! E5 — §3.2: composability of access operations.
//!
//! Algebraic aggregates (count/sum/mean/var/min/max) decompose into
//! constant-size partials: pushdown moves O(#objects) bytes. The holistic
//! median does not: the filtered values must travel. Sweeps dataset size
//! and reports bytes moved + simulated latency for both, plus the
//! co-partitioning remedy measured in E7.
//!
//! Run: `cargo bench --bench e5_composability`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let mut rows_out = Vec::new();
    for rows in [50_000usize, 100_000, 200_000, 400_000] {
        let cfg = Config::from_text(
            "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
        )
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let batch = gen::sensor_table(rows, 13);
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(256 * 1024),
                None,
            )
            .unwrap();
        let objects = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Count, "val"), None)
            .unwrap()
            .stats
            .objects;

        stack.driver.reset_time();
        let mean = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Mean, "val"), None)
            .unwrap();
        stack.driver.reset_time();
        let median = stack
            .driver
            .execute(&Query::scan("t").aggregate(AggFunc::Median, "val"), None)
            .unwrap();
        // The §3.2 remedy: a de-composable approximation (mergeable
        // quantile sketch — constant-size partials like the mean).
        stack.driver.reset_time();
        let (approx, bound, sketch_stats) = stack
            .driver
            .approx_quantile("t", "val", 0.5, &skyhook_map::skyhook::Predicate::True)
            .unwrap();

        // Sanity: median of N(50,15) ≈ 50; sketch within its bound.
        assert!((median.aggregates[0] - 50.0).abs() < 1.0);
        assert!((approx - median.aggregates[0]).abs() <= 2.0 * bound);

        rows_out.push(vec![
            rows.to_string(),
            objects.to_string(),
            fmt_size(mean.stats.bytes_moved),
            fmt_size(median.stats.bytes_moved),
            fmt_size(sketch_stats.bytes_moved),
            format!("{:.4}", mean.stats.sim_seconds),
            format!("{:.4}", median.stats.sim_seconds),
            format!(
                "{:.0}x",
                median.stats.bytes_moved as f64 / mean.stats.bytes_moved as f64
            ),
            format!("{:.3}", (approx - median.aggregates[0]).abs()),
        ]);
    }
    table(
        "E5: algebraic (mean) vs holistic (median) aggregate pushdown",
        &[
            "rows",
            "objects",
            "mean bytes",
            "median bytes",
            "sketch bytes",
            "mean sim s",
            "median sim s",
            "median penalty",
            "sketch err",
        ],
        &rows_out,
    );
    println!(
        "\nexpected shape: mean's bytes stay ~O(objects) and flat per row count;\n\
         median's bytes grow linearly with rows. The sketch column is the §3.2\n\
         remedy implemented: a de-composable approximation whose partials are\n\
         constant-size (like the mean) with the measured absolute error shown."
    );
    println!("\ne5_composability OK");
}
