//! E6-cost — the planner's cost-based offload choice, validated.
//!
//! Sweeps selectivity × object size over an unprojected filtered scan
//! and records, per cell: the per-object assignment the cost model
//! chose, the estimated vs actual bytes moved, and the simulated
//! latency of the chosen plan against both forced baselines.
//!
//! The two regimes the model must get right (Skyhook arXiv:2204.06074,
//! HEP object-store study arXiv:2107.07304):
//!
//! - **selective** filters → pushdown (partials are tiny; shipping the
//!   object would waste the network);
//! - **selectivity ~1 on small objects** → client-side (pushdown would
//!   re-encode and ship every row anyway, paying server CPU for
//!   nothing — the plain read path wins).
//!
//! Both regime assertions are hard: the bench fails if the planner
//! picks the wrong side or the chosen plan is slower than the best
//! forced baseline (beyond noise).
//!
//! Run: `cargo bench --bench e6_cost_model` (snapshotted into
//! `BENCH_costmodel.json` by `scripts/bench.sh`).

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let rows = 200_000usize;
    let batch = gen::sensor_table(rows, 17);

    // (target object size, label) × (threshold on val ~ N(50,15), label).
    let sizes: &[(u64, &str)] = &[(4 * 1024, "4KiB"), (64 * 1024, "64KiB"), (512 * 1024, "512KiB")];
    let sels: &[(f64, &str)] = &[(-1000.0, "~1.00"), (50.0, "~0.50"), (95.0, "~0.00")];

    let mut out = Vec::new();
    for &(target, size_label) in sizes {
        for &(thr, sel_label) in sels {
            let cfg = Config::from_text(
                "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
            )
            .unwrap();
            let stack = Stack::build(&cfg).unwrap();
            stack
                .driver
                .write_table(
                    "t",
                    &batch,
                    Layout::Col,
                    &PartitionSpec::with_target(target),
                    None,
                )
                .unwrap();
            // Unprojected filtered scan: the offload decision hinges
            // purely on how much the filter reduces.
            let q = Query::scan("t").filter(Predicate::cmp("val", CmpOp::Gt, thr));

            stack.driver.reset_time();
            let chosen = stack.driver.execute(&q, None).unwrap();
            stack.driver.reset_time();
            let push = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
            stack.driver.reset_time();
            let client = stack.driver.execute(&q, Some(ExecMode::ClientSide)).unwrap();

            // All three executions agree on the answer.
            assert_eq!(
                chosen.rows.as_ref().unwrap().nrows(),
                push.rows.as_ref().unwrap().nrows()
            );
            assert_eq!(
                chosen.rows.as_ref().unwrap().nrows(),
                client.rows.as_ref().unwrap().nrows()
            );

            out.push(vec![
                size_label.to_string(),
                sel_label.to_string(),
                chosen.stats.objects.to_string(),
                format!(
                    "{}p/{}c",
                    chosen.stats.objects_pushdown, chosen.stats.objects_client
                ),
                fmt_size(chosen.stats.bytes_estimated),
                fmt_size(chosen.stats.bytes_moved),
                format!("{:.4}", chosen.stats.sim_seconds),
                format!("{:.4}", push.stats.sim_seconds),
                format!("{:.4}", client.stats.sim_seconds),
            ]);

            // Regime assertions (the acceptance bar of the cost model).
            let (np, nc) = (chosen.stats.objects_pushdown, chosen.stats.objects_client);
            if thr <= -100.0 && target <= 64 * 1024 {
                assert!(
                    nc > np,
                    "{size_label}/{sel_label}: expected client-side majority, got {np}p/{nc}c"
                );
                assert!(
                    chosen.stats.sim_seconds <= push.stats.sim_seconds * 1.05,
                    "{size_label}/{sel_label}: chosen {} vs forced push {}",
                    chosen.stats.sim_seconds,
                    push.stats.sim_seconds
                );
            }
            if thr >= 95.0 && target >= 512 * 1024 {
                // The classic selective regime: few large objects per
                // OSD, tiny partials — pushdown wins outright.
                assert!(
                    np > nc,
                    "{size_label}/{sel_label}: expected pushdown majority, got {np}p/{nc}c"
                );
                assert!(
                    chosen.stats.sim_seconds <= client.stats.sim_seconds * 1.05,
                    "{size_label}/{sel_label}: chosen {} vs forced client {}",
                    chosen.stats.sim_seconds,
                    client.stats.sim_seconds
                );
                assert!(
                    chosen.stats.bytes_moved < client.stats.bytes_moved,
                    "selective pushdown must move fewer bytes"
                );
            }
            if thr >= 95.0 && target <= 64 * 1024 {
                // The contended regime (objects ≫ OSDs): the serialized
                // extension CPU shifts (some of) the boundary
                // client-ward even for a selective filter — the HEP
                // tiny-object observation. Whatever the split, the
                // chosen plan must track the better forced baseline.
                assert!(
                    nc > 0,
                    "{size_label}/{sel_label}: saturation should shed work client-ward, got {np}p/{nc}c"
                );
                let best = push.stats.sim_seconds.min(client.stats.sim_seconds);
                assert!(
                    chosen.stats.sim_seconds <= best * 1.10,
                    "{size_label}/{sel_label}: chosen {} vs best forced {best}",
                    chosen.stats.sim_seconds,
                );
            }
            // Where the uniform-range assumption is well-founded (the
            // match-everything cells), the bytes estimate must track the
            // actual wire bytes closely. Tail-selectivity cells are
            // reported but not pinned: val is normal, so the uniform
            // model deliberately over-estimates the tail (a conservative
            // bias — it can only under-sell pushdown's win there).
            if thr <= -100.0 {
                let est = chosen.stats.bytes_estimated.max(1) as f64;
                let act = chosen.stats.bytes_moved.max(1) as f64;
                assert!(
                    est / act < 4.0 && act / est < 4.0,
                    "{size_label}/{sel_label}: estimate {est} drifted from actual {act}"
                );
            }
        }
    }
    // ---- E6-sat: the OSD-contention shift, isolated ---------------------
    // Same dataset and query, priced through plan_costed for a 16-OSD
    // cluster (uncontended) and a 1-OSD cluster (saturated). Deterministic
    // — no simulation noise — so the boundary shift asserts hard: the
    // selective scan pushes down when servers are free and goes
    // client-side when every object queues on one server's CPU.
    {
        use skyhook_map::dataset::metadata;
        use skyhook_map::simnet::CostParams;
        use skyhook_map::skyhook::plan_costed;
        let cfg = Config::from_text(
            "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
        )
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(512 * 1024),
                None,
            )
            .unwrap();
        let q = Query::scan("t").filter(Predicate::cmp("val", CmpOp::Gt, 95.0));
        let (meta, _) = metadata::load_meta(stack.driver.cluster(), 0.0, "t").unwrap();
        let mut sat_rows = Vec::new();
        let mut assignments = Vec::new();
        for osds in [16usize, 4, 1] {
            let cost = CostParams {
                osds,
                ..stack.driver.cluster().cost().clone()
            };
            let p = plan_costed(&q, &meta, None, true, &cost).unwrap();
            assignments.push(p.assignment);
            sat_rows.push(vec![
                osds.to_string(),
                p.subqueries.len().to_string(),
                format!("{:.1}", p.subqueries.len() as f64 / osds as f64),
                format!("{}p/{}c", p.assignment.0, p.assignment.1),
                format!("{:.4}", p.cost.pushdown_s),
                format!("{:.4}", p.cost.client_s),
            ]);
        }
        table(
            "E6-sat: objects-per-OSD saturation shifts the offload boundary",
            &[
                "osds",
                "objects",
                "objs/osd",
                "assignment",
                "est push s",
                "est client s",
            ],
            &sat_rows,
        );
        // Uncontended → pushdown majority; fully saturated → client
        // majority; client-side count never decreases as contention grows.
        assert!(
            assignments[0].0 > assignments[0].1,
            "16 OSDs should push down: {assignments:?}"
        );
        assert!(
            assignments[2].1 > assignments[2].0,
            "1 OSD should shed client-ward: {assignments:?}"
        );
        assert!(
            assignments[0].1 <= assignments[1].1 && assignments[1].1 <= assignments[2].1,
            "client share must grow with contention: {assignments:?}"
        );

        // ---- E6-kernel: the compiled tier moves the offload boundary ----
        // The saturated (1-OSD) aggregate cell: with the scalar kernel,
        // the serialized extension CPU makes the plain read path win and
        // every object goes client-side; enable the compiled tier and
        // the same cell flips back to pushdown because the chunked pass
        // is cheap enough to pay even at full contention. Deterministic
        // (plan_costed, no simulation noise), so the flip asserts hard.
        let qk = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 20.0))
            .aggregate(skyhook_map::skyhook::AggFunc::Mean, "val");
        let mut kernel_rows = Vec::new();
        let mut flip = Vec::new();
        for compiled in [false, true] {
            let mut cost = CostParams {
                osds: 1,
                ..stack.driver.cluster().cost().clone()
            };
            cost.exec.compiled_tier = compiled;
            let p = plan_costed(&qk, &meta, None, true, &cost).unwrap();
            flip.push(p.assignment);
            kernel_rows.push(vec![
                (if compiled { "compiled" } else { "scalar" }).to_string(),
                format!("{}p/{}c", p.assignment.0, p.assignment.1),
                format!("{:.4}", p.cost.pushdown_s),
                format!("{:.4}", p.cost.client_s),
            ]);
        }
        table(
            "E6-kernel: mean(val) where val>20 at 1 OSD — tier flips the assignment",
            &["kernel tier", "assignment", "est push s", "est client s"],
            &kernel_rows,
        );
        assert!(
            flip[0].1 > flip[0].0,
            "scalar tier at 1 OSD should assign client-side: {flip:?}"
        );
        assert!(
            flip[1].0 > flip[1].1,
            "compiled tier should flip the cell to pushdown: {flip:?}"
        );
    }

    table(
        "E6-cost: cost-based offload choice across selectivity × object size",
        &[
            "objsize",
            "sel",
            "objects",
            "assignment",
            "est moved",
            "moved",
            "chosen sim s",
            "push sim s",
            "client sim s",
        ],
        &out,
    );
    println!(
        "\nexpected shape: high-selectivity cells assign client-side (the plain read\n\
         path beats re-encode-and-ship when nothing reduces), selective cells assign\n\
         pushdown (tiny partials). The chosen column should track min(push, client)\n\
         in every row, and `est moved` should track `moved`."
    );
    println!("\ne6_cost_model OK");
}
