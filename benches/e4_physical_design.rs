//! E4 — §5 bullet 2: physical design management.
//!
//! Row vs columnar object layout across query projectivity (how many of
//! the 16 columns a query touches), plus the storage-side transform cost
//! and its break-even. Also times the raw layout codecs (wall clock) and,
//! when artifacts are present, the PJRT transform kernel.
//!
//! Run: `cargo bench --bench e4_physical_design`

use skyhook_map::config::Config;
use skyhook_map::dataset::layout::{decode_batch, decode_projection, encode_batch};
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::{black_box, report, table, Bench};
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let rows = 150_000;
    let ncols = 16;
    let batch = gen::wide_table(rows, ncols, 9);

    // ---- query-path comparison over the cluster ------------------------
    let mut out = Vec::new();
    for projectivity in [1usize, 4, 16] {
        let mut sims = Vec::new();
        for layout in [Layout::Row, Layout::Col] {
            let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
            let stack = Stack::build(&cfg).unwrap();
            stack
                .driver
                .write_table(
                    "w",
                    &batch,
                    layout,
                    &PartitionSpec::with_target(512 * 1024),
                    None,
                )
                .unwrap();
            let mut q = Query::scan("w");
            for c in 0..projectivity {
                q = q.aggregate(AggFunc::Mean, &format!("c{c}"));
            }
            stack.driver.reset_time();
            let r = stack.driver.execute(&q, None).unwrap();
            sims.push(r.stats.sim_seconds);
        }
        out.push(vec![
            format!("{projectivity}/{ncols}"),
            format!("{:.4}", sims[0]),
            format!("{:.4}", sims[1]),
            format!("{:.2}x", sims[0] / sims[1]),
        ]);
    }
    table(
        "E4a: mean over k of 16 columns — row vs col objects (sim seconds)",
        &["projectivity", "row", "col", "col speedup"],
        &out,
    );

    // ---- transform cost + break-even -----------------------------------
    let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
    let stack = Stack::build(&cfg).unwrap();
    stack
        .driver
        .write_table(
            "w",
            &batch,
            Layout::Row,
            &PartitionSpec::with_target(512 * 1024),
            None,
        )
        .unwrap();
    let q = Query::scan("w").aggregate(AggFunc::Mean, "c0");
    stack.driver.reset_time();
    let before = stack.driver.execute(&q, None).unwrap().stats.sim_seconds;
    stack.driver.reset_time();
    let tcost = stack
        .driver
        .transform_layout("w", Layout::Col)
        .unwrap()
        .sim_seconds;
    stack.driver.reset_time();
    let after = stack.driver.execute(&q, None).unwrap().stats.sim_seconds;
    println!(
        "\nE4b: transform-at-storage cost {tcost:.3}s; query {before:.4}s -> {after:.4}s; \
         break-even after {:.1} queries",
        tcost / (before - after).max(1e-9)
    );

    // ---- E4e: sort-aware clustered ingest sweep -------------------------
    // The same table written unclustered vs clustered by `val`, measured
    // on the two workloads write-time clustering targets: ascending
    // top-k over the clustered column (bounded prefix reads) and a range
    // filter over it (sharpened zone maps + filter early-stop). The
    // assertions pin the physical-design claim: clustering must
    // *strictly* reduce bytes moved and per-object sort/scan work.
    struct ClusterCell {
        client_bytes: u64,
        push_sim: f64,
        prefix_reads: u64,
        pruned: usize,
        short_circuited: u64,
        explain: String,
    }
    let cbatch = gen::sensor_table(120_000, 33);
    let mut cells = Vec::new();
    let mut crows = Vec::new();
    for clustered in [false, true] {
        let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let mut spec = PartitionSpec::with_target(256 * 1024);
        if clustered {
            spec = spec.cluster_by("val");
        }
        stack
            .driver
            .write_table("cb", &cbatch, Layout::Col, &spec, None)
            .unwrap();
        let topk = Query::scan("cb").select(&["ts"]).sort("val").limit(32);
        stack.driver.reset_time();
        let cli = stack
            .driver
            .execute(&topk, Some(ExecMode::ClientSide))
            .unwrap();
        stack.driver.reset_time();
        let push = stack.driver.execute(&topk, Some(ExecMode::Pushdown)).unwrap();
        let range = Query::scan("cb")
            .filter(Predicate::cmp("val", CmpOp::Lt, 35.0))
            .aggregate(AggFunc::Count, "val");
        stack.driver.reset_time();
        let pr = stack.driver.execute(&range, None).unwrap();
        let cell = ClusterCell {
            client_bytes: cli.stats.bytes_moved,
            push_sim: push.stats.sim_seconds,
            prefix_reads: push.stats.prefix_reads,
            pruned: pr.stats.objects_pruned,
            short_circuited: pr.stats.rows_short_circuited,
            explain: stack.driver.explain(&topk, None).unwrap(),
        };
        crows.push(vec![
            if clustered { "cluster_by=val" } else { "unclustered" }.to_string(),
            fmt_size(cell.client_bytes),
            format!("{:.4}", cell.push_sim),
            cell.prefix_reads.to_string(),
            format!("{}/{}", cell.pruned, pr.stats.objects + pr.stats.objects_pruned),
            cell.short_circuited.to_string(),
        ]);
        cells.push(cell);
    }
    table(
        "E4e: clustered vs unclustered ingest — top-32 by val + range filter",
        &[
            "layout",
            "top-k client bytes",
            "top-k push sim",
            "prefix reads",
            "range pruned",
            "rows short-circ",
        ],
        &crows,
    );
    let (un, cl) = (&cells[0], &cells[1]);
    assert!(
        cl.client_bytes < un.client_bytes,
        "clustered top-k must strictly reduce bytes moved: {} vs {}",
        cl.client_bytes,
        un.client_bytes
    );
    assert!(
        cl.push_sim < un.push_sim,
        "clustered top-k must strictly reduce per-object sort/scan work: {} vs {}",
        cl.push_sim,
        un.push_sim
    );
    assert!(cl.prefix_reads > 0 && un.prefix_reads == 0);
    assert!(
        cl.explain.contains("(prefix read)") && cl.explain.contains("clustered by \"val\""),
        "explain must show the prefix-read stage:\n{}",
        cl.explain
    );
    assert!(
        cl.pruned > un.pruned,
        "clustered range filter must prune more: {} vs {}",
        cl.pruned,
        un.pruned
    );
    assert!(cl.short_circuited > 0);

    // ---- codec microbenches (wall clock) --------------------------------
    let small = gen::wide_table(20_000, ncols, 2);
    let row_bytes = encode_batch(&small, Layout::Row);
    let col_bytes = encode_batch(&small, Layout::Col);
    let b = Bench::new().warmup(1).samples(8);
    let results = vec![
        b.run_bytes("encode row", row_bytes.len() as u64, || {
            black_box(encode_batch(&small, Layout::Row));
        }),
        b.run_bytes("encode col", col_bytes.len() as u64, || {
            black_box(encode_batch(&small, Layout::Col));
        }),
        b.run_bytes("decode row (full)", row_bytes.len() as u64, || {
            black_box(decode_batch(&row_bytes).unwrap());
        }),
        b.run_bytes("decode col (full)", col_bytes.len() as u64, || {
            black_box(decode_batch(&col_bytes).unwrap());
        }),
        b.run_bytes("project 1/16 from row", row_bytes.len() as u64, || {
            black_box(decode_projection(&row_bytes, &["c3"]).unwrap());
        }),
        b.run_bytes("project 1/16 from col", col_bytes.len() as u64, || {
            black_box(decode_projection(&col_bytes, &["c3"]).unwrap());
        }),
    ];
    report("E4c: layout codec microbenches (20k x 16 f32)", &results);

    // ---- PJRT transform kernel (when artifacts exist) --------------------
    if std::path::Path::new("artifacts/transform_r2c.hlo.txt").exists() {
        use skyhook_map::runtime::{PjrtEngine, COLS, ROWS};
        let engine = PjrtEngine::load("artifacts").unwrap();
        let data: Vec<f32> = (0..ROWS * COLS).map(|i| i as f32).collect();
        let r = Bench::new().warmup(1).samples(5).run_bytes(
            "pjrt transform r2c (16384x8)",
            (ROWS * COLS * 4) as u64,
            || {
                black_box(engine.transform(&data, true).unwrap());
            },
        );
        report("E4d: AOT Pallas transform kernel", &[r]);
    }

    println!("\ne4_physical_design OK");
}
