//! E4 — §5 bullet 2: physical design management.
//!
//! Row vs columnar object layout across query projectivity (how many of
//! the 16 columns a query touches), plus the storage-side transform cost
//! and its break-even. Also times the raw layout codecs (wall clock) and,
//! when artifacts are present, the PJRT transform kernel.
//!
//! Run: `cargo bench --bench e4_physical_design`

use skyhook_map::config::Config;
use skyhook_map::dataset::layout::{decode_batch, decode_projection, encode_batch};
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, Query};
use skyhook_map::util::bench::{black_box, report, table, Bench};

fn main() {
    let rows = 150_000;
    let ncols = 16;
    let batch = gen::wide_table(rows, ncols, 9);

    // ---- query-path comparison over the cluster ------------------------
    let mut out = Vec::new();
    for projectivity in [1usize, 4, 16] {
        let mut sims = Vec::new();
        for layout in [Layout::Row, Layout::Col] {
            let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
            let stack = Stack::build(&cfg).unwrap();
            stack
                .driver
                .write_table(
                    "w",
                    &batch,
                    layout,
                    &PartitionSpec::with_target(512 * 1024),
                    None,
                )
                .unwrap();
            let mut q = Query::scan("w");
            for c in 0..projectivity {
                q = q.aggregate(AggFunc::Mean, &format!("c{c}"));
            }
            stack.driver.reset_time();
            let r = stack.driver.execute(&q, None).unwrap();
            sims.push(r.stats.sim_seconds);
        }
        out.push(vec![
            format!("{projectivity}/{ncols}"),
            format!("{:.4}", sims[0]),
            format!("{:.4}", sims[1]),
            format!("{:.2}x", sims[0] / sims[1]),
        ]);
    }
    table(
        "E4a: mean over k of 16 columns — row vs col objects (sim seconds)",
        &["projectivity", "row", "col", "col speedup"],
        &out,
    );

    // ---- transform cost + break-even -----------------------------------
    let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
    let stack = Stack::build(&cfg).unwrap();
    stack
        .driver
        .write_table(
            "w",
            &batch,
            Layout::Row,
            &PartitionSpec::with_target(512 * 1024),
            None,
        )
        .unwrap();
    let q = Query::scan("w").aggregate(AggFunc::Mean, "c0");
    stack.driver.reset_time();
    let before = stack.driver.execute(&q, None).unwrap().stats.sim_seconds;
    stack.driver.reset_time();
    let tcost = stack
        .driver
        .transform_layout("w", Layout::Col)
        .unwrap()
        .sim_seconds;
    stack.driver.reset_time();
    let after = stack.driver.execute(&q, None).unwrap().stats.sim_seconds;
    println!(
        "\nE4b: transform-at-storage cost {tcost:.3}s; query {before:.4}s -> {after:.4}s; \
         break-even after {:.1} queries",
        tcost / (before - after).max(1e-9)
    );

    // ---- codec microbenches (wall clock) --------------------------------
    let small = gen::wide_table(20_000, ncols, 2);
    let row_bytes = encode_batch(&small, Layout::Row);
    let col_bytes = encode_batch(&small, Layout::Col);
    let b = Bench::new().warmup(1).samples(8);
    let results = vec![
        b.run_bytes("encode row", row_bytes.len() as u64, || {
            black_box(encode_batch(&small, Layout::Row));
        }),
        b.run_bytes("encode col", col_bytes.len() as u64, || {
            black_box(encode_batch(&small, Layout::Col));
        }),
        b.run_bytes("decode row (full)", row_bytes.len() as u64, || {
            black_box(decode_batch(&row_bytes).unwrap());
        }),
        b.run_bytes("decode col (full)", col_bytes.len() as u64, || {
            black_box(decode_batch(&col_bytes).unwrap());
        }),
        b.run_bytes("project 1/16 from row", row_bytes.len() as u64, || {
            black_box(decode_projection(&row_bytes, &["c3"]).unwrap());
        }),
        b.run_bytes("project 1/16 from col", col_bytes.len() as u64, || {
            black_box(decode_projection(&col_bytes, &["c3"]).unwrap());
        }),
    ];
    report("E4c: layout codec microbenches (20k x 16 f32)", &results);

    // ---- PJRT transform kernel (when artifacts exist) --------------------
    if std::path::Path::new("artifacts/transform_r2c.hlo.txt").exists() {
        use skyhook_map::runtime::{PjrtEngine, COLS, ROWS};
        let engine = PjrtEngine::load("artifacts").unwrap();
        let data: Vec<f32> = (0..ROWS * COLS).map(|i| i as f32).collect();
        let r = Bench::new().warmup(1).samples(5).run_bytes(
            "pjrt transform r2c (16384x8)",
            (ROWS * COLS * 4) as u64,
            || {
                black_box(engine.transform(&data, true).unwrap());
            },
        );
        report("E4d: AOT Pallas transform kernel", &[r]);
    }

    println!("\ne4_physical_design OK");
}
