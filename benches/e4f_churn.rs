//! E4f — mutable datasets: churn-then-compact.
//!
//! The lifecycle claim behind delete vectors + row-group appends +
//! re-clustering compaction, pinned with hard asserts:
//!
//!   1. churn (interleaved appends and tombstone deletes that keep the
//!      live row count constant) must *strictly* degrade the cost of a
//!      fixed clustered workload — dead rows ride along, appended
//!      objects break the val-clustering, delete vectors add reads;
//!   2. compaction must bring that cost back to within 10% of the
//!      pre-churn baseline — same live rows, re-sorted, zero tombstones;
//!   3. at every stage the answers are bit-identical to an independently
//!      maintained reference model, and the three forced execution modes
//!      agree with each other bit for bit.
//!
//! Run: `cargo bench --bench e4f_churn`

use skyhook_map::config::Config;
use skyhook_map::dataset::layout::decode_batch;
use skyhook_map::dataset::metadata;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::{gen, Batch, Column};
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{
    sort_rows, AggFunc, CmpOp, ExecMode, Predicate, Query, SortKey,
};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;
use std::collections::HashSet;

/// Per-stage cost of the fixed workload (default planner mode), plus the
/// physical-design signals the stages move.
struct StageCost {
    sim: f64,
    bytes: u64,
    prefix_reads: u64,
    pruned: usize,
}

/// Run the fixed workload, assert reference equality and three-mode
/// agreement, and return the stage's cost.
fn run_stage(stack: &Stack, reference: &Batch, label: &str) -> StageCost {
    let modes = [None, Some(ExecMode::Pushdown), Some(ExecMode::ClientSide)];

    // q1 — ascending top-32 by the clustered column, ts tiebreak (total
    // order, so the rows compare bit-exactly against the model).
    let q1 = Query::scan("cb")
        .select(&["ts", "val"])
        .sort("val")
        .sort("ts")
        .limit(32);
    let expected = sort_rows(reference, &[SortKey::asc("val"), SortKey::asc("ts")])
        .unwrap()
        .slice(0, reference.nrows().min(32))
        .unwrap()
        .project(&["ts", "val"])
        .unwrap();
    let mut q1_rows = Vec::new();
    let mut sim = 0.0;
    let mut bytes = 0;
    let mut prefix_reads = 0;
    let mut pruned = 0;
    for mode in modes {
        stack.driver.reset_time();
        let r = stack.driver.execute(&q1, mode).unwrap();
        if mode.is_none() {
            sim += r.stats.sim_seconds;
            bytes += r.stats.bytes_moved;
            prefix_reads = r.stats.prefix_reads;
        }
        q1_rows.push(r.rows.unwrap());
    }
    assert_eq!(q1_rows[0], expected, "{label}: top-32 diverged from the model");
    assert_eq!(q1_rows[0], q1_rows[1], "{label}: push vs default top-32");
    assert_eq!(q1_rows[0], q1_rows[2], "{label}: client vs default top-32");

    // q2 — range filter over the clustered column (pruning signal); the
    // count is exact, so it cross-checks the model directly.
    let q2 = Query::scan("cb")
        .filter(Predicate::cmp("val", CmpOp::Lt, 35.0))
        .aggregate(AggFunc::Count, "val");
    let Column::F32(vals) = reference.col("val").unwrap() else {
        unreachable!()
    };
    let want = vals.iter().filter(|&&v| (v as f64) < 35.0).count() as f64;
    for mode in modes {
        stack.driver.reset_time();
        let r = stack.driver.execute(&q2, mode).unwrap();
        if mode.is_none() {
            sim += r.stats.sim_seconds;
            bytes += r.stats.bytes_moved;
            pruned = r.stats.objects_pruned;
        }
        assert_eq!(
            r.aggregates[0], want,
            "{label}: range count diverged from the model ({mode:?})"
        );
    }

    // q3 — full-scan aggregate: count cross-checks the model, mean must
    // agree bit for bit across the three modes (same partials, same
    // merge order — the offload-transparency invariant).
    let q3 = Query::scan("cb")
        .aggregate(AggFunc::Count, "val")
        .aggregate(AggFunc::Mean, "val");
    let mut means = Vec::new();
    for mode in modes {
        stack.driver.reset_time();
        let r = stack.driver.execute(&q3, mode).unwrap();
        if mode.is_none() {
            sim += r.stats.sim_seconds;
            bytes += r.stats.bytes_moved;
        }
        assert_eq!(
            r.aggregates[0],
            reference.nrows() as f64,
            "{label}: live count diverged ({mode:?})"
        );
        means.push(r.aggregates[1]);
    }
    assert!(
        means[0].to_bits() == means[1].to_bits() && means[0].to_bits() == means[2].to_bits(),
        "{label}: mean diverged across modes: {means:?}"
    );

    StageCost {
        sim,
        bytes,
        prefix_reads,
        pruned,
    }
}

fn main() {
    // The stages below assert on *unforced* trigger behavior; a leaked
    // SKYHOOK_FORCE_COMPACT=1 would compact away the churn mid-stage.
    std::env::remove_var("SKYHOOK_FORCE_COMPACT");

    let cfg = Config::from_text("[cluster]\nosds = 4\nreplicas = 1\n").unwrap();
    let stack = Stack::build(&cfg).unwrap();
    let rows = 120_000usize;
    let slab = 8_000usize;
    let nslabs = 3usize;
    let base = gen::sensor_table(rows, 33);
    stack
        .driver
        .write_table(
            "cb",
            &base,
            Layout::Col,
            &PartitionSpec::with_target(256 * 1024).cluster_by("val"),
            None,
        )
        .unwrap();
    let mut reference = base;

    // ---- stage 0: pre-churn baseline ------------------------------------
    let c0 = run_stage(&stack, &reference, "baseline");

    // ---- stage 1: churn -------------------------------------------------
    // Appends and deletes of equal volume: the live row count is back at
    // 120k, but 24k dead rows ride along under delete vectors and the
    // three appended slabs are unsorted on val (the clustering claim is
    // gone). Deletes stay under the auto-compaction threshold so the
    // degradation is actually measurable.
    for j in 0..nslabs {
        let mut extra = gen::sensor_table(slab, 100 + j as u64);
        let Column::I64(ts) = &mut extra.columns[0] else {
            unreachable!()
        };
        for t in ts.iter_mut() {
            *t += (rows + j * slab) as i64;
        }
        stack.driver.append("cb", &extra, 256 * 1024).unwrap();
        reference.concat(&extra).unwrap();
    }
    let mut to_kill = nslabs * slab;
    let mut dead: HashSet<i64> = HashSet::new();
    let (meta, _) = metadata::load_meta(&stack.cluster, 0.0, "cb").unwrap();
    let names = meta.object_names("cb");
    for (oi, name) in names.iter().enumerate() {
        if to_kill == 0 {
            break;
        }
        let raw = stack.cluster.read_object(0.0, name).unwrap().value;
        let (ob, _) = decode_batch(&raw).unwrap();
        let k = ob.nrows().min(to_kill);
        let ids: Vec<u32> = (0..k as u32).collect();
        stack.driver.delete_rows("cb", oi, &ids).unwrap();
        let Column::I64(ots) = &ob.columns[0] else {
            unreachable!()
        };
        dead.extend(ots[..k].iter().copied());
        to_kill -= k;
    }
    let Column::I64(rts) = &reference.columns[0] else {
        unreachable!()
    };
    let keep: Vec<bool> = rts.iter().map(|t| !dead.contains(t)).collect();
    reference = reference.filter(&keep).unwrap();
    assert_eq!(reference.nrows(), rows, "appends and deletes must balance");
    let c1 = run_stage(&stack, &reference, "churned");

    // ---- stage 2: compaction --------------------------------------------
    let rep = stack.driver.compact("cb").unwrap();
    assert!(rep.objects > 0);
    let (meta, _) = metadata::load_meta(&stack.cluster, 0.0, "cb").unwrap();
    let muta = meta.mutability().unwrap();
    assert!(muta.generation > 0 && muta.tombstones.is_empty());
    assert_eq!(meta.cluster_column(), Some("val"), "claim restored");
    let c2 = run_stage(&stack, &reference, "compacted");

    table(
        "E4f: churn-then-compact — fixed clustered workload (top-32 + range + full agg)",
        &["stage", "sim seconds", "bytes moved", "prefix reads", "pruned"],
        &[
            vec![
                "baseline".into(),
                format!("{:.4}", c0.sim),
                fmt_size(c0.bytes),
                c0.prefix_reads.to_string(),
                c0.pruned.to_string(),
            ],
            vec![
                "churned".into(),
                format!("{:.4}", c1.sim),
                fmt_size(c1.bytes),
                c1.prefix_reads.to_string(),
                c1.pruned.to_string(),
            ],
            vec![
                "compacted".into(),
                format!("{:.4}", c2.sim),
                fmt_size(c2.bytes),
                c2.prefix_reads.to_string(),
                c2.pruned.to_string(),
            ],
        ],
    );

    // The lifecycle asserts. Churn must cost strictly more than the
    // baseline; compaction must return to within 10% of it.
    assert!(
        c1.sim > c0.sim,
        "churn must strictly degrade cost: {:.4} vs {:.4}",
        c1.sim,
        c0.sim
    );
    assert!(
        c2.sim <= 1.10 * c0.sim,
        "compaction must return within 10% of baseline: {:.4} vs {:.4}",
        c2.sim,
        c0.sim
    );

    println!("\ne4f_churn OK");
}
