//! E7 — §3.1: data partitioning and locality.
//!
//! "If data is partitioned so that all input data for a common operation
//! is on one server, that operation can be executed on that server
//! without the need to transfer data. This is particularly important for
//! holistic functions such as the median."
//!
//! Compares a per-sensor median with (a) scattered row groups (default
//! hash placement) vs (b) sensor-co-located row groups (locality keys →
//! shared PG). With co-location, the holistic values all come from one
//! OSD's objects, and placement is provably aligned; scattered placement
//! touches every OSD. Also verifies placement co-residency directly.
//!
//! Run: `cargo bench --bench e7_locality`

use skyhook_map::config::Config;
use skyhook_map::dataset::metadata;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::{gen, Batch, Column};
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

/// Sort rows by sensor so row groups align with sensors (pre-partitioning
/// by logical unit, as §5 bullet 1.3 asks).
fn sort_by_sensor(batch: &Batch) -> Batch {
    let sensors = match batch.col("sensor").unwrap() {
        Column::I64(v) => v.clone(),
        _ => unreachable!(),
    };
    let mut idx: Vec<usize> = (0..batch.nrows()).collect();
    idx.sort_by_key(|&i| sensors[i]);
    let mut mask_order = Batch::empty(&batch.schema);
    for &i in &idx {
        for (dst, src) in mask_order.columns.iter_mut().zip(&batch.columns) {
            dst.push_from(src, i).unwrap();
        }
    }
    mask_order
}

fn main() {
    let rows = 200_000;
    let raw = gen::sensor_table(rows, 31);

    let mut out = Vec::new();
    let mut placements = Vec::new();
    for (label, colocate) in [("scattered (hash)", false), ("co-located (locality)", true)] {
        let cfg = Config::from_text(
            "[cluster]\nosds = 8\nreplicas = 1\n[driver]\nworkers = 8\n",
        )
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let batch = if colocate { sort_by_sensor(&raw) } else { raw.clone() };
        // Locality key: the dominant sensor of each row group.
        let loc_fn = |_: usize, g: &Batch| -> String {
            let sensors = match g.col("sensor").unwrap() {
                Column::I64(v) => v,
                _ => unreachable!(),
            };
            format!("sensor{}", sensors[sensors.len() / 2])
        };
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(64 * 1024),
                colocate.then_some(&loc_fn as &dyn Fn(usize, &Batch) -> String),
            )
            .unwrap();

        // Holistic median of one hot sensor's values.
        let q = Query::scan("t")
            .filter(Predicate::cmp("sensor", CmpOp::Eq, 0.0))
            .aggregate(AggFunc::Median, "val");
        stack.driver.reset_time();
        let r = stack.driver.execute(&q, None).unwrap();

        // How many distinct OSDs hold sensor-0 data?
        let (meta, _) = metadata::load_meta(&stack.cluster, 0.0, "t").unwrap();
        let names = meta.object_names("t");
        let mut osds: Vec<_> = names
            .iter()
            .filter(|n| !colocate || n.starts_with("sensor0#"))
            .map(|n| stack.cluster.placement(n)[0])
            .collect();
        osds.sort_unstable();
        osds.dedup();
        placements.push(osds.len());

        out.push(vec![
            label.to_string(),
            format!("{:.4}", r.aggregates[0]),
            fmt_size(r.stats.bytes_moved),
            format!("{:.4}", r.stats.sim_seconds),
            osds.len().to_string(),
        ]);
    }
    table(
        "E7: median(val) of sensor 0 — scattered vs co-located partitioning",
        &["partitioning", "median", "bytes moved", "sim s", "OSDs holding data"],
        &out,
    );
    assert!(
        placements[1] < placements[0],
        "co-location must concentrate placement: {placements:?}"
    );
    println!(
        "\nco-location puts all of a sensor's row groups in one placement group\n\
         (object-locator semantics), so the holistic operation's inputs live\n\
         on {} OSD(s) instead of {} — the §3.1 argument.",
        placements[1], placements[0]
    );
    println!("\ne7_locality OK");
}
