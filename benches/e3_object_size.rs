//! E3 — §5 bullet 1: mapping datasets to objects of proper sizes.
//!
//! Sweeps the partitioner's target object size and measures, per size:
//! write makespan, full-scan aggregate makespan, a point-lookup makespan,
//! object count (metadata overhead proxy), and load balance across OSDs.
//! Expected shape: a U-curve — tiny objects pay per-request overhead and
//! metadata; huge objects lose parallelism and load balance.
//!
//! Run: `cargo bench --bench e3_object_size`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let rows = 400_000;
    let batch = gen::sensor_table(rows, 5);
    let sizes: &[u64] = &[
        8 << 10,
        32 << 10,
        128 << 10,
        512 << 10,
        2 << 20,
        8 << 20,
    ];

    let mut out = Vec::new();
    for &target in sizes {
        let cfg =
            Config::from_text("[cluster]\nosds = 8\nreplicas = 1\n[driver]\nworkers = 8\n")
                .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let rep = stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(target),
                None,
            )
            .unwrap();

        // Full-scan aggregate.
        stack.driver.reset_time();
        let scan = stack
            .driver
            .execute(
                &Query::scan("t").aggregate(AggFunc::Mean, "val"),
                None,
            )
            .unwrap();

        // Narrow query (selective filter on the sorted ts column): small
        // objects pay per-object op overhead but let zone-map pruning
        // drop nearly everything — the pruned/unpruned gap is the win.
        let narrow_q = Query::scan("t")
            .filter(Predicate::cmp("ts", CmpOp::Lt, 1000.0))
            .select(&["val"]);
        stack.driver.reset_time();
        let narrow = stack.driver.execute(&narrow_q, None).unwrap();
        stack.driver.reset_time();
        let narrow_unpruned = stack.driver.execute_opts(&narrow_q, None, false).unwrap();
        assert_eq!(narrow.rows, narrow_unpruned.rows, "pruning changed results");

        // Load balance: stddev/mean of per-OSD object counts.
        let dist = stack.cluster.object_distribution();
        let counts: Vec<f64> = dist.iter().map(|(_, n)| *n as f64).collect();
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var =
            counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / counts.len() as f64;
        let imbalance = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };

        out.push(vec![
            fmt_size(target),
            rep.objects.to_string(),
            format!("{:.3}", rep.sim_seconds),
            format!("{:.4}", scan.stats.sim_seconds),
            format!("{:.4}", narrow.stats.sim_seconds),
            format!("{:.4}", narrow_unpruned.stats.sim_seconds),
            narrow.stats.objects_pruned.to_string(),
            format!("{:.2}", imbalance),
        ]);
    }
    table(
        "E3: object-size sweep (400k rows, 8 OSDs)",
        &[
            "target",
            "objects",
            "write sim s",
            "scan sim s",
            "narrow sim s",
            "narrow unpruned s",
            "pruned objs",
            "imbalance",
        ],
        &out,
    );
    println!(
        "\nexpected shape: write/scan cost is U-shaped — per-object overhead dominates at the\n\
         small end, lost parallelism + imbalance at the large end. The knee is the 'proper size'."
    );

    // ---- E3b: header-prefix sweep (partial-read follow-up) --------------
    // The `cluster.header_prefix` knob trades blind over-fetch (a big
    // prefix reads bytes a narrow projection never needed) against extra
    // ranged-read round trips (a small prefix pays another request per
    // column run). Sweep it at a fixed 512 KiB object size with a
    // projected client-side scan and record the wire bytes.
    // Note: exactly 64 KiB — the config default — is the planner's
    // "knob untouched" sentinel and gets auto-tuned down to the schema's
    // real header size, so the sweep uses 32 KiB for its mid point to
    // keep every value an explicit override.
    let mut prefix_out = Vec::new();
    let mut moved = Vec::new();
    let mut first_rows: Option<usize> = None;
    for prefix in ["4KiB", "16KiB", "32KiB", "256KiB", "1MiB"] {
        let cfg = Config::from_text(&format!(
            "[cluster]\nosds = 8\nreplicas = 1\nheader_prefix = \"{prefix}\"\n[driver]\nworkers = 8\n"
        ))
        .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(512 << 10),
                None,
            )
            .unwrap();
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 80.0))
            .select(&["ts"]);
        stack.driver.reset_time();
        let r = stack
            .driver
            .execute(&q, Some(skyhook_map::skyhook::ExecMode::ClientSide))
            .unwrap();
        let rows = r.rows.as_ref().map(|b| b.nrows()).unwrap_or(0);
        match first_rows {
            None => first_rows = Some(rows),
            Some(n) => assert_eq!(n, rows, "prefix size must not change results"),
        }
        moved.push(r.stats.bytes_moved);
        prefix_out.push(vec![
            prefix.to_string(),
            fmt_size(r.stats.bytes_moved),
            r.stats.reads_coalesced.to_string(),
            format!("{:.4}", r.stats.sim_seconds),
        ]);
    }
    table(
        "E3b: header-prefix sweep (512KiB objects, client-side projected scan)",
        &["header_prefix", "moved", "reads coalesced", "sim s"],
        &prefix_out,
    );
    // For a narrow projection over large objects, a bigger prefix can
    // only add blind over-fetch: wire bytes are monotonically
    // non-decreasing in the knob, and the smallest prefix moves strictly
    // less than the object-covering one.
    assert!(
        moved.windows(2).all(|w| w[0] <= w[1]),
        "over-fetch must grow with the prefix: {moved:?}"
    );
    assert!(
        moved[0] < *moved.last().unwrap(),
        "4KiB prefix must beat an object-covering prefix: {moved:?}"
    );
    println!("\ne3_object_size OK");
}
