//! E3 — §5 bullet 1: mapping datasets to objects of proper sizes.
//!
//! Sweeps the partitioner's target object size and measures, per size:
//! write makespan, full-scan aggregate makespan, a point-lookup makespan,
//! object count (metadata overhead proxy), and load balance across OSDs.
//! Expected shape: a U-curve — tiny objects pay per-request overhead and
//! metadata; huge objects lose parallelism and load balance.
//!
//! Run: `cargo bench --bench e3_object_size`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let rows = 400_000;
    let batch = gen::sensor_table(rows, 5);
    let sizes: &[u64] = &[
        8 << 10,
        32 << 10,
        128 << 10,
        512 << 10,
        2 << 20,
        8 << 20,
    ];

    let mut out = Vec::new();
    for &target in sizes {
        let cfg =
            Config::from_text("[cluster]\nosds = 8\nreplicas = 1\n[driver]\nworkers = 8\n")
                .unwrap();
        let stack = Stack::build(&cfg).unwrap();
        let rep = stack
            .driver
            .write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(target),
                None,
            )
            .unwrap();

        // Full-scan aggregate.
        stack.driver.reset_time();
        let scan = stack
            .driver
            .execute(
                &Query::scan("t").aggregate(AggFunc::Mean, "val"),
                None,
            )
            .unwrap();

        // Narrow query (selective filter on the sorted ts column): small
        // objects pay per-object op overhead but let zone-map pruning
        // drop nearly everything — the pruned/unpruned gap is the win.
        let narrow_q = Query::scan("t")
            .filter(Predicate::cmp("ts", CmpOp::Lt, 1000.0))
            .select(&["val"]);
        stack.driver.reset_time();
        let narrow = stack.driver.execute(&narrow_q, None).unwrap();
        stack.driver.reset_time();
        let narrow_unpruned = stack.driver.execute_opts(&narrow_q, None, false).unwrap();
        assert_eq!(narrow.rows, narrow_unpruned.rows, "pruning changed results");

        // Load balance: stddev/mean of per-OSD object counts.
        let dist = stack.cluster.object_distribution();
        let counts: Vec<f64> = dist.iter().map(|(_, n)| *n as f64).collect();
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        let var =
            counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / counts.len() as f64;
        let imbalance = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };

        out.push(vec![
            fmt_size(target),
            rep.objects.to_string(),
            format!("{:.3}", rep.sim_seconds),
            format!("{:.4}", scan.stats.sim_seconds),
            format!("{:.4}", narrow.stats.sim_seconds),
            format!("{:.4}", narrow_unpruned.stats.sim_seconds),
            narrow.stats.objects_pruned.to_string(),
            format!("{:.2}", imbalance),
        ]);
    }
    table(
        "E3: object-size sweep (400k rows, 8 OSDs)",
        &[
            "target",
            "objects",
            "write sim s",
            "scan sim s",
            "narrow sim s",
            "narrow unpruned s",
            "pruned objs",
            "imbalance",
        ],
        &out,
    );
    println!(
        "\nexpected shape: write/scan cost is U-shaped — per-object overhead dominates at the\n\
         small end, lost parallelism + imbalance at the large end. The knee is the 'proper size'."
    );
    println!("\ne3_object_size OK");
}
