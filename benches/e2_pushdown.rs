//! E2 — Figure 4 workflow: pushdown vs client-side query execution.
//!
//! Sweeps predicate selectivity and measures (a) bytes crossing the
//! client↔storage network, (b) simulated latency, (c) wall time, for
//! aggregate and row queries. Expected shape: pushdown moves
//! ~selectivity-proportional bytes for row queries and O(#objects)
//! constant-size partials for algebraic aggregates; client-side always
//! moves the whole dataset.
//!
//! E2c sweeps zone-map pruning on the clustered `ts` column: the planner
//! drops provably-dead sub-queries before any I/O, so at low selectivity
//! both bytes moved and objects decoded collapse while results stay
//! bit-identical to the unpruned execution.
//!
//! Run: `cargo bench --bench e2_pushdown`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let cfg = Config::from_text(
        "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
    )
    .unwrap();
    let stack = Stack::build(&cfg).unwrap();
    let rows = 300_000;
    let batch = gen::sensor_table(rows, 7);
    stack
        .driver
        .write_table(
            "t",
            &batch,
            Layout::Col,
            &PartitionSpec::with_target(256 * 1024),
            None,
        )
        .unwrap();

    // val ~ N(50,15): thresholds giving ~selectivity fractions.
    let cases = [
        ("~0.1%", 96.0),
        ("~2%", 81.0),
        ("~16%", 65.0),
        ("~50%", 50.0),
        ("100%", -1e9),
    ];

    // Aggregate queries.
    let mut agg_rows = Vec::new();
    for (label, thr) in cases {
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, thr))
            .aggregate(AggFunc::Mean, "val")
            .aggregate(AggFunc::Count, "val");
        stack.driver.reset_time();
        let push = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let client = stack.driver.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert!((push.aggregates[1] - client.aggregates[1]).abs() < 0.5);
        agg_rows.push(vec![
            label.to_string(),
            format!("{:.0}", push.aggregates[1]),
            fmt_size(push.stats.bytes_moved),
            fmt_size(client.stats.bytes_moved),
            format!("{:.4}", push.stats.sim_seconds),
            format!("{:.4}", client.stats.sim_seconds),
            format!(
                "{:.1}x",
                client.stats.sim_seconds / push.stats.sim_seconds
            ),
        ]);
    }
    table(
        "E2a: aggregate mean(val) where val>thr — pushdown vs client-side",
        &[
            "selectivity",
            "matches",
            "push bytes",
            "client bytes",
            "push sim s",
            "client sim s",
            "speedup",
        ],
        &agg_rows,
    );

    // Row queries (results must come back, so pushdown advantage shrinks
    // as selectivity grows — the crossover the planner cares about).
    let mut row_rows = Vec::new();
    for (label, thr) in cases {
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, thr))
            .select(&["ts", "val"]);
        stack.driver.reset_time();
        let push = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let client = stack.driver.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert_eq!(
            push.rows.as_ref().unwrap().nrows(),
            client.rows.as_ref().unwrap().nrows()
        );
        row_rows.push(vec![
            label.to_string(),
            push.rows.as_ref().unwrap().nrows().to_string(),
            fmt_size(push.stats.bytes_moved),
            fmt_size(client.stats.bytes_moved),
            format!("{:.4}", push.stats.sim_seconds),
            format!("{:.4}", client.stats.sim_seconds),
        ]);
    }
    table(
        "E2b: row retrieval select ts,val where val>thr",
        &[
            "selectivity",
            "rows",
            "push bytes",
            "client bytes",
            "push sim s",
            "client sim s",
        ],
        &row_rows,
    );

    // E2c: zone-map pruning on the clustered ts column. `ts` is sorted,
    // so each row-group object covers a disjoint [min, max] range and a
    // range predicate prunes all but ~selectivity of the objects.
    let mut prune_rows = Vec::new();
    for (label, sel) in [
        ("0.1%", 0.001),
        ("1%", 0.01),
        ("10%", 0.1),
        ("100%", 1.0),
    ] {
        let thr = rows as f64 * sel;
        let q = Query::scan("t")
            .filter(Predicate::cmp("ts", CmpOp::Lt, thr))
            .aggregate(AggFunc::Sum, "val")
            .aggregate(AggFunc::Count, "val");
        stack.driver.reset_time();
        let pruned = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let unpruned = stack
            .driver
            .execute_opts(&q, Some(ExecMode::Pushdown), false)
            .unwrap();
        stack.driver.reset_time();
        let client = stack
            .driver
            .execute_opts(&q, Some(ExecMode::ClientSide), false)
            .unwrap();
        // Pruning must be invisible in results.
        assert_eq!(pruned.aggregates, unpruned.aggregates);
        if sel < 0.02 {
            // The acceptance bar: at ~1% selectivity the pruned path
            // moves ≥5x fewer bytes and decodes ≥5x fewer objects than
            // both unpruned executions, with pruning actually engaged.
            assert!(pruned.stats.objects_pruned > 0, "nothing pruned");
            assert!(
                pruned.stats.bytes_moved * 5 <= unpruned.stats.bytes_moved,
                "bytes: pruned {} vs unpruned {}",
                pruned.stats.bytes_moved,
                unpruned.stats.bytes_moved
            );
            assert!(
                pruned.stats.bytes_moved * 5 <= client.stats.bytes_moved,
                "bytes: pruned {} vs client {}",
                pruned.stats.bytes_moved,
                client.stats.bytes_moved
            );
            assert!(
                pruned.stats.objects * 5 <= unpruned.stats.objects,
                "objects: pruned {} vs unpruned {}",
                pruned.stats.objects,
                unpruned.stats.objects
            );
        }
        // Row results are bit-identical under pruning.
        let rq = Query::scan("t")
            .filter(Predicate::cmp("ts", CmpOp::Lt, thr))
            .select(&["ts", "val"]);
        stack.driver.reset_time();
        let rp = stack.driver.execute(&rq, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let ru = stack
            .driver
            .execute_opts(&rq, Some(ExecMode::Pushdown), false)
            .unwrap();
        assert_eq!(rp.rows, ru.rows, "pruned rows differ at {label}");
        prune_rows.push(vec![
            label.to_string(),
            format!(
                "{}/{}",
                pruned.stats.objects,
                pruned.stats.objects + pruned.stats.objects_pruned
            ),
            fmt_size(pruned.stats.bytes_moved),
            fmt_size(unpruned.stats.bytes_moved),
            fmt_size(pruned.stats.bytes_skipped),
            format!("{:.4}", pruned.stats.sim_seconds),
            format!("{:.4}", unpruned.stats.sim_seconds),
        ]);
    }
    table(
        "E2c: zone-map pruning, sum/count(val) where ts < sel*rows (pushdown)",
        &[
            "selectivity",
            "objs scanned",
            "pruned bytes",
            "unpruned bytes",
            "bytes skipped",
            "pruned sim s",
            "unpruned sim s",
        ],
        &prune_rows,
    );

    // ---- E2d: compiled-kernel vs scalar pushdown (tier ablation) --------
    // Two identical clusters, one with the compiled execution tier
    // enabled in its cost profile (what `Stack::build` does when the
    // PJRT engine loads). Eligible filter+aggregate plans must get
    // strictly cheaper simulated pushdown on the compiled tier — the
    // chunked pass replaces the scalar per-row/per-value rates — while
    // answers stay bit-identical.
    {
        use skyhook_map::config::{ClusterConfig, DriverConfig};
        use skyhook_map::skyhook::{register_skyhook_class, scalar_forced, Driver};
        use skyhook_map::store::{ClassRegistry, Cluster};

        let tier_driver = |compiled: bool| {
            let mut reg = ClassRegistry::with_builtins();
            register_skyhook_class(&mut reg, None);
            let ccfg = ClusterConfig {
                osds: 6,
                replicas: 1,
                ..Default::default()
            };
            let mut cost = ccfg.profile.params();
            if compiled {
                cost.exec = cost.exec.with_compiled_tier();
            }
            let d = Driver::new(
                Cluster::with_cost(&ccfg, reg, cost),
                DriverConfig {
                    workers: 6,
                    ..Default::default()
                },
            );
            d.write_table(
                "t",
                &batch,
                Layout::Col,
                &PartitionSpec::with_target(256 * 1024),
                None,
            )
            .unwrap();
            d
        };
        let scalar = tier_driver(false);
        let compiled = tier_driver(true);
        let mut tier_rows = Vec::new();
        for (label, thr) in cases {
            let q = Query::scan("t")
                .filter(Predicate::cmp("val", CmpOp::Gt, thr))
                .aggregate(AggFunc::Mean, "val")
                .aggregate(AggFunc::Count, "val");
            scalar.reset_time();
            let rs = scalar.execute(&q, Some(ExecMode::Pushdown)).unwrap();
            compiled.reset_time();
            let rc = compiled.execute(&q, Some(ExecMode::Pushdown)).unwrap();
            // The tier is invisible in the answer, to the bit.
            for (a, b) in rc.aggregates.iter().zip(&rs.aggregates) {
                assert_eq!(a.to_bits(), b.to_bits(), "tier changed the answer: {a} vs {b}");
            }
            if !scalar_forced() {
                assert!(
                    rc.stats.compiled_chunks > 0,
                    "{label}: compiled tier never engaged"
                );
                assert!(
                    rc.stats.sim_seconds < rs.stats.sim_seconds,
                    "{label}: compiled pushdown must be strictly cheaper \
                     ({} vs scalar {})",
                    rc.stats.sim_seconds,
                    rs.stats.sim_seconds
                );
            }
            tier_rows.push(vec![
                label.to_string(),
                format!("{:.4}", rs.stats.sim_seconds),
                format!("{:.4}", rc.stats.sim_seconds),
                format!("{:.1}x", rs.stats.sim_seconds / rc.stats.sim_seconds),
                rc.stats.compiled_chunks.to_string(),
                rc.stats.compiled_rows.to_string(),
            ]);
        }
        table(
            "E2d: mean/count(val) where val>thr, forced pushdown — scalar vs compiled tier",
            &[
                "selectivity",
                "scalar sim s",
                "compiled sim s",
                "speedup",
                "chunks",
                "rows compiled",
            ],
            &tier_rows,
        );
        if scalar_forced() {
            println!("(SKYHOOK_FORCE_SCALAR set: tier asserts skipped, both columns scalar)");
        }
    }

    println!("\ne2_pushdown OK");
}
