//! E2 — Figure 4 workflow: pushdown vs client-side query execution.
//!
//! Sweeps predicate selectivity and measures (a) bytes crossing the
//! client↔storage network, (b) simulated latency, (c) wall time, for
//! aggregate and row queries. Expected shape: pushdown moves
//! ~selectivity-proportional bytes for row queries and O(#objects)
//! constant-size partials for algebraic aggregates; client-side always
//! moves the whole dataset.
//!
//! Run: `cargo bench --bench e2_pushdown`

use skyhook_map::config::Config;
use skyhook_map::dataset::partition::PartitionSpec;
use skyhook_map::dataset::table::gen;
use skyhook_map::dataset::Layout;
use skyhook_map::launch::Stack;
use skyhook_map::skyhook::{AggFunc, CmpOp, ExecMode, Predicate, Query};
use skyhook_map::util::bench::table;
use skyhook_map::util::bytes::fmt_size;

fn main() {
    let cfg = Config::from_text(
        "[cluster]\nosds = 6\nreplicas = 1\n[driver]\nworkers = 6\n",
    )
    .unwrap();
    let stack = Stack::build(&cfg).unwrap();
    let rows = 300_000;
    let batch = gen::sensor_table(rows, 7);
    stack
        .driver
        .write_table(
            "t",
            &batch,
            Layout::Col,
            &PartitionSpec::with_target(256 * 1024),
            None,
        )
        .unwrap();

    // val ~ N(50,15): thresholds giving ~selectivity fractions.
    let cases = [
        ("~0.1%", 96.0),
        ("~2%", 81.0),
        ("~16%", 65.0),
        ("~50%", 50.0),
        ("100%", -1e9),
    ];

    // Aggregate queries.
    let mut agg_rows = Vec::new();
    for (label, thr) in cases {
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, thr))
            .aggregate(AggFunc::Mean, "val")
            .aggregate(AggFunc::Count, "val");
        stack.driver.reset_time();
        let push = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let client = stack.driver.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert!((push.aggregates[1] - client.aggregates[1]).abs() < 0.5);
        agg_rows.push(vec![
            label.to_string(),
            format!("{:.0}", push.aggregates[1]),
            fmt_size(push.stats.bytes_moved),
            fmt_size(client.stats.bytes_moved),
            format!("{:.4}", push.stats.sim_seconds),
            format!("{:.4}", client.stats.sim_seconds),
            format!(
                "{:.1}x",
                client.stats.sim_seconds / push.stats.sim_seconds
            ),
        ]);
    }
    table(
        "E2a: aggregate mean(val) where val>thr — pushdown vs client-side",
        &[
            "selectivity",
            "matches",
            "push bytes",
            "client bytes",
            "push sim s",
            "client sim s",
            "speedup",
        ],
        &agg_rows,
    );

    // Row queries (results must come back, so pushdown advantage shrinks
    // as selectivity grows — the crossover the planner cares about).
    let mut row_rows = Vec::new();
    for (label, thr) in cases {
        let q = Query::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, thr))
            .select(&["ts", "val"]);
        stack.driver.reset_time();
        let push = stack.driver.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        stack.driver.reset_time();
        let client = stack.driver.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert_eq!(
            push.rows.as_ref().unwrap().nrows(),
            client.rows.as_ref().unwrap().nrows()
        );
        row_rows.push(vec![
            label.to_string(),
            push.rows.as_ref().unwrap().nrows().to_string(),
            fmt_size(push.stats.bytes_moved),
            fmt_size(client.stats.bytes_moved),
            format!("{:.4}", push.stats.sim_seconds),
            format!("{:.4}", client.stats.sim_seconds),
        ]);
    }
    table(
        "E2b: row retrieval select ts,val where val>thr",
        &[
            "selectivity",
            "rows",
            "push bytes",
            "client bytes",
            "push sim s",
            "client sim s",
        ],
        &row_rows,
    );

    println!("\ne2_pushdown OK");
}
