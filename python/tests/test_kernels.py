"""Kernel-vs-reference correctness: the CORE L1 signal.

Each Pallas kernel is checked against its pure-jnp oracle in ref.py,
including hypothesis sweeps over value distributions, mask densities and
padding patterns.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import filter_agg, ref, stats, transform

ROWS = filter_agg.ROWS
COLS = stats.COLS

RNG = np.random.default_rng(0)


def pad_to_rows(values, mask):
    """Pad arbitrary-length inputs to the kernel's fixed ROWS."""
    n = len(values)
    assert n <= ROWS
    v = np.zeros(ROWS, np.float32)
    m = np.zeros(ROWS, np.float32)
    v[:n] = values
    m[:n] = mask
    return v, m


def assert_moments_close(got, want, *, empty_ok=True):
    got = np.asarray(got)
    want = np.asarray(want)
    assert got.shape == want.shape
    # count exact
    np.testing.assert_array_equal(got[..., 0], want[..., 0])
    # sums: tile-order accumulation differs from the reference's single
    # reduction, so allow float32-level tolerance.
    np.testing.assert_allclose(got[..., 1], want[..., 1], rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(got[..., 2], want[..., 2], rtol=2e-4, atol=1e-2)
    # min/max exact when the masked set is non-empty
    np.testing.assert_allclose(got[..., 3], want[..., 3], rtol=1e-6)
    np.testing.assert_allclose(got[..., 4], want[..., 4], rtol=1e-6)


class TestMaskedMoments:
    def test_dense_mask(self):
        v = RNG.normal(50, 15, ROWS).astype(np.float32)
        m = np.ones(ROWS, np.float32)
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        want = ref.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert_moments_close(got, want)
        # and against numpy directly
        assert float(got[0]) == ROWS
        np.testing.assert_allclose(float(got[1]), v.sum(), rtol=1e-5)
        assert float(got[3]) == v.min()
        assert float(got[4]) == v.max()

    def test_empty_mask(self):
        v = RNG.normal(0, 1, ROWS).astype(np.float32)
        m = np.zeros(ROWS, np.float32)
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert float(got[0]) == 0.0
        assert float(got[1]) == 0.0
        assert float(got[2]) == 0.0
        # min/max are sentinels; Rust checks count first.
        assert float(got[3]) >= 3e38
        assert float(got[4]) <= -3e38

    def test_single_element(self):
        v = np.zeros(ROWS, np.float32)
        m = np.zeros(ROWS, np.float32)
        v[7] = -3.5
        m[7] = 1.0
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert float(got[0]) == 1.0
        assert float(got[1]) == -3.5
        np.testing.assert_allclose(float(got[2]), 12.25, rtol=1e-6)
        assert float(got[3]) == -3.5
        assert float(got[4]) == -3.5

    def test_mask_in_last_tile_only(self):
        # Exercises cross-tile accumulation: data only in the final tile.
        v = np.zeros(ROWS, np.float32)
        m = np.zeros(ROWS, np.float32)
        v[-3:] = [1.0, 2.0, 3.0]
        m[-3:] = 1.0
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert float(got[0]) == 3.0
        assert float(got[1]) == 6.0
        assert float(got[3]) == 1.0
        assert float(got[4]) == 3.0

    def test_negative_values(self):
        v = -np.abs(RNG.normal(10, 3, ROWS)).astype(np.float32)
        m = (RNG.random(ROWS) < 0.5).astype(np.float32)
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        want = ref.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert_moments_close(got, want)
        assert float(got[4]) < 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=0, max_value=ROWS),
        density=st.floats(min_value=0.0, max_value=1.0),
        scale=st.floats(min_value=0.1, max_value=1000.0),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, n, density, scale, seed):
        rng = np.random.default_rng(seed)
        values = rng.normal(0, scale, n).astype(np.float32)
        mask = (rng.random(n) < density).astype(np.float32)
        v, m = pad_to_rows(values, mask)
        got = filter_agg.masked_moments(jnp.asarray(v), jnp.asarray(m))
        want = ref.masked_moments(jnp.asarray(v), jnp.asarray(m))
        assert_moments_close(got, want)


class TestMatrixMoments:
    def test_matches_reference(self):
        mat = RNG.normal(0, 10, (ROWS, COLS)).astype(np.float32)
        mask = (RNG.random(ROWS) < 0.3).astype(np.float32)
        got = stats.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        want = ref.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        assert got.shape == (COLS, 8)
        assert_moments_close(np.asarray(got), np.asarray(want))

    def test_each_column_independent(self):
        mat = np.zeros((ROWS, COLS), np.float32)
        for c in range(COLS):
            mat[:, c] = c + 1
        mask = np.ones(ROWS, np.float32)
        got = np.asarray(
            stats.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        )
        for c in range(COLS):
            assert got[c, 0] == ROWS
            np.testing.assert_allclose(got[c, 1], (c + 1) * ROWS, rtol=1e-6)
            assert got[c, 3] == c + 1
            assert got[c, 4] == c + 1

    def test_empty_mask_matrix(self):
        mat = RNG.normal(0, 1, (ROWS, COLS)).astype(np.float32)
        mask = np.zeros(ROWS, np.float32)
        got = np.asarray(
            stats.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        )
        np.testing.assert_array_equal(got[:, 0], 0.0)

    @settings(max_examples=10, deadline=None)
    @given(
        density=st.floats(min_value=0.0, max_value=1.0),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_matrix(self, density, seed):
        rng = np.random.default_rng(seed)
        mat = rng.normal(5, 100, (ROWS, COLS)).astype(np.float32)
        mask = (rng.random(ROWS) < density).astype(np.float32)
        got = stats.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        want = ref.matrix_masked_moments(jnp.asarray(mat), jnp.asarray(mask))
        assert_moments_close(np.asarray(got), np.asarray(want))


class TestTransform:
    def test_roundtrip(self):
        mat = RNG.normal(0, 1, (ROWS, COLS)).astype(np.float32)
        t = transform.row_to_col(jnp.asarray(mat))
        assert t.shape == (COLS, ROWS)
        np.testing.assert_array_equal(np.asarray(t), mat.T)
        back = transform.col_to_row(t)
        np.testing.assert_array_equal(np.asarray(back), mat)

    def test_matches_reference(self):
        mat = np.arange(ROWS * COLS, dtype=np.float32).reshape(ROWS, COLS)
        got = transform.row_to_col(jnp.asarray(mat))
        want = ref.transpose(jnp.asarray(mat))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestWrongShapes:
    def test_vector_kernel_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            filter_agg.masked_moments(
                jnp.zeros(ROWS + 1, jnp.float32), jnp.zeros(ROWS + 1, jnp.float32)
            )

    def test_matrix_kernel_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            stats.matrix_masked_moments(
                jnp.zeros((ROWS, COLS + 1), jnp.float32),
                jnp.zeros(ROWS, jnp.float32),
            )
