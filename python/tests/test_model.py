"""L2 model tests: the fused chunk pipeline vs the reference, plus AOT
lowering shape checks (the artifacts the Rust runtime will load)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

ROWS = model.ROWS
COLS = model.COLS

RNG = np.random.default_rng(1)


def onehot(c):
    v = np.zeros(COLS, np.float32)
    v[c] = 1.0
    return v


class TestChunkPipeline:
    def test_matches_reference(self):
        mat = RNG.normal(50, 15, (ROWS, COLS)).astype(np.float32)
        sel = onehot(2)
        thr = np.array([55.0], np.float32)
        valid = np.ones(ROWS, np.float32)
        (got,) = model.chunk_pipeline_entry(
            jnp.asarray(mat), jnp.asarray(sel), jnp.asarray(thr), jnp.asarray(valid)
        )
        want = ref.chunk_pipeline(
            jnp.asarray(mat), jnp.asarray(sel), jnp.asarray(thr), jnp.asarray(valid)
        )
        np.testing.assert_array_equal(
            np.asarray(got)[:, 0], np.asarray(want)[:, 0]
        )
        np.testing.assert_allclose(
            np.asarray(got)[:, 1:5], np.asarray(want)[:, 1:5], rtol=2e-4, atol=1e-2
        )

    def test_against_numpy_semantics(self):
        mat = RNG.normal(0, 10, (ROWS, COLS)).astype(np.float32)
        sel = onehot(0)
        thr = np.array([0.0], np.float32)
        valid = np.ones(ROWS, np.float32)
        (got,) = model.chunk_pipeline_entry(
            jnp.asarray(mat), jnp.asarray(sel), jnp.asarray(thr), jnp.asarray(valid)
        )
        got = np.asarray(got)
        keep = mat[:, 0] > 0.0
        assert got[0, 0] == keep.sum()
        np.testing.assert_allclose(got[1, 1], mat[keep, 1].sum(), rtol=1e-4)
        if keep.any():
            np.testing.assert_allclose(got[3, 3], mat[keep, 3].min(), rtol=1e-6)

    def test_padding_rows_excluded(self):
        mat = np.full((ROWS, COLS), 100.0, np.float32)
        valid = np.zeros(ROWS, np.float32)
        valid[:10] = 1.0
        (got,) = model.chunk_pipeline_entry(
            jnp.asarray(mat),
            jnp.asarray(onehot(0)),
            jnp.asarray(np.array([0.0], np.float32)),
            jnp.asarray(valid),
        )
        assert float(np.asarray(got)[0, 0]) == 10.0

    def test_no_rows_pass(self):
        mat = np.zeros((ROWS, COLS), np.float32)
        (got,) = model.chunk_pipeline_entry(
            jnp.asarray(mat),
            jnp.asarray(onehot(0)),
            jnp.asarray(np.array([1e9], np.float32)),
            jnp.asarray(np.ones(ROWS, np.float32)),
        )
        assert float(np.asarray(got)[0, 0]) == 0.0

    @settings(max_examples=10, deadline=None)
    @given(
        col=st.integers(min_value=0, max_value=COLS - 1),
        thr=st.floats(min_value=-50, max_value=50),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_pipeline(self, col, thr, seed):
        rng = np.random.default_rng(seed)
        mat = rng.normal(0, 30, (ROWS, COLS)).astype(np.float32)
        valid = (rng.random(ROWS) < 0.9).astype(np.float32)
        (got,) = model.chunk_pipeline_entry(
            jnp.asarray(mat),
            jnp.asarray(onehot(col)),
            jnp.asarray(np.array([thr], np.float32)),
            jnp.asarray(valid),
        )
        keep = (mat[:, col] > thr) & (valid > 0)
        got = np.asarray(got)
        assert got[0, 0] == keep.sum()
        for c in range(COLS):
            np.testing.assert_allclose(
                got[c, 1], mat[keep, c].sum(), rtol=3e-4, atol=2e-2
            )


class TestAotLowering:
    def test_all_entries_lower_to_hlo_text(self):
        for name, fn, example in aot.entries():
            lowered = jax.jit(fn).lower(*example)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name
            # No Mosaic custom-calls (interpret=True requirement).
            assert "tpu_custom_call" not in text, name

    def test_artifact_names_match_makefile(self):
        names = {n for n, _, _ in aot.entries()}
        assert names == {
            "filter_agg",
            "stats",
            "chunk_pipeline",
            "transform_r2c",
            "transform_c2r",
        }
