"""AOT lowering: JAX/Pallas entry points -> HLO *text* artifacts.

HLO text (NOT `lowered.compile()` or serialized protos) is the
interchange format: the image's xla_extension 0.5.1 rejects jax>=0.5's
64-bit-instruction-id protos, while the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). The Rust runtime
loads these with `HloModuleProto::from_text_file` and compiles them on
the PJRT CPU client.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries():
    """(name, fn, example args) for every artifact."""
    r = model.ROWS
    c = model.COLS
    f32 = jnp.float32
    vec = jax.ShapeDtypeStruct((r,), f32)
    mat = jax.ShapeDtypeStruct((r, c), f32)
    matT = jax.ShapeDtypeStruct((c, r), f32)
    sel = jax.ShapeDtypeStruct((c,), f32)
    thr = jax.ShapeDtypeStruct((1,), f32)
    return [
        ("filter_agg", model.masked_moments_entry, (vec, vec)),
        ("stats", model.matrix_moments_entry, (mat, vec)),
        ("chunk_pipeline", model.chunk_pipeline_entry, (mat, sel, thr, vec)),
        ("transform_r2c", model.row_to_col_entry, (mat,)),
        ("transform_c2r", model.col_to_row_entry, (matT,)),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, example in entries():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
