"""L1 Pallas kernel: per-column masked moments of a column chunk.

The matrix form of `filter_agg`: one (ROWS, COLS) f32 chunk + one shared
row mask -> (COLS, 8) per-column partials. Used by the fused L2 pipeline
so a whole multi-column chunk is aggregated in one kernel launch.

TPU mapping: grid over (row-tile, column); each step reduces a
(TILE, 1) strip against the (TILE,) mask slice and accumulates into the
revisiting (1, 8) output block. Working set per step = TILE*4 B values +
TILE*4 B mask — VMEM-resident with room for double buffering.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

ROWS = 16384
COLS = 8
TILE = 2048

GRID_R = ROWS // TILE


def _kernel(x_ref, m_ref, o_ref):
    i = pl.program_id(0)  # row tile
    x = x_ref[...]  # (TILE, 1)
    m = m_ref[...]  # (TILE,)
    xv = x[:, 0]
    cnt = jnp.sum(m)
    s = jnp.sum(xv * m)
    ss = jnp.sum(xv * xv * m)
    mn = jnp.min(jnp.where(m > 0, xv, ref.BIG))
    mx = jnp.max(jnp.where(m > 0, xv, -ref.BIG))
    zero = jnp.float32(0)
    part = jnp.stack([cnt, s, ss, mn, mx, zero, zero, zero])[None, :]  # (1, 8)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i > 0)
    def _accum():
        prev = o_ref[...]
        o_ref[...] = jnp.concatenate(
            [
                prev[:, 0:1] + part[:, 0:1],
                prev[:, 1:2] + part[:, 1:2],
                prev[:, 2:3] + part[:, 2:3],
                jnp.minimum(prev[:, 3:4], part[:, 3:4]),
                jnp.maximum(prev[:, 4:5], part[:, 4:5]),
                prev[:, 5:8],
            ],
            axis=1,
        )


@jax.jit
def matrix_masked_moments(matrix, mask):
    """(ROWS, COLS) f32 + (ROWS,) mask -> (COLS, 8) f32 partials."""
    assert matrix.shape == (ROWS, COLS), matrix.shape
    assert mask.shape == (ROWS,), mask.shape
    return pl.pallas_call(
        _kernel,
        grid=(GRID_R, COLS),
        in_specs=[
            pl.BlockSpec((TILE, 1), lambda i, c: (i, c)),
            pl.BlockSpec((TILE,), lambda i, c: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 8), lambda i, c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((COLS, 8), jnp.float32),
        interpret=True,
    )(matrix.astype(jnp.float32), mask.astype(jnp.float32))
