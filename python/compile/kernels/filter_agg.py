"""L1 Pallas kernel: masked moments of a value vector.

This is the compute hot-spot of the Skyhook-Extension's `agg` pushdown:
for one column chunk and one predicate mask, produce the constant-size
partial-aggregate state [count, sum, sumsq, min, max] that crosses the
network instead of the data.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  - fixed chunk of ROWS=16384 f32 values (64 KiB) + mask (64 KiB), tiled
    into TILE=2048-element blocks: each grid step's working set is
    2*8 KiB — trivially VMEM-resident, and the grid pipeline overlaps the
    HBM->VMEM DMA of tile i+1 with the reduction of tile i (the role
    threadblock double-buffering plays on GPU);
  - masked *reduction*, not compaction: output shape is fixed at (8,)
    (8*4 B, lane-aligned) regardless of selectivity, so there are no
    data-dependent shapes — the TPU rethink of row filtering;
  - accumulation across grid steps uses the revisiting output block
    (out index_map -> 0), the canonical Pallas reduction pattern.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated from the BlockSpec footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Fixed logical chunk length (padded by the caller; pad rows have mask 0).
ROWS = 16384
# Per-grid-step tile.
TILE = 2048

GRID = ROWS // TILE


def _kernel(x_ref, m_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    m = m_ref[...]
    cnt = jnp.sum(m)
    s = jnp.sum(x * m)
    ss = jnp.sum(x * x * m)
    mn = jnp.min(jnp.where(m > 0, x, ref.BIG))
    mx = jnp.max(jnp.where(m > 0, x, -ref.BIG))
    zero = jnp.float32(0)
    part = jnp.stack([cnt, s, ss, mn, mx, zero, zero, zero])

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i > 0)
    def _accum():
        prev = o_ref[...]
        o_ref[...] = jnp.stack(
            [
                prev[0] + part[0],
                prev[1] + part[1],
                prev[2] + part[2],
                jnp.minimum(prev[3], part[3]),
                jnp.maximum(prev[4], part[4]),
                zero,
                zero,
                zero,
            ]
        )


@functools.partial(jax.jit, static_argnames=())
def masked_moments(values, mask):
    """Pallas masked moments. values/mask: (ROWS,) f32 -> (8,) f32."""
    assert values.shape == (ROWS,), values.shape
    assert mask.shape == (ROWS,), mask.shape
    return pl.pallas_call(
        _kernel,
        grid=(GRID,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((8,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        interpret=True,
    )(values.astype(jnp.float32), mask.astype(jnp.float32))
