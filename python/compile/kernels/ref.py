"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and reference across shapes, masks and
value distributions (see python/tests/).
"""

import jax.numpy as jnp

# Sentinel returned for min/max of an empty masked set. The Rust caller
# checks count > 0 before trusting min/max, so any finite sentinel works;
# it keeps the kernel branch-free on TPU. A plain Python float: Pallas
# kernels may not close over traced array constants.
BIG = 3.4e38


def masked_moments(values, mask):
    """Moments of `values` where `mask` is set.

    Args:
      values: (R,) f32
      mask:   (R,) f32 of 0.0 / 1.0
    Returns:
      (8,) f32: [count, sum, sumsq, min, max, 0, 0, 0]
    """
    values = values.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    cnt = jnp.sum(mask)
    s = jnp.sum(values * mask)
    ss = jnp.sum(values * values * mask)
    mn = jnp.min(jnp.where(mask > 0, values, BIG))
    mx = jnp.max(jnp.where(mask > 0, values, -BIG))
    zero = jnp.float32(0)
    return jnp.stack([cnt, s, ss, mn, mx, zero, zero, zero])


def matrix_masked_moments(matrix, mask):
    """Per-column masked moments.

    Args:
      matrix: (R, C) f32
      mask:   (R,) f32 of 0.0 / 1.0
    Returns:
      (C, 8) f32, row c = masked_moments(matrix[:, c], mask)
    """
    matrix = matrix.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    m = mask[:, None]
    cnt_col = jnp.full((matrix.shape[1],), jnp.sum(mask), dtype=jnp.float32)
    s = jnp.sum(matrix * m, axis=0)
    ss = jnp.sum(matrix * matrix * m, axis=0)
    mn = jnp.min(jnp.where(m > 0, matrix, BIG), axis=0)
    mx = jnp.max(jnp.where(m > 0, matrix, -BIG), axis=0)
    zeros = jnp.zeros_like(s)
    return jnp.stack([cnt_col, s, ss, mn, mx, zeros, zeros, zeros], axis=1)


def transpose(matrix):
    """Row-major -> column-major transform (and back): plain transpose."""
    return matrix.T


def chunk_pipeline(matrix, colsel, threshold, valid):
    """The fused L2 reference: predicate -> mask -> per-column moments.

    Args:
      matrix:    (R, C) f32 column chunk
      colsel:    (C,)  f32 one-hot selecting the predicate column
      threshold: (1,)  f32 predicate threshold (op is `>`)
      valid:     (R,)  f32 row-validity mask (padding rows = 0)
    Returns:
      (C, 8) f32 per-column moments of rows where
      matrix[:, sel] > threshold and valid.
    """
    matrix = matrix.astype(jnp.float32)
    pred_col = matrix @ colsel.astype(jnp.float32)
    mask = (pred_col > threshold[0]).astype(jnp.float32) * valid.astype(jnp.float32)
    return matrix_masked_moments(matrix, mask)
