"""L1 Pallas kernel: tiled row<->column layout transform (transpose).

Physical design management (§5) re-organizes objects between row- and
column-oriented layouts on the storage server. For fixed-width numeric
chunks that is a (ROWS, COLS) transpose; this kernel does it in
(TILE, COLS) strips so each grid step's working set stays VMEM-sized,
writing (COLS, TILE) output tiles.

On a real TPU the in-VMEM transpose lowers to efficient vector shuffles;
lane-dim padding to 128 would be added by Mosaic. interpret=True here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 16384
COLS = 8
TILE = 2048

GRID = ROWS // TILE


def _kernel(x_ref, o_ref):
    # x: (TILE, COLS) strip -> o: (COLS, TILE) strip.
    o_ref[...] = x_ref[...].T


@jax.jit
def row_to_col(matrix):
    """(ROWS, COLS) f32 -> (COLS, ROWS) f32 transpose."""
    assert matrix.shape == (ROWS, COLS), matrix.shape
    return pl.pallas_call(
        _kernel,
        grid=(GRID,),
        in_specs=[pl.BlockSpec((TILE, COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((COLS, TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((COLS, ROWS), jnp.float32),
        interpret=True,
    )(matrix.astype(jnp.float32))


def _kernel_back(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@jax.jit
def col_to_row(matrix):
    """(COLS, ROWS) f32 -> (ROWS, COLS) f32 transpose."""
    assert matrix.shape == (COLS, ROWS), matrix.shape
    return pl.pallas_call(
        _kernel_back,
        grid=(GRID,),
        in_specs=[pl.BlockSpec((COLS, TILE), lambda i: (0, i))],
        out_specs=pl.BlockSpec((TILE, COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ROWS, COLS), jnp.float32),
        interpret=True,
    )(matrix.astype(jnp.float32))
