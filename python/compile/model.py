"""L2: the JAX compute graph for storage-side chunk processing.

Composes the L1 Pallas kernels into the fused graphs that get AOT-lowered
to HLO and executed by the Rust runtime inside Skyhook-Extension calls:

  - `masked_moments_entry`   — one column + mask -> (8,) partials
  - `matrix_moments_entry`   — (R, C) chunk + mask -> (C, 8) partials
  - `chunk_pipeline_entry`   — the fully fused pushdown: predicate
    evaluation (select column, compare against threshold), mask
    combination with row validity, then per-column masked moments — one
    HLO module, no host round-trips between filter and aggregate (the L2
    fusion target in DESIGN.md §Perf)
  - `row_to_col_entry` / `col_to_row_entry` — physical design transform

Everything here runs ONCE at build time (`make artifacts`); Python is
never on the request path.
"""

import jax.numpy as jnp

from .kernels import filter_agg, stats, transform

ROWS = filter_agg.ROWS
COLS = stats.COLS


def masked_moments_entry(values, mask):
    """(ROWS,) f32, (ROWS,) f32 -> (8,) f32 via the L1 kernel."""
    return (filter_agg.masked_moments(values, mask),)


def matrix_moments_entry(matrix, mask):
    """(ROWS, COLS) f32, (ROWS,) f32 -> (COLS, 8) f32 via the L1 kernel."""
    return (stats.matrix_masked_moments(matrix, mask),)


def chunk_pipeline_entry(matrix, colsel, threshold, valid):
    """Fused predicate + aggregate over one chunk.

    Args:
      matrix:    (ROWS, COLS) f32
      colsel:    (COLS,) f32 one-hot predicate column selector
      threshold: (1,) f32, predicate is `col > threshold`
      valid:     (ROWS,) f32 row-validity mask (padding rows = 0)
    Returns:
      ((COLS, 8) f32,) per-column masked moments
    """
    pred_col = matrix @ colsel  # (ROWS,)
    mask = (pred_col > threshold[0]).astype(jnp.float32) * valid
    return (stats.matrix_masked_moments(matrix, mask),)


def row_to_col_entry(matrix):
    """(ROWS, COLS) -> (COLS, ROWS) layout transform via the L1 kernel."""
    return (transform.row_to_col(matrix),)


def col_to_row_entry(matrix):
    """(COLS, ROWS) -> (ROWS, COLS) layout transform via the L1 kernel."""
    return (transform.col_to_row(matrix),)
