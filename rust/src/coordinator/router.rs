//! The front-door request router: the piece of L3 that a deployment would
//! put its clients behind. Wraps the Skyhook driver with admission
//! control (write credits on the ingest path, the global + per-tenant
//! [`QueryGate`] on the query path), per-request metrics, and a uniform
//! request/response surface used by the CLI `serve` loop and examples.
//!
//! The query path is safe to drive from many threads at once (the CLI
//! `serve --concurrency` loop and the serving-layer tests do): admission
//! bounds how many run, `router.queries_inflight` gauges how many are in
//! right now, and a query turned away by the gate surfaces as the typed
//! [`Error::Overloaded`](crate::Error::Overloaded) plus a
//! `router.queries_rejected` count — load shedding a client can see and
//! back off from, never an unbounded queue.

use super::backpressure::{CreditGate, QueryGate, QueryGateConfig};
use super::metrics::Metrics;
use crate::dataset::partition::PartitionSpec;
use crate::dataset::table::Batch;
use crate::dataset::Layout;
use crate::error::Result;
use crate::skyhook::{Driver, ExecMode, Query, QueryResult, WriteReport};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A routable request.
pub enum Request {
    /// Ingest a table as a new dataset.
    WriteTable {
        dataset: String,
        batch: Batch,
        layout: Layout,
        spec: PartitionSpec,
    },
    /// Run a query.
    Query {
        query: Query,
        force_mode: Option<ExecMode>,
        /// Admission accounting: queries with a tenant draw from that
        /// tenant's credit pool as well as the global one; `None` draws
        /// from the global pool only.
        tenant: Option<String>,
    },
    /// Build a secondary index.
    BuildIndex { dataset: String, column: String },
    /// Physical-design transform.
    Transform { dataset: String, target: Layout },
    /// Tombstone rows of one row-group object (object-local row ids).
    Delete {
        dataset: String,
        object_index: usize,
        rows: Vec<u32>,
    },
    /// Append rows to an existing dataset as new row groups.
    Append {
        dataset: String,
        batch: Batch,
        target_bytes: u64,
    },
    /// Re-clustering compaction (explicit; the threshold-triggered kind
    /// rides the Delete/Append paths automatically).
    Compact { dataset: String },
}

/// Response union.
pub enum Response {
    Write(WriteReport),
    Query(QueryResult),
    Index(u64),
    Transform(WriteReport),
    /// Tombstone count of the targeted object after the delete.
    Delete(u64),
    Compact(WriteReport),
}

/// The router.
pub struct Router {
    driver: Arc<Driver>,
    write_gate: CreditGate,
    query_gate: QueryGate,
    /// Queries currently executing (admitted, not yet returned). The
    /// `router.queries_inflight` gauge mirrors this on every transition.
    inflight: AtomicU64,
    pub metrics: Arc<Metrics>,
}

/// Keeps the in-flight count honest even when `Driver::execute` errors:
/// the decrement rides the unwind path, so a failed query never leaves
/// the gauge stuck above zero.
struct InflightScope<'a> {
    router: &'a Router,
}

impl Drop for InflightScope<'_> {
    fn drop(&mut self) {
        let now = self.router.inflight.fetch_sub(1, Ordering::SeqCst) - 1;
        self.router.metrics.set("router.queries_inflight", now);
    }
}

impl Router {
    pub fn new(driver: Arc<Driver>, write_credits: usize) -> Self {
        Self::with_gates(driver, write_credits, QueryGateConfig::default())
    }

    /// Construct with explicit query-admission sizing. `new` uses
    /// [`QueryGateConfig::default`], which is generous enough that
    /// single-threaded callers never notice the gate exists.
    pub fn with_gates(
        driver: Arc<Driver>,
        write_credits: usize,
        gate_cfg: QueryGateConfig,
    ) -> Self {
        Self {
            driver,
            write_gate: CreditGate::new(write_credits),
            query_gate: QueryGate::new(gate_cfg),
            inflight: AtomicU64::new(0),
            metrics: Arc::new(Metrics::new()),
        }
    }

    pub fn driver(&self) -> &Arc<Driver> {
        &self.driver
    }

    /// The query-admission gate (observability and tests: benches drain
    /// it to provoke deterministic `Overloaded` rejections).
    pub fn query_gate(&self) -> &QueryGate {
        &self.query_gate
    }

    /// Route one request, recording metrics.
    pub fn handle(&self, req: Request) -> Result<Response> {
        let start = Instant::now();
        let out = match req {
            Request::WriteTable {
                dataset,
                batch,
                layout,
                spec,
            } => {
                // Admission control on the ingest path.
                let _credit = self.write_gate.acquire(1);
                self.metrics.incr("router.writes", 1);
                self.metrics.incr("router.write_rows", batch.nrows() as u64);
                let rep = self
                    .driver
                    .write_table(&dataset, &batch, layout, &spec, None)?;
                self.metrics
                    .incr("router.write_bytes", rep.bytes_written);
                self.metrics
                    .observe("router.write_latency", start.elapsed().as_secs_f64());
                Response::Write(rep)
            }
            Request::Query {
                query,
                force_mode,
                tenant,
            } => {
                // Admission: bounded wait for a credit, then shed. The
                // credit pair (tenant + global) rides `_admission` and is
                // returned when this arm exits, success or error.
                let _admission = match self.query_gate.admit(tenant.as_deref()) {
                    Ok(a) => a,
                    Err(e) => {
                        self.metrics.incr("router.queries_rejected", 1);
                        return Err(e);
                    }
                };
                let now = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
                self.metrics.set("router.queries_inflight", now);
                let _scope = InflightScope { router: self };
                self.metrics.incr("router.queries", 1);
                let r = self.driver.execute(&query, force_mode)?;
                self.metrics.incr("router.query_bytes_moved", r.stats.bytes_moved);
                if r.stats.pushdown {
                    self.metrics.incr("router.pushdown_queries", 1);
                }
                self.metrics
                    .incr("router.index_probes", r.stats.index_probes);
                self.metrics
                    .incr("router.index_postings", r.stats.index_postings);
                self.metrics
                    .incr("router.shared_scan_hits", r.stats.shared_scan_hits);
                if r.stats.index_probes > 0 {
                    // Probes pay per LSM run; keep the gauges current so
                    // the report explains the probe-vs-scan choice.
                    self.observe_kv_stats();
                }
                self.metrics
                    .observe("router.query_latency", start.elapsed().as_secs_f64());
                self.metrics
                    .observe("router.query_sim_seconds", r.stats.sim_seconds);
                Response::Query(r)
            }
            Request::BuildIndex { dataset, column } => {
                self.metrics.incr("router.index_builds", 1);
                let n = self.driver.build_index(&dataset, &column)?;
                self.metrics.incr("router.index_rows", n);
                self.observe_kv_stats();
                Response::Index(n)
            }
            Request::Transform { dataset, target } => {
                self.metrics.incr("router.transforms", 1);
                let rep = self.driver.transform_layout(&dataset, target)?;
                Response::Transform(rep)
            }
            Request::Delete {
                dataset,
                object_index,
                rows,
            } => {
                // Mutations are writes for admission purposes.
                let _credit = self.write_gate.acquire(1);
                self.metrics.incr("router.deletes", 1);
                self.metrics.incr("router.delete_rows", rows.len() as u64);
                let n = self.driver.delete_rows(&dataset, object_index, &rows)?;
                // `delete_rows` may have tripped the compaction threshold;
                // keep the gauge current either way.
                self.metrics
                    .set("driver.compactions", self.driver.compactions());
                self.metrics
                    .observe("router.delete_latency", start.elapsed().as_secs_f64());
                Response::Delete(n)
            }
            Request::Append {
                dataset,
                batch,
                target_bytes,
            } => {
                let _credit = self.write_gate.acquire(1);
                self.metrics.incr("router.appends", 1);
                self.metrics
                    .incr("router.append_rows", batch.nrows() as u64);
                let rep = self.driver.append(&dataset, &batch, target_bytes)?;
                self.metrics.incr("router.write_bytes", rep.bytes_written);
                self.metrics
                    .set("driver.compactions", self.driver.compactions());
                self.metrics
                    .observe("router.append_latency", start.elapsed().as_secs_f64());
                Response::Write(rep)
            }
            Request::Compact { dataset } => {
                let _credit = self.write_gate.acquire(1);
                self.metrics.incr("router.compacts", 1);
                let rep = self.driver.compact(&dataset)?;
                self.metrics
                    .set("driver.compactions", self.driver.compactions());
                self.metrics
                    .observe("router.compact_latency", start.elapsed().as_secs_f64());
                Response::Compact(rep)
            }
        };
        Ok(out)
    }

    /// Available write credits (observability).
    pub fn write_credits_available(&self) -> usize {
        self.write_gate.available()
    }

    /// Available global query credits (observability; the serving tests
    /// assert this returns to capacity after bursts and failures).
    pub fn query_credits_available(&self) -> usize {
        self.query_gate.available()
    }

    /// Snapshot the OSDs' LSM state into gauge metrics, so index builds
    /// and probes leave more signal than the bare `router.index_builds`
    /// count: total sorted runs and buffered memtable entries across the
    /// cluster, plus the worst-case read amplification a probe pays.
    fn observe_kv_stats(&self) {
        let stats = self.driver.cluster().kv_stats();
        let runs: usize = stats.iter().map(|s| s.runs).sum();
        let mem: usize = stats.iter().map(|s| s.memtable_entries).sum();
        let amp = stats.iter().map(|s| s.read_amp()).max().unwrap_or(1);
        self.metrics.set("kv.sstable_runs", runs as u64);
        self.metrics.set("kv.memtable_entries", mem as u64);
        self.metrics.set("kv.read_amp_max", amp as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, DriverConfig};
    use crate::dataset::table::gen;
    use crate::skyhook::{register_skyhook_class, AggFunc, Query};
    use crate::store::{ClassRegistry, Cluster};

    fn router() -> Router {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        let cluster = Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            reg,
        );
        let driver = Arc::new(Driver::new(cluster, DriverConfig::default()));
        Router::new(driver, 4)
    }

    #[test]
    fn write_then_query_via_router() {
        let r = router();
        let batch = gen::sensor_table(1500, 2);
        let resp = r
            .handle(Request::WriteTable {
                dataset: "s".into(),
                batch,
                layout: Layout::Col,
                spec: PartitionSpec::with_target(8 * 1024),
            })
            .unwrap();
        let Response::Write(rep) = resp else { panic!() };
        assert!(rep.objects > 1);

        let resp = r
            .handle(Request::Query {
                query: Query::scan("s").aggregate(AggFunc::Count, "val"),
                force_mode: None,
                tenant: None,
            })
            .unwrap();
        let Response::Query(q) = resp else { panic!() };
        assert_eq!(q.aggregates[0], 1500.0);

        assert_eq!(r.metrics.counter("router.writes"), 1);
        assert_eq!(r.metrics.counter("router.queries"), 1);
        assert_eq!(r.metrics.counter("router.pushdown_queries"), 1);
        assert!(r.metrics.counter("router.write_bytes") > 0);
        assert!(r.metrics.histogram("router.query_latency").is_some());
    }

    #[test]
    fn index_and_transform_via_router() {
        let r = router();
        r.handle(Request::WriteTable {
            dataset: "s".into(),
            batch: gen::sensor_table(500, 3),
            layout: Layout::Row,
            spec: PartitionSpec::with_target(8 * 1024),
        })
        .unwrap();
        let Response::Index(n) = r
            .handle(Request::BuildIndex {
                dataset: "s".into(),
                column: "sensor".into(),
            })
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(n, 500);
        // The build left LSM signal behind, not just a request count:
        // postings sit in memtables/runs and the probe-cost gauge is live.
        assert_eq!(r.metrics.counter("router.index_builds"), 1);
        assert_eq!(r.metrics.counter("router.index_rows"), 500);
        assert!(r.metrics.counter("kv.read_amp_max") >= 1);
        assert!(
            r.metrics.counter("kv.memtable_entries") + r.metrics.counter("kv.sstable_runs") > 0,
            "postings should be buffered or flushed somewhere"
        );
        let Response::Transform(rep) = r
            .handle(Request::Transform {
                dataset: "s".into(),
                target: Layout::Col,
            })
            .unwrap()
        else {
            panic!()
        };
        assert!(rep.objects > 0);
    }

    #[test]
    fn errors_propagate() {
        let r = router();
        assert!(r
            .handle(Request::Query {
                query: Query::scan("ghost"),
                force_mode: None,
                tenant: None,
            })
            .is_err());
    }

    #[test]
    fn serving_metrics_track_admission_and_inflight() {
        use crate::coordinator::backpressure::QueryGateConfig;
        use std::time::Duration;

        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        let cluster = Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            reg,
        );
        let driver = Arc::new(Driver::new(cluster, DriverConfig::default()));
        let r = Router::with_gates(
            driver,
            4,
            QueryGateConfig {
                global_credits: 1,
                tenant_credits: 1,
                admit_timeout: Duration::from_millis(5),
            },
        );
        r.handle(Request::WriteTable {
            dataset: "s".into(),
            batch: gen::sensor_table(800, 9),
            layout: Layout::Col,
            spec: PartitionSpec::with_target(8 * 1024),
        })
        .unwrap();

        // A successful query leaves the gauge back at zero and credits
        // fully restored -- even though it transited through 1 in-flight.
        let q = || Request::Query {
            query: Query::scan("s").aggregate(AggFunc::Count, "val"),
            force_mode: None,
            tenant: Some("t0".into()),
        };
        r.handle(q()).unwrap();
        assert_eq!(r.metrics.counter("router.queries_inflight"), 0);
        assert_eq!(r.query_credits_available(), 1);
        assert_eq!(r.metrics.counter("router.queries_rejected"), 0);
        // Serial queries never overlap, so the shared-scan counter exists
        // but stays zero.
        assert_eq!(r.metrics.counter("router.shared_scan_hits"), 0);

        // Drain the single global credit out from under the router: the
        // next query must shed with the typed error and count it.
        let held = r.query_gate().admit(None).unwrap();
        let err = r.handle(q()).unwrap_err();
        assert!(matches!(err, crate::Error::Overloaded(_)));
        assert_eq!(r.metrics.counter("router.queries_rejected"), 1);
        assert_eq!(r.metrics.counter("router.queries_inflight"), 0);
        drop(held);

        // Gate restored: the same query is admitted again.
        r.handle(q()).unwrap();
        assert_eq!(r.metrics.counter("router.queries"), 2);
        assert_eq!(r.query_credits_available(), 1);

        // A failing query (ghost dataset) still returns its credit and
        // decrements the gauge on the unwind path.
        let bad = Request::Query {
            query: Query::scan("ghost"),
            force_mode: None,
            tenant: None,
        };
        assert!(r.handle(bad).is_err());
        assert_eq!(r.metrics.counter("router.queries_inflight"), 0);
        assert_eq!(r.query_credits_available(), 1);
    }

    #[test]
    fn mutations_route_through_router_and_leave_metrics() {
        let r = router();
        let batch = gen::sensor_table(1200, 5);
        r.handle(Request::WriteTable {
            dataset: "m".into(),
            batch: batch.clone(),
            layout: Layout::Col,
            spec: PartitionSpec::with_target(8 * 1024),
        })
        .unwrap();

        // Delete a handful of rows from the first object.
        let Response::Delete(n) = r
            .handle(Request::Delete {
                dataset: "m".into(),
                object_index: 0,
                rows: vec![0, 1, 2],
            })
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(n, 3);
        assert_eq!(r.metrics.counter("router.deletes"), 1);
        assert_eq!(r.metrics.counter("router.delete_rows"), 3);

        // Append a fresh slab of rows; the count visible to queries grows.
        let extra = gen::sensor_table(300, 77);
        let Response::Write(rep) = r
            .handle(Request::Append {
                dataset: "m".into(),
                batch: extra,
                target_bytes: 8 * 1024,
            })
            .unwrap()
        else {
            panic!()
        };
        assert!(rep.objects > 0);
        assert_eq!(r.metrics.counter("router.appends"), 1);
        assert_eq!(r.metrics.counter("router.append_rows"), 300);

        let Response::Query(q) = r
            .handle(Request::Query {
                query: Query::scan("m").aggregate(AggFunc::Count, "val"),
                force_mode: None,
                tenant: None,
            })
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(q.aggregates[0], (1200 - 3 + 300) as f64);

        // Explicit compaction drops the tombstoned rows for good and the
        // gauge reflects the driver's lifetime compaction count.
        let Response::Compact(rep) = r.handle(Request::Compact { dataset: "m".into() }).unwrap()
        else {
            panic!()
        };
        assert!(rep.objects > 0);
        assert_eq!(r.metrics.counter("router.compacts"), 1);
        assert!(r.metrics.counter("driver.compactions") >= 1);

        let Response::Query(q) = r
            .handle(Request::Query {
                query: Query::scan("m").aggregate(AggFunc::Count, "val"),
                force_mode: None,
                tenant: None,
            })
            .unwrap()
        else {
            panic!()
        };
        assert_eq!(q.aggregates[0], (1200 - 3 + 300) as f64);

        // Mutation credits all came back.
        assert_eq!(r.write_credits_available(), 4);
    }

    #[test]
    fn credits_are_returned_after_writes() {
        let r = router();
        let before = r.write_credits_available();
        r.handle(Request::WriteTable {
            dataset: "a".into(),
            batch: gen::sensor_table(100, 4),
            layout: Layout::Col,
            spec: PartitionSpec::default(),
        })
        .unwrap();
        assert_eq!(r.write_credits_available(), before);
    }
}
