//! Metrics registry: named counters + latency histograms for the request
//! path. Snapshots feed the CLI's `stats` output and the benches.

use crate::util::stats::Histogram;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

/// Snapshot of one histogram.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn incr(&self, name: &str, by: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += by;
    }

    /// Set a gauge-style counter to an absolute value (last write wins)
    /// — for state snapshots like LSM run counts, where accumulation
    /// would be meaningless.
    pub fn set(&self, name: &str, value: u64) {
        self.counters
            .lock()
            .unwrap()
            .insert(name.to_string(), value);
    }

    /// Record a latency/duration observation (seconds).
    pub fn observe(&self, name: &str, seconds: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(Histogram::for_latency)
            .record(seconds);
    }

    /// Counter value (0 if never written).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Histogram snapshot.
    pub fn histogram(&self, name: &str) -> Option<HistSnapshot> {
        self.histograms.lock().unwrap().get(name).map(|h| HistSnapshot {
            count: h.count(),
            mean: h.mean(),
            p50: h.p50(),
            p95: h.p95(),
            max: h.max(),
        })
    }

    /// All counters, sorted by name.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Render a human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str("== counters ==\n");
        for (k, v) in self.counters() {
            out.push_str(&format!("{k:<40} {v}\n"));
        }
        out.push_str("== latencies ==\n");
        let hists = self.histograms.lock().unwrap();
        for (k, h) in hists.iter() {
            out.push_str(&format!(
                "{k:<40} n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms\n",
                h.count(),
                h.mean() * 1e3,
                h.p50() * 1e3,
                h.p95() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("reqs", 1);
        m.incr("reqs", 2);
        assert_eq!(m.counter("reqs"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("kv.runs", 7);
        m.set("kv.runs", 3);
        assert_eq!(m.counter("kv.runs"), 3);
    }

    #[test]
    fn histograms_record() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe("lat", i as f64 * 1e-3);
        }
        let s = m.histogram("lat").unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 > 0.03 && s.p50 < 0.07, "p50={}", s.p50);
        assert!(m.histogram("none").is_none());
    }

    #[test]
    fn report_contains_entries() {
        let m = Metrics::new();
        m.incr("writes", 5);
        m.observe("q", 0.01);
        let r = m.report();
        assert!(r.contains("writes"));
        assert!(r.contains("q"));
    }

    #[test]
    fn concurrent_updates() {
        let m = Arc::new(Metrics::new());
        let mut hs = Vec::new();
        for _ in 0..8 {
            let m = Arc::clone(&m);
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.incr("c", 1);
                    m.observe("h", 0.001);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("c"), 8000);
        assert_eq!(m.histogram("h").unwrap().count, 8000);
    }
}
