//! Dynamic batching for chunk-compute requests.
//!
//! The PJRT executables have fixed shapes; amortizing dispatch overhead
//! means packing many small pushdown requests into full kernel launches.
//! The batcher collects submissions until either `max_batch` items are
//! pending or `max_wait` elapses since the first item of the batch
//! (vLLM-style time/size dual trigger), then hands the whole batch to the
//! processor on a dedicated thread.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Batcher statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BatchStats {
    pub batches: u64,
    pub items: u64,
    pub full_batches: u64,
}

struct Submission<T, R> {
    item: T,
    resp: mpsc::Sender<R>,
}

enum Msg<T, R> {
    Submit(Submission<T, R>),
    Shutdown,
}

/// A generic dynamic batcher. `processor` receives 1..=max_batch items
/// and must return exactly one result per item, in order.
pub struct Batcher<T: Send + 'static, R: Send + 'static> {
    tx: Mutex<mpsc::Sender<Msg<T, R>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    stats: Arc<Mutex<BatchStats>>,
}

impl<T: Send + 'static, R: Send + 'static> Batcher<T, R> {
    pub fn new<F>(policy: BatchPolicy, processor: F) -> Arc<Self>
    where
        F: Fn(Vec<T>) -> Vec<R> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg<T, R>>();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let stats2 = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("skyhook-batcher".into())
            .spawn(move || batch_loop(rx, policy, processor, stats2))
            .expect("spawn batcher");
        Arc::new(Self {
            tx: Mutex::new(tx),
            handle: Mutex::new(Some(handle)),
            stats,
        })
    }

    /// Submit one item; blocks until its result is ready.
    pub fn submit(&self, item: T) -> R {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Submit(Submission { item, resp: rtx }))
            .expect("batcher gone");
        rrx.recv().expect("batcher dropped request")
    }

    pub fn stats(&self) -> BatchStats {
        *self.stats.lock().unwrap()
    }
}

impl<T: Send + 'static, R: Send + 'static> Drop for Batcher<T, R> {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Msg::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn batch_loop<T, R, F>(
    rx: mpsc::Receiver<Msg<T, R>>,
    policy: BatchPolicy,
    processor: F,
    stats: Arc<Mutex<BatchStats>>,
) where
    F: Fn(Vec<T>) -> Vec<R>,
{
    let max_batch = policy.max_batch.max(1);
    'outer: loop {
        // Wait for the first item of a batch.
        let first = match rx.recv() {
            Ok(Msg::Submit(s)) => s,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        // Fill until full or deadline.
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Submit(s)) => pending.push(s),
                Ok(Msg::Shutdown) => {
                    flush(&processor, pending, &stats, max_batch);
                    break 'outer;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush(&processor, pending, &stats, max_batch);
                    break 'outer;
                }
            }
        }
        flush(&processor, pending, &stats, max_batch);
    }
}

fn flush<T, R, F>(
    processor: &F,
    pending: Vec<Submission<T, R>>,
    stats: &Arc<Mutex<BatchStats>>,
    max_batch: usize,
) where
    F: Fn(Vec<T>) -> Vec<R>,
{
    if pending.is_empty() {
        return;
    }
    {
        let mut s = stats.lock().unwrap();
        s.batches += 1;
        s.items += pending.len() as u64;
        if pending.len() >= max_batch {
            s.full_batches += 1;
        }
    }
    let (items, resps): (Vec<T>, Vec<mpsc::Sender<R>>) = pending
        .into_iter()
        .map(|s| (s.item, s.resp))
        .unzip();
    let results = processor(items);
    assert_eq!(
        results.len(),
        resps.len(),
        "processor must return one result per item"
    );
    for (r, tx) in results.into_iter().zip(resps) {
        let _ = tx.send(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::WaitGroup;

    #[test]
    fn single_item_flushes_on_timeout() {
        let b = Batcher::new(
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(5),
            },
            |items: Vec<u32>| items.iter().map(|x| x * 2).collect(),
        );
        assert_eq!(b.submit(21), 42);
        let s = b.stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.items, 1);
        assert_eq!(s.full_batches, 0);
    }

    #[test]
    fn concurrent_submitters_get_batched() {
        let b = Batcher::new(
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
            },
            |items: Vec<u32>| items.iter().map(|x| x + 1).collect(),
        );
        let wg = WaitGroup::new();
        let mut handles = Vec::new();
        for i in 0..32u32 {
            let b = Arc::clone(&b);
            let g = wg.add();
            handles.push(std::thread::spawn(move || {
                let r = b.submit(i);
                drop(g);
                assert_eq!(r, i + 1);
            }));
        }
        wg.wait();
        for h in handles {
            h.join().unwrap();
        }
        let s = b.stats();
        assert_eq!(s.items, 32);
        assert!(
            s.batches < 32,
            "expected batching, got {} batches",
            s.batches
        );
    }

    #[test]
    fn results_map_to_correct_submitters() {
        let b = Batcher::new(
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
            },
            |items: Vec<u64>| items.iter().map(|x| x * x).collect(),
        );
        let mut handles = Vec::new();
        for i in 0..20u64 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || (i, b.submit(i))));
        }
        for h in handles {
            let (i, r) = h.join().unwrap();
            assert_eq!(r, i * i, "submitter {i} got wrong result");
        }
    }

    #[test]
    fn full_batch_triggers_immediately() {
        // With a huge max_wait, only the size trigger can flush.
        let b = Batcher::new(
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(5),
            },
            |items: Vec<u32>| items.iter().map(|x| *x).collect(),
        );
        let wg = WaitGroup::new();
        let mut handles = Vec::new();
        let start = Instant::now();
        for i in 0..4u32 {
            let b = Arc::clone(&b);
            let g = wg.add();
            handles.push(std::thread::spawn(move || {
                let r = b.submit(i);
                drop(g);
                r
            }));
        }
        wg.wait();
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "size trigger should flush fast"
        );
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.stats().full_batches, 1);
    }

    #[test]
    fn drop_flushes_cleanly() {
        let b = Batcher::new(BatchPolicy::default(), |items: Vec<u8>| items);
        assert_eq!(b.submit(9), 9);
        drop(b); // must join without hanging
    }
}
