//! Streaming ingestion: the L3 data-pipeline front end.
//!
//! Rows arrive as a stream of record batches (sensors, event logs, …);
//! the ingestor accumulates them into row groups near the target object
//! size, seals and writes groups through the worker pool with
//! credit-based backpressure (bounded in-flight object writes), and
//! finalizes dataset metadata on close. This is the §2 goal-1 write path
//! — "gather the data which is from the same logical units and put the
//! data in the same storage locations" — as a continuously running
//! pipeline rather than a one-shot bulk load.

use super::backpressure::CreditGate;
use crate::dataset::metadata::{self, DatasetMeta, RowGroupMeta};
use crate::dataset::naming;
use crate::dataset::table::Batch;
use crate::dataset::{Layout, TableSchema};
use crate::error::{Error, Result};
use crate::simnet::Timeline;
use crate::store::Cluster;
use crate::util::pool::{ThreadPool, WaitGroup};
use std::sync::{Arc, Mutex};

/// Ingestion configuration.
#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// Seal a row group when its serialized size estimate reaches this.
    pub target_object_bytes: u64,
    /// Object layout.
    pub layout: Layout,
    /// Max object writes in flight (backpressure window).
    pub max_inflight: usize,
    /// Optional locality key for all groups of this stream (§3.1).
    pub locality: Option<String>,
    /// Sort-aware clustering: sort every sealed row group by this column
    /// before encoding, so each object's rows come out sorted and the
    /// write path stamps its sortedness marker. A stream cannot sort
    /// globally (rows keep arriving), so this is per-object clustering —
    /// zone maps sharpen only as far as the arrival order allows, but
    /// prefix-read top-k and sort-skipping work on every object.
    pub cluster_by: Option<String>,
    /// Columns to keep under a server-local secondary index: every
    /// sealed object gets its `ix1/` omap postings built right after the
    /// write, and the finalized metadata lists the columns so the
    /// planner can offer the IndexScan access path. i64/f32 only.
    pub index_cols: Vec<String>,
}

impl Default for IngestConfig {
    fn default() -> Self {
        Self {
            target_object_bytes: 4 * 1024 * 1024,
            layout: Layout::Col,
            max_inflight: 8,
            locality: None,
            cluster_by: None,
            index_cols: Vec::new(),
        }
    }
}

/// Final report of a completed stream.
#[derive(Clone, Debug)]
pub struct IngestReport {
    pub rows: u64,
    pub objects: usize,
    pub bytes_written: u64,
    pub sim_seconds: f64,
    /// Times a push had to wait for a write credit.
    pub stalls: u64,
}

struct Shared {
    row_groups: Vec<(u64, RowGroupMeta)>, // (index, meta)
    bytes_written: u64,
    sim_finish: f64,
    first_error: Option<Error>,
}

/// A streaming writer for one dataset.
pub struct Ingestor {
    cluster: Arc<Cluster>,
    pool: Arc<ThreadPool>,
    cfg: IngestConfig,
    dataset: String,
    schema: TableSchema,
    buffer: Batch,
    next_index: u64,
    rows: u64,
    stalls: u64,
    gate: CreditGate,
    wg: WaitGroup,
    shared: Arc<Mutex<Shared>>,
    worker_cpu: Arc<Timeline>,
    finished: bool,
}

impl Ingestor {
    /// Open a stream for a new dataset. Fails if the dataset exists.
    pub fn open(
        cluster: Arc<Cluster>,
        pool: Arc<ThreadPool>,
        dataset: &str,
        schema: &TableSchema,
        cfg: IngestConfig,
    ) -> Result<Ingestor> {
        if cluster.object_exists(&naming::meta_object(dataset)) {
            return Err(Error::AlreadyExists(format!("dataset {dataset}")));
        }
        if let Some(col) = &cfg.cluster_by {
            // Fail at open, not on the first sealed group.
            schema.col_index(col)?;
        }
        // Same early-failure contract for indexed columns: a ghost or
        // string column is rejected before any data moves.
        metadata::validate_index_cols(schema, &cfg.index_cols)?;
        Ok(Ingestor {
            cluster,
            pool,
            gate: CreditGate::new(cfg.max_inflight),
            cfg,
            dataset: dataset.to_string(),
            schema: schema.clone(),
            buffer: Batch::empty(schema),
            next_index: 0,
            rows: 0,
            stalls: 0,
            wg: WaitGroup::new(),
            shared: Arc::new(Mutex::new(Shared {
                row_groups: Vec::new(),
                bytes_written: 0,
                sim_finish: 0.0,
                first_error: None,
            })),
            worker_cpu: Arc::new(Timeline::new()),
            finished: false,
        })
    }

    /// Push a record batch into the stream. Blocks when the backpressure
    /// window is full.
    pub fn push(&mut self, batch: &Batch) -> Result<()> {
        if self.finished {
            return Err(Error::Invalid("stream already finished".into()));
        }
        if batch.schema != self.schema {
            return Err(Error::Invalid("schema mismatch in stream".into()));
        }
        self.check_error()?;
        self.rows += batch.nrows() as u64;
        self.buffer.concat(batch)?;
        while self.buffer.byte_size() as u64 >= self.cfg.target_object_bytes
            && self.buffer.nrows() > 1
        {
            let per_row = (self.buffer.byte_size() as f64
                / self.buffer.nrows() as f64)
                .max(1.0);
            let take = ((self.cfg.target_object_bytes as f64 / per_row) as usize)
                .clamp(1, self.buffer.nrows());
            let group = self.buffer.slice(0, take)?;
            self.buffer = self.buffer.slice(take, self.buffer.nrows())?;
            self.seal(group)?;
        }
        Ok(())
    }

    /// Seal one row group: cluster it when configured, then acquire a
    /// write credit and hand the object write to the pool. The sort
    /// happens *before* the write is spawned, so the zone map the worker
    /// stamps (including the sortedness marker) is computed from exactly
    /// the rows that hit the device — a failed or interrupted write can
    /// lose the object, but never leave a marker lying about its bytes.
    fn seal(&mut self, group: Batch) -> Result<()> {
        let group = match &self.cfg.cluster_by {
            Some(col) => group.sort_by_column(col)?,
            None => group,
        };
        let credit = match self.gate.try_acquire(1) {
            Some(c) => c,
            None => {
                self.stalls += 1;
                self.gate.acquire(1)
            }
        };
        let index = self.next_index;
        self.next_index += 1;
        let name = {
            let base = naming::table_object(&self.dataset, index);
            match &self.cfg.locality {
                Some(l) => naming::with_locality(l, &base),
                None => base,
            }
        };
        let cluster = Arc::clone(&self.cluster);
        let shared = Arc::clone(&self.shared);
        let layout = self.cfg.layout;
        let index_cols = self.cfg.index_cols.clone();
        let cpu = Arc::clone(&self.worker_cpu);
        self.pool.spawn_tracked(&self.wg, move || {
            let _credit = credit; // released when the write completes
            let rows = group.nrows() as u64;
            let write_and_index = || -> Result<(u64, f64, Vec<metadata::ColumnStats>)> {
                let (bytes, mut finish, stats) = crate::skyhook::worker::write_row_group(
                    &cluster, &name, &group, layout, 0.0, &cpu,
                )?;
                // Index maintenance rides the write: postings exist
                // before the metadata that advertises them commits.
                for col in &index_cols {
                    let mut w = crate::util::bytes::ByteWriter::new();
                    w.str(col);
                    let t = cluster.call(finish, &name, "skyhook", "build_index", &w.finish())?;
                    finish = finish.max(t.finish);
                }
                Ok((bytes, finish, stats))
            };
            match write_and_index() {
                Ok((bytes, finish, stats)) => {
                    let mut s = shared.lock().unwrap();
                    s.row_groups.push((index, RowGroupMeta { rows, bytes, stats }));
                    s.bytes_written += bytes;
                    s.sim_finish = s.sim_finish.max(finish);
                }
                Err(e) => {
                    let mut s = shared.lock().unwrap();
                    if s.first_error.is_none() {
                        s.first_error = Some(e);
                    }
                }
            }
        });
        Ok(())
    }

    fn check_error(&self) -> Result<()> {
        let mut s = self.shared.lock().unwrap();
        if let Some(e) = s.first_error.take() {
            return Err(e);
        }
        Ok(())
    }

    /// Rows pushed so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Flush the tail, wait for all writes, and commit metadata.
    pub fn finish(mut self) -> Result<IngestReport> {
        self.finished = true;
        if self.buffer.nrows() > 0 {
            let tail = std::mem::replace(&mut self.buffer, Batch::empty(&self.schema));
            self.seal(tail)?;
        }
        self.wg.wait();
        self.check_error()?;
        let mut s = self.shared.lock().unwrap();
        s.row_groups.sort_by_key(|(i, _)| *i);
        // Indices must be dense 0..n for the naming scheme.
        for (want, (got, _)) in s.row_groups.iter().enumerate() {
            if *got != want as u64 {
                return Err(Error::Corrupt(format!(
                    "row group index hole: expected {want}, found {got}"
                )));
            }
        }
        let objects = s.row_groups.len();
        let localities = vec![self.cfg.locality.clone().unwrap_or_default(); objects];
        let row_groups = std::mem::take(&mut s.row_groups);
        let meta = DatasetMeta::Table {
            schema: self.schema.clone(),
            layout: self.cfg.layout,
            row_groups: row_groups.into_iter().map(|(_, g)| g).collect(),
            localities,
            cluster_by: self.cfg.cluster_by.clone().unwrap_or_default(),
            index_cols: self.cfg.index_cols.clone(),
            muta: Default::default(),
        };
        let sim = metadata::save_meta(&self.cluster, s.sim_finish, &self.dataset, &meta, false)?;
        Ok(IngestReport {
            rows: self.rows,
            objects,
            bytes_written: s.bytes_written,
            sim_seconds: sim,
            stalls: self.stalls,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::dataset::table::gen;
    use crate::skyhook::{register_skyhook_class, AggFunc, Query};
    use crate::store::ClassRegistry;

    fn cluster() -> Arc<Cluster> {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            reg,
        )
    }

    fn ingest(rows: usize, chunk: usize, cfg: IngestConfig) -> (Arc<Cluster>, IngestReport) {
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(4));
        let full = gen::sensor_table(rows, 71);
        let mut ing =
            Ingestor::open(Arc::clone(&c), pool, "stream", &full.schema, cfg).unwrap();
        let mut lo = 0;
        while lo < rows {
            let hi = (lo + chunk).min(rows);
            ing.push(&full.slice(lo, hi).unwrap()).unwrap();
            lo = hi;
        }
        let rep = ing.finish().unwrap();
        (c, rep)
    }

    #[test]
    fn stream_equals_bulk() {
        let (c, rep) = ingest(
            20_000,
            777,
            IngestConfig {
                target_object_bytes: 32 * 1024,
                ..Default::default()
            },
        );
        assert_eq!(rep.rows, 20_000);
        assert!(rep.objects > 1);
        // Query the streamed dataset.
        let driver = crate::skyhook::Driver::new(c, crate::config::DriverConfig::default());
        let r = driver
            .execute(&Query::scan("stream").aggregate(AggFunc::Count, "val"), None)
            .unwrap();
        assert_eq!(r.aggregates[0], 20_000.0);
        // Row order preserved.
        let rows = driver.execute(&Query::scan("stream"), None).unwrap().rows.unwrap();
        match rows.col("ts").unwrap() {
            crate::dataset::table::Column::I64(v) => {
                assert!(v.windows(2).all(|w| w[0] < w[1]));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn tiny_pushes_accumulate() {
        let (_, rep) = ingest(
            500,
            1, // one row at a time
            IngestConfig {
                target_object_bytes: 4 * 1024,
                ..Default::default()
            },
        );
        assert_eq!(rep.rows, 500);
        assert!(rep.objects >= 3, "{}", rep.objects);
    }

    #[test]
    fn clustered_stream_sorts_each_object_and_stamps_markers() {
        let (c, rep) = ingest(
            5_000,
            333,
            IngestConfig {
                target_object_bytes: 16 * 1024,
                cluster_by: Some("val".into()),
                ..Default::default()
            },
        );
        assert!(rep.objects > 1);
        assert_eq!(rep.rows, 5_000);
        // Every object's stamped sortedness marker is self-consistent
        // with its bytes, and the metadata records the clustered column.
        assert_eq!(
            metadata::verify_sortedness(&c, "stream").unwrap(),
            Vec::<String>::new()
        );
        let (meta, _) = metadata::load_meta(&c, 0.0, "stream").unwrap();
        assert_eq!(meta.cluster_column(), Some("val"));
        let crate::dataset::metadata::DatasetMeta::Table { row_groups, .. } = &meta else {
            unreachable!()
        };
        // val (column 2 of the sensor schema) is marked sorted in every
        // group; results are unaffected — the count still adds up.
        assert!(row_groups.iter().all(|g| g.stats[2].sorted));
        let driver = crate::skyhook::Driver::new(c, crate::config::DriverConfig::default());
        let r = driver
            .execute(&Query::scan("stream").aggregate(AggFunc::Count, "val"), None)
            .unwrap();
        assert_eq!(r.aggregates[0], 5_000.0);
        // Ghost cluster columns fail at open, before any data moves.
        let c2 = cluster();
        let pool = Arc::new(ThreadPool::new(2));
        let t = gen::sensor_table(10, 1);
        assert!(Ingestor::open(
            c2,
            pool,
            "bad",
            &t.schema,
            IngestConfig {
                cluster_by: Some("ghost".into()),
                ..Default::default()
            },
        )
        .is_err());
    }

    #[test]
    fn backpressure_window_bounds_inflight() {
        // Deterministic stall: a single-worker pool is blocked by a
        // sentinel job, so the first sealed group's credit cannot be
        // released; the second seal must stall until we unblock.
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.spawn(move || {
            rx.recv().ok();
        });
        let full = gen::sensor_table(20_000, 71);
        let mut ing = Ingestor::open(
            Arc::clone(&c),
            pool,
            "bp",
            &full.schema,
            IngestConfig {
                target_object_bytes: 16 * 1024,
                max_inflight: 1,
                ..Default::default()
            },
        )
        .unwrap();
        // Unblock the pool shortly, from another thread.
        let unblock = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(50));
            tx.send(()).ok();
        });
        ing.push(&full).unwrap();
        let rep = ing.finish().unwrap();
        unblock.join().unwrap();
        assert_eq!(rep.rows, 20_000);
        assert!(rep.stalls > 0, "second seal must have stalled");
        assert!(rep.objects > 2);
    }

    #[test]
    fn schema_mismatch_rejected() {
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(2));
        let t = gen::sensor_table(10, 1);
        let mut ing = Ingestor::open(c, pool, "s", &t.schema, Default::default()).unwrap();
        let wide = gen::wide_table(10, 3, 1);
        assert!(ing.push(&wide).is_err());
        ing.push(&t).unwrap();
        ing.finish().unwrap();
    }

    #[test]
    fn duplicate_dataset_rejected() {
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(2));
        let t = gen::sensor_table(10, 1);
        let ing = Ingestor::open(
            Arc::clone(&c),
            Arc::clone(&pool),
            "dup",
            &t.schema,
            Default::default(),
        )
        .unwrap();
        ing.finish().unwrap();
        assert!(Ingestor::open(c, pool, "dup", &t.schema, Default::default()).is_err());
    }

    #[test]
    fn empty_stream_is_valid() {
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(2));
        let t = gen::sensor_table(1, 1);
        let ing = Ingestor::open(Arc::clone(&c), pool, "empty", &t.schema, Default::default())
            .unwrap();
        let rep = ing.finish().unwrap();
        assert_eq!(rep.rows, 0);
        assert_eq!(rep.objects, 0);
        // Metadata exists and is queryable (zero rows).
        let driver = crate::skyhook::Driver::new(c, crate::config::DriverConfig::default());
        let r = driver
            .execute(&Query::scan("empty").aggregate(AggFunc::Count, "val"), None)
            .unwrap();
        assert_eq!(r.aggregates[0], 0.0);
    }

    #[test]
    fn locality_applies_to_all_groups() {
        let c = cluster();
        let pool = Arc::new(ThreadPool::new(2));
        let full = gen::sensor_table(5_000, 3);
        let mut ing = Ingestor::open(
            Arc::clone(&c),
            pool,
            "loc",
            &full.schema,
            IngestConfig {
                target_object_bytes: 8 * 1024,
                locality: Some("hot".into()),
                ..Default::default()
            },
        )
        .unwrap();
        ing.push(&full).unwrap();
        let rep = ing.finish().unwrap();
        assert!(rep.objects > 1);
        let (meta, _) = metadata::load_meta(&c, 0.0, "loc").unwrap();
        let names = meta.object_names("loc");
        assert!(names.iter().all(|n| n.starts_with("hot#")));
        // Co-located: one PG → one primary.
        let mut primaries: Vec<_> = names.iter().map(|n| c.placement(n)[0]).collect();
        primaries.dedup();
        assert_eq!(primaries.len(), 1);
    }
}
