//! L3 request-path coordination: routing, admission control, dynamic
//! batching, rebalance planning, and metrics.

pub mod backpressure;
pub mod batcher;
pub mod ingest;
pub mod metrics;
pub mod rebalance;
pub mod router;

pub use backpressure::{Admission, Credit, CreditGate, QueryGate, QueryGateConfig};
pub use batcher::{BatchPolicy, BatchStats, Batcher};
pub use ingest::{IngestConfig, IngestReport, Ingestor};
pub use metrics::Metrics;
pub use rebalance::{plan_moves, Move, PlanSummary};
pub use router::{Request, Response, Router};
