//! Credit-based backpressure: the router grants a bounded number of
//! in-flight operations; producers block (or fail fast) when the storage
//! tier can't keep up — the data-pipeline coordination role of L3.
//!
//! Two layers live here:
//!
//! - [`CreditGate`] — a counting semaphore handing out RAII [`Credit`]s.
//!   Every lock/wait is poison-tolerant: a panic anywhere (including in a
//!   credit holder, whose `Drop` then runs mid-unwind) must never leak a
//!   credit or abort by double-panicking in `Drop`.
//! - [`QueryGate`] — the query admission path: a global pool plus lazily
//!   created per-tenant pools, with a bounded-wait [`QueryGate::admit`]
//!   that rejects with a typed [`Error::Overloaded`] instead of queueing
//!   unboundedly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::error::{Error, Result};

/// Poison-tolerant lock: a panic in some other holder must not take the
/// gate down with it — the protected count is a plain integer that is
/// always in a valid state, so we keep serving through the poison flag.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct Inner {
    available: Mutex<usize>,
    cv: Condvar,
    capacity: usize,
    /// Condvar wait iterations taken by `acquire_timeout` callers — the
    /// observable the no-busy-spin tests bound: a correct deadline wait
    /// wakes O(1) times per call, a poll loop wakes unboundedly.
    timeout_polls: AtomicUsize,
}

/// A counting semaphore handing out write credits.
#[derive(Clone)]
pub struct CreditGate {
    inner: Arc<Inner>,
}

/// RAII credit; returned to the gate on drop.
pub struct Credit {
    inner: Arc<Inner>,
    n: usize,
}

impl CreditGate {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            inner: Arc::new(Inner {
                available: Mutex::new(capacity),
                cv: Condvar::new(),
                capacity,
                timeout_polls: AtomicUsize::new(0),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Currently available credits.
    pub fn available(&self) -> usize {
        *plock(&self.inner.available)
    }

    /// Total condvar wake-ups observed inside [`Self::acquire_timeout`]
    /// waits since the gate was built (see `Inner::timeout_polls`).
    pub fn timeout_poll_count(&self) -> usize {
        self.inner.timeout_polls.load(Ordering::Relaxed)
    }

    /// Block until `n` credits are available, then take them.
    pub fn acquire(&self, n: usize) -> Credit {
        let n = n.min(self.inner.capacity).max(1);
        let mut avail = plock(&self.inner.available);
        while *avail < n {
            avail = self
                .inner
                .cv
                .wait(avail)
                .unwrap_or_else(PoisonError::into_inner);
        }
        *avail -= n;
        Credit {
            inner: Arc::clone(&self.inner),
            n,
        }
    }

    /// Take `n` credits without blocking; None if unavailable.
    pub fn try_acquire(&self, n: usize) -> Option<Credit> {
        let n = n.min(self.inner.capacity).max(1);
        let mut avail = plock(&self.inner.available);
        if *avail < n {
            return None;
        }
        *avail -= n;
        Some(Credit {
            inner: Arc::clone(&self.inner),
            n,
        })
    }

    /// Acquire with a timeout; None on timeout. The wait is
    /// deadline-driven (one condvar sleep spanning the full remaining
    /// window), never a poll loop — `timeout_poll_count` proves it.
    pub fn acquire_timeout(&self, n: usize, timeout: Duration) -> Option<Credit> {
        let n = n.min(self.inner.capacity).max(1);
        let deadline = std::time::Instant::now() + timeout;
        let mut avail = plock(&self.inner.available);
        while *avail < n {
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self
                .inner
                .cv
                .wait_timeout(avail, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            avail = g;
            self.inner.timeout_polls.fetch_add(1, Ordering::Relaxed);
            if res.timed_out() && *avail < n {
                return None;
            }
        }
        *avail -= n;
        Some(Credit {
            inner: Arc::clone(&self.inner),
            n,
        })
    }
}

impl Credit {
    /// Number of credits held.
    pub fn count(&self) -> usize {
        self.n
    }
}

impl Drop for Credit {
    fn drop(&mut self) {
        // Runs during unwind when the holder panicked: must not panic
        // itself (a poisoned mutex would have made `.unwrap()` abort the
        // process via double-panic) and must always return the credits.
        let mut avail = plock(&self.inner.available);
        *avail += self.n;
        self.inner.cv.notify_all();
    }
}

/// Sizing for the [`QueryGate`] admission path.
#[derive(Debug, Clone)]
pub struct QueryGateConfig {
    /// Cluster-wide cap on concurrently admitted queries.
    pub global_credits: usize,
    /// Per-tenant cap (each tenant gets its own pool of this size).
    pub tenant_credits: usize,
    /// Bounded admission wait before rejecting with `Overloaded`.
    pub admit_timeout: Duration,
}

impl Default for QueryGateConfig {
    fn default() -> Self {
        Self {
            global_credits: 256,
            tenant_credits: 64,
            admit_timeout: Duration::from_millis(250),
        }
    }
}

/// Query admission: one global credit pool shared by every query, plus a
/// lazily created pool per tenant so no tenant can saturate the cluster
/// alone. `admit` waits at most `admit_timeout` end to end and rejects
/// with a typed [`Error::Overloaded`] naming the exhausted pool.
pub struct QueryGate {
    global: CreditGate,
    tenants: Mutex<HashMap<String, CreditGate>>,
    cfg: QueryGateConfig,
}

/// Proof of admission; both credits release on drop (unwind-safe, since
/// [`Credit`]'s `Drop` is).
pub struct Admission {
    _tenant: Option<Credit>,
    _global: Credit,
}

impl QueryGate {
    pub fn new(cfg: QueryGateConfig) -> Self {
        Self {
            global: CreditGate::new(cfg.global_credits),
            tenants: Mutex::new(HashMap::new()),
            cfg,
        }
    }

    /// Globally available query credits.
    pub fn available(&self) -> usize {
        self.global.available()
    }

    /// Global capacity.
    pub fn capacity(&self) -> usize {
        self.global.capacity()
    }

    /// Available credits in `tenant`'s pool; None if the tenant has
    /// never been admitted (its pool is created on first admit).
    pub fn tenant_available(&self, tenant: &str) -> Option<usize> {
        plock(&self.tenants).get(tenant).map(CreditGate::available)
    }

    fn tenant_gate(&self, tenant: &str) -> CreditGate {
        plock(&self.tenants)
            .entry(tenant.to_string())
            .or_insert_with(|| CreditGate::new(self.cfg.tenant_credits))
            .clone()
    }

    /// Admit one query, waiting at most `admit_timeout` across both
    /// pools. Tenant first (a tenant over its own budget is turned away
    /// before it touches the shared pool), then global with whatever
    /// window remains; acquisition order is identical for every caller,
    /// so the two-stage wait cannot deadlock.
    pub fn admit(&self, tenant: Option<&str>) -> Result<Admission> {
        let deadline = std::time::Instant::now() + self.cfg.admit_timeout;
        let tenant_credit = match tenant {
            None => None,
            Some(t) => {
                let gate = self.tenant_gate(t);
                match gate.acquire_timeout(1, self.cfg.admit_timeout) {
                    Some(c) => Some(c),
                    None => {
                        return Err(Error::Overloaded(format!(
                            "tenant {t:?}: no query credit within {:?} \
                             (pool of {})",
                            self.cfg.admit_timeout, self.cfg.tenant_credits
                        )))
                    }
                }
            }
        };
        let remaining = deadline.saturating_duration_since(std::time::Instant::now());
        match self.global.acquire_timeout(1, remaining) {
            Some(g) => Ok(Admission {
                _tenant: tenant_credit,
                _global: g,
            }),
            None => Err(Error::Overloaded(format!(
                "global pool: no query credit within {:?} (pool of {})",
                self.cfg.admit_timeout, self.cfg.global_credits
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn acquire_and_release() {
        let g = CreditGate::new(3);
        assert_eq!(g.available(), 3);
        let c1 = g.acquire(2);
        assert_eq!(g.available(), 1);
        assert_eq!(c1.count(), 2);
        drop(c1);
        assert_eq!(g.available(), 3);
    }

    #[test]
    fn try_acquire_fails_when_exhausted() {
        let g = CreditGate::new(2);
        let _c = g.acquire(2);
        assert!(g.try_acquire(1).is_none());
        drop(_c);
        assert!(g.try_acquire(2).is_some());
    }

    #[test]
    fn acquire_clamps_to_capacity() {
        let g = CreditGate::new(2);
        let c = g.acquire(100); // clamped, must not deadlock
        assert_eq!(c.count(), 2);
    }

    #[test]
    fn timeout_expires() {
        let g = CreditGate::new(1);
        let _held = g.acquire(1);
        let start = std::time::Instant::now();
        assert!(g.acquire_timeout(1, Duration::from_millis(30)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn blocked_acquirer_wakes_on_release() {
        let g = CreditGate::new(1);
        let held = g.acquire(1);
        let g2 = g.clone();
        let progressed = Arc::new(AtomicUsize::new(0));
        let p2 = Arc::clone(&progressed);
        let h = std::thread::spawn(move || {
            let _c = g2.acquire(1);
            p2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(progressed.load(Ordering::SeqCst), 0, "should be blocked");
        drop(held);
        h.join().unwrap();
        assert_eq!(progressed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn bounded_inflight_invariant() {
        // N producers through a gate of 4: observed concurrency never
        // exceeds 4.
        let g = CreditGate::new(4);
        let inflight = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..16 {
            let g = g.clone();
            let inflight = Arc::clone(&inflight);
            let peak = Arc::clone(&peak);
            hs.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let _c = g.acquire(1);
                    let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_micros(200));
                    inflight.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn credits_restore_when_holder_panics() {
        // The Credit Drop runs during the holder's unwind; the credit
        // must come back and later acquirers must proceed.
        let g = CreditGate::new(2);
        let g2 = g.clone();
        let joined = std::thread::spawn(move || {
            let _c = g2.acquire(2);
            panic!("holder dies with both credits");
        })
        .join();
        assert!(joined.is_err());
        assert_eq!(g.available(), 2, "panicking holder leaked credits");
        let c = g.acquire_timeout(2, Duration::from_millis(100));
        assert!(c.is_some(), "gate wedged after holder panic");
    }

    #[test]
    fn gate_survives_poisoned_mutex() {
        // Poison the gate's mutex directly (a panic while holding the
        // lock). Every subsequent operation — including the Credit Drop,
        // which would previously double-panic and abort — must keep
        // working off the still-valid count.
        let g = CreditGate::new(2);
        let held = g.acquire(1);
        let inner = Arc::clone(&g.inner);
        let poisoned = std::thread::spawn(move || {
            let _guard = inner.available.lock().unwrap();
            panic!("poison the gate mutex");
        })
        .join();
        assert!(poisoned.is_err());
        assert!(g.inner.available.is_poisoned());
        assert_eq!(g.available(), 1);
        drop(held); // must restore, not abort
        assert_eq!(g.available(), 2);
        let c = g.acquire_timeout(2, Duration::from_millis(100)).unwrap();
        drop(c);
        assert_eq!(g.available(), 2);
        assert!(g.try_acquire(1).is_some());
    }

    #[test]
    fn stress_churn_always_restores_initial_credits() {
        // Threads × barrier churn across every acquisition flavor, with
        // some holders panicking mid-hold: after everything joins, the
        // credit count is exactly the initial capacity — no leaks, no
        // double-returns.
        let g = CreditGate::new(6);
        let threads = 12;
        let rounds = 40;
        let barrier = Arc::new(Barrier::new(threads));
        let mut hs = Vec::new();
        for t in 0..threads {
            let g = g.clone();
            let barrier = Arc::clone(&barrier);
            hs.push(std::thread::spawn(move || {
                barrier.wait(); // maximal contention from the first round
                for i in 0..rounds {
                    let n = 1 + (t + i) % 3;
                    match (t + i) % 4 {
                        0 => {
                            let _c = g.acquire(n);
                        }
                        1 => {
                            let _c = g.try_acquire(n);
                        }
                        2 => {
                            let _c = g.acquire_timeout(n, Duration::from_millis(5));
                        }
                        _ => {
                            // Panic while holding; unwind must return it.
                            let r = catch_unwind(AssertUnwindSafe(|| {
                                let _c = g.acquire(n);
                                panic!("churn holder panic");
                            }));
                            assert!(r.is_err());
                        }
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(g.available(), g.capacity(), "credits leaked or forged");
    }

    #[test]
    fn zero_credit_timeout_never_busy_spins() {
        // With the only credit held and nobody releasing, a timed-out
        // acquire must sleep the window in O(1) condvar waits — a poll
        // loop would rack up hundreds of wake-ups in 60ms.
        let g = CreditGate::new(1);
        let _held = g.acquire(1);
        let before = g.timeout_poll_count();
        assert!(g.acquire_timeout(1, Duration::from_millis(60)).is_none());
        let polls = g.timeout_poll_count() - before;
        assert!(polls <= 8, "busy-spin: {polls} wake-ups for one timeout");
    }

    fn qcfg(global: usize, tenant: usize, ms: u64) -> QueryGateConfig {
        QueryGateConfig {
            global_credits: global,
            tenant_credits: tenant,
            admit_timeout: Duration::from_millis(ms),
        }
    }

    #[test]
    fn query_gate_per_tenant_isolation() {
        let qg = QueryGate::new(qcfg(8, 1, 20));
        let held = qg.admit(Some("a")).unwrap();
        // Tenant a is at its cap: rejected with the typed error naming it.
        let err = qg.admit(Some("a")).unwrap_err();
        assert!(matches!(&err, Error::Overloaded(m) if m.contains("\"a\"")), "{err}");
        // Tenant b is unaffected.
        let b = qg.admit(Some("b")).unwrap();
        drop(held);
        assert_eq!(qg.tenant_available("a"), Some(1));
        assert!(qg.admit(Some("a")).is_ok());
        drop(b);
    }

    #[test]
    fn query_gate_global_cap_spans_tenants() {
        let qg = QueryGate::new(qcfg(2, 8, 20));
        let a = qg.admit(Some("a")).unwrap();
        let b = qg.admit(Some("b")).unwrap();
        let err = qg.admit(Some("c")).unwrap_err();
        assert!(matches!(&err, Error::Overloaded(m) if m.contains("global")), "{err}");
        drop(a);
        assert!(qg.admit(Some("c")).is_ok());
        drop(b);
        assert_eq!(qg.available(), 1);
    }

    #[test]
    fn query_gate_anonymous_uses_global_only() {
        let qg = QueryGate::new(qcfg(1, 1, 20));
        let held = qg.admit(None).unwrap();
        assert_eq!(qg.available(), 0);
        assert!(qg.admit(None).is_err());
        drop(held);
        assert_eq!(qg.available(), 1);
        assert_eq!(qg.tenant_available("nobody"), None);
    }
}
