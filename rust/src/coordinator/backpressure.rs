//! Credit-based backpressure for the streaming write path: the ingestion
//! router grants a bounded number of in-flight object writes; producers
//! block (or fail fast) when the storage tier can't keep up — the
//! data-pipeline coordination role of L3.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Inner {
    available: Mutex<usize>,
    cv: Condvar,
    capacity: usize,
}

/// A counting semaphore handing out write credits.
#[derive(Clone)]
pub struct CreditGate {
    inner: Arc<Inner>,
}

/// RAII credit; returned to the gate on drop.
pub struct Credit {
    inner: Arc<Inner>,
    n: usize,
}

impl CreditGate {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            inner: Arc::new(Inner {
                available: Mutex::new(capacity),
                cv: Condvar::new(),
                capacity,
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }

    /// Currently available credits.
    pub fn available(&self) -> usize {
        *self.inner.available.lock().unwrap()
    }

    /// Block until `n` credits are available, then take them.
    pub fn acquire(&self, n: usize) -> Credit {
        let n = n.min(self.inner.capacity).max(1);
        let mut avail = self.inner.available.lock().unwrap();
        while *avail < n {
            avail = self.inner.cv.wait(avail).unwrap();
        }
        *avail -= n;
        Credit {
            inner: Arc::clone(&self.inner),
            n,
        }
    }

    /// Take `n` credits without blocking; None if unavailable.
    pub fn try_acquire(&self, n: usize) -> Option<Credit> {
        let n = n.min(self.inner.capacity).max(1);
        let mut avail = self.inner.available.lock().unwrap();
        if *avail < n {
            return None;
        }
        *avail -= n;
        Some(Credit {
            inner: Arc::clone(&self.inner),
            n,
        })
    }

    /// Acquire with a timeout; None on timeout.
    pub fn acquire_timeout(&self, n: usize, timeout: Duration) -> Option<Credit> {
        let n = n.min(self.inner.capacity).max(1);
        let deadline = std::time::Instant::now() + timeout;
        let mut avail = self.inner.available.lock().unwrap();
        while *avail < n {
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, res) = self
                .inner
                .cv
                .wait_timeout(avail, deadline - now)
                .unwrap();
            avail = g;
            if res.timed_out() && *avail < n {
                return None;
            }
        }
        *avail -= n;
        Some(Credit {
            inner: Arc::clone(&self.inner),
            n,
        })
    }
}

impl Credit {
    /// Number of credits held.
    pub fn count(&self) -> usize {
        self.n
    }
}

impl Drop for Credit {
    fn drop(&mut self) {
        let mut avail = self.inner.available.lock().unwrap();
        *avail += self.n;
        self.inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn acquire_and_release() {
        let g = CreditGate::new(3);
        assert_eq!(g.available(), 3);
        let c1 = g.acquire(2);
        assert_eq!(g.available(), 1);
        assert_eq!(c1.count(), 2);
        drop(c1);
        assert_eq!(g.available(), 3);
    }

    #[test]
    fn try_acquire_fails_when_exhausted() {
        let g = CreditGate::new(2);
        let _c = g.acquire(2);
        assert!(g.try_acquire(1).is_none());
        drop(_c);
        assert!(g.try_acquire(2).is_some());
    }

    #[test]
    fn acquire_clamps_to_capacity() {
        let g = CreditGate::new(2);
        let c = g.acquire(100); // clamped, must not deadlock
        assert_eq!(c.count(), 2);
    }

    #[test]
    fn timeout_expires() {
        let g = CreditGate::new(1);
        let _held = g.acquire(1);
        let start = std::time::Instant::now();
        assert!(g.acquire_timeout(1, Duration::from_millis(30)).is_none());
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn blocked_acquirer_wakes_on_release() {
        let g = CreditGate::new(1);
        let held = g.acquire(1);
        let g2 = g.clone();
        let progressed = Arc::new(AtomicUsize::new(0));
        let p2 = Arc::clone(&progressed);
        let h = std::thread::spawn(move || {
            let _c = g2.acquire(1);
            p2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(progressed.load(Ordering::SeqCst), 0, "should be blocked");
        drop(held);
        h.join().unwrap();
        assert_eq!(progressed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn bounded_inflight_invariant() {
        // N producers through a gate of 4: observed concurrency never
        // exceeds 4.
        let g = CreditGate::new(4);
        let inflight = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..16 {
            let g = g.clone();
            let inflight = Arc::clone(&inflight);
            let peak = Arc::clone(&peak);
            hs.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let _c = g.acquire(1);
                    let now = inflight.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_micros(200));
                    inflight.fetch_sub(1, Ordering::SeqCst);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 4);
    }
}
