//! Rebalance planning: given two osdmap epochs, compute exactly which
//! objects must move where — the preview/throttling layer above
//! `Cluster::rebalance` (§2 goal 1's "load balancing, elasticity").

use crate::store::placement::{OsdId, OsdMap};

/// One planned object movement.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Move {
    pub object: String,
    pub from: OsdId,
    pub to: OsdId,
}

/// Summary of a movement plan.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PlanSummary {
    pub objects_total: usize,
    pub objects_moving: usize,
    pub moves: usize,
    /// Fraction of objects whose placement changed.
    pub churn: f64,
}

/// Compute the movement plan between two maps for `objects`.
///
/// A move is emitted per (object, new OSD) that doesn't hold the object
/// under the old map, sourced from an old holder that is preferably also
/// surviving (first old OSD as source, matching Cluster::rebalance).
pub fn plan_moves(
    before: &OsdMap,
    after: &OsdMap,
    objects: &[String],
    replicas: usize,
) -> (Vec<Move>, PlanSummary) {
    let mut moves = Vec::new();
    let mut moving = 0usize;
    for obj in objects {
        let old = before.place(obj, replicas);
        let new = after.place(obj, replicas);
        let added: Vec<OsdId> = new
            .iter()
            .copied()
            .filter(|id| !old.contains(id))
            .collect();
        if !added.is_empty() {
            moving += 1;
        }
        for to in added {
            moves.push(Move {
                object: obj.clone(),
                from: old[0],
                to,
            });
        }
    }
    let summary = PlanSummary {
        objects_total: objects.len(),
        objects_moving: moving,
        moves: moves.len(),
        churn: if objects.is_empty() {
            0.0
        } else {
            moving as f64 / objects.len() as f64
        },
    };
    (moves, summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn objects(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("obj.{i:04}")).collect()
    }

    #[test]
    fn no_change_no_moves() {
        let m = OsdMap::new(4, 128);
        let (moves, s) = plan_moves(&m, &m.clone(), &objects(100), 2);
        assert!(moves.is_empty());
        assert_eq!(s.objects_moving, 0);
        assert_eq!(s.churn, 0.0);
    }

    #[test]
    fn adding_osd_moves_bounded_fraction() {
        let before = OsdMap::new(8, 256);
        let mut after = before.clone();
        after.add_osd(1.0);
        let objs = objects(800);
        let (moves, s) = plan_moves(&before, &after, &objs, 1);
        // Ideal churn for 8→9 is 1/9 ≈ 11%; allow 2x slack.
        assert!(s.churn > 0.02 && s.churn < 0.25, "churn={}", s.churn);
        // Every move targets the new OSD (id 8) under replicas=1.
        assert!(moves.iter().all(|m| m.to == 8));
        assert_eq!(s.moves, moves.len());
        assert_eq!(s.objects_total, 800);
    }

    #[test]
    fn removing_osd_moves_only_its_objects() {
        let before = OsdMap::new(6, 256);
        let mut after = before.clone();
        after.set_weight(2, 0.0);
        let objs = objects(600);
        let (moves, _) = plan_moves(&before, &after, &objs, 1);
        for mv in &moves {
            // Every moving object was primary on the removed OSD.
            assert_eq!(before.place(&mv.object, 1)[0], 2, "{mv:?}");
            assert_ne!(mv.to, 2);
        }
        assert!(!moves.is_empty());
    }

    #[test]
    fn replicated_moves_have_valid_sources() {
        let before = OsdMap::new(5, 128);
        let mut after = before.clone();
        after.add_osd(2.0);
        let objs = objects(300);
        let (moves, _) = plan_moves(&before, &after, &objs, 3);
        for mv in &moves {
            let old = before.place(&mv.object, 3);
            assert!(old.contains(&mv.from), "source must hold the object");
            assert!(!old.contains(&mv.to), "target must be new");
        }
    }

    #[test]
    fn empty_object_list() {
        let m = OsdMap::new(3, 64);
        let mut m2 = m.clone();
        m2.add_osd(1.0);
        let (moves, s) = plan_moves(&m, &m2, &[], 1);
        assert!(moves.is_empty());
        assert_eq!(s.churn, 0.0);
    }

    #[test]
    fn plan_matches_cluster_rebalance_count() {
        use crate::config::ClusterConfig;
        use crate::store::Cluster;
        let cfg = ClusterConfig {
            osds: 3,
            replicas: 1,
            ..Default::default()
        };
        let c = Cluster::with_defaults(&cfg);
        let mut names = Vec::new();
        for i in 0..80 {
            let n = format!("pm.{i}");
            c.write_object(0.0, &n, b"xx").unwrap();
            names.push(n);
        }
        // Snapshot maps around the topology change.
        let before = OsdMap::new(3, cfg.pg_count);
        let mut after = before.clone();
        after.add_osd(1.0);
        let (_, summary) = plan_moves(&before, &after, &names, 1);
        c.add_osd(1.0);
        let (moved, _) = c.rebalance().unwrap();
        assert_eq!(
            moved as usize, summary.moves,
            "plan and execution disagree"
        );
    }
}
