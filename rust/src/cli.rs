//! `skyhook-map` CLI implementation, as a library: the binary
//! (`main.rs`) is a thin wrapper around [`run`], so integration tests
//! can drive the exact command-line surface — flag parsing, hydration,
//! EXPLAIN rendering, the stats footer — and assert on its output.
//!
//! Subcommands (hand-rolled arg parsing; clap is unavailable offline):
//!
//! ```text
//! skyhook-map demo                          # quick end-to-end tour
//! skyhook-map put    --dataset D --rows N [--layout row|col] [--object-size 4MiB]
//!                    [--cluster-by COL] [--index COLS]
//! skyhook-map query  --dataset D [--filter EXPR] [--agg F:COL]... [--group C1,C2]
//!                    [--select C1,C2] [--sort SPEC] [--limit N]
//!                    [--pipe PIPELINE] [--explain] [--force-mode push|client]
//!                    [--cluster-by COL]
//! skyhook-map index  --dataset D --column C
//! skyhook-map transform --dataset D --layout row|col
//! skyhook-map compact --dataset D [--if-needed]
//! skyhook-map inspect                        # datasets + distribution
//! skyhook-map serve  --requests N            # synthetic load + metrics
//! ```
//!
//! Global flags: `--config FILE`, `--osds N`, `--use-pjrt`.

use crate::config::Config;
use crate::coordinator::{Request, Response};
use crate::dataset::metadata;
use crate::dataset::partition::PartitionSpec;
use crate::dataset::table::gen;
use crate::dataset::Layout;
use crate::launch::Stack;
use crate::skyhook::parse::{parse_aggregate, parse_pipeline, parse_predicate, parse_sort};
use crate::skyhook::{ExecMode, Query};
use crate::util::bytes::{fmt_size, parse_size};
use crate::{Error, Result};
use std::fmt::Write as _;

/// Tiny flag parser: `--key value` and bare `--switch`.
struct Flags {
    positional: Vec<String>,
    pairs: Vec<(String, Option<String>)>,
}

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut positional = Vec::new();
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let value = args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if value.is_some() {
                    i += 1;
                }
                pairs.push((key.to_string(), value));
            } else {
                positional.push(args[i].clone());
            }
            i += 1;
        }
        Flags { positional, pairs }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.pairs
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    fn has(&self, key: &str) -> bool {
        self.pairs.iter().any(|(k, _)| k == key)
    }
}

fn build_config(f: &Flags) -> Result<Config> {
    let mut cfg = match f.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config {
            artifacts_dir: "artifacts".into(),
            ..Default::default()
        },
    };
    if let Some(n) = f.get("osds") {
        cfg.cluster.osds = n
            .parse()
            .map_err(|_| Error::Config(format!("bad --osds {n}")))?;
        cfg.cluster.replicas = cfg.cluster.replicas.min(cfg.cluster.osds);
    }
    if f.has("use-pjrt") {
        cfg.driver.use_pjrt = true;
    }
    // --cluster-by overrides the config file's [dataset] cluster_by.
    if let Some(col) = f.get("cluster-by") {
        cfg.dataset.cluster_by = Some(col.to_string());
    }
    // --index (repeatable and/or comma-separated) overrides the config
    // file's [dataset] index.
    let ix = f.get_all("index");
    if !ix.is_empty() {
        cfg.dataset.index = Config::parse_index_cols(&ix.join(","))?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Run one CLI invocation, returning the text a terminal would show.
pub fn run(args: &[String]) -> Result<String> {
    let flags = Flags::parse(args);
    let cmd = flags.positional.first().map(String::as_str).unwrap_or("help");
    let mut out = String::new();
    match cmd {
        "demo" => cmd_demo(&flags, &mut out)?,
        "put" => cmd_put(&flags, &mut out)?,
        "query" => cmd_query(&flags, &mut out)?,
        "index" => cmd_index(&flags, &mut out)?,
        "transform" => cmd_transform(&flags, &mut out)?,
        "compact" => cmd_compact(&flags, &mut out)?,
        "inspect" => cmd_inspect(&flags, &mut out)?,
        "serve" => cmd_serve(&flags, &mut out)?,
        "help" | "--help" | "-h" => out.push_str(HELP),
        other => {
            return Err(Error::Invalid(format!("unknown command {other:?}")));
        }
    }
    Ok(out)
}

/// Binary entry point: prints the output (or the error) exactly like
/// the pre-library CLI did and returns the process exit code — usage
/// errors (unknown subcommand) print the help to stderr and exit 2,
/// runtime failures exit 1.
pub fn main_entry(args: &[String]) -> i32 {
    match run(args) {
        Ok(out) => {
            print!("{out}");
            0
        }
        Err(Error::Invalid(msg)) if msg.starts_with("unknown command") => {
            eprintln!("{msg}\n{HELP}");
            2
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

pub const HELP: &str = "\
skyhook-map — mapping datasets to object storage (paper reproduction)

USAGE:
  skyhook-map <demo|put|query|index|transform|compact|inspect|serve> [flags]

FLAGS:
  --config FILE     TOML config (see examples in README)
  --osds N          override cluster size
  --use-pjrt        run pushdown aggregation on the AOT JAX/Pallas kernels
  --dataset D       dataset name
  --rows N          synthetic rows for `put`
  --layout row|col  object layout
  --object-size SZ  partition target (e.g. 4MiB)
  --cluster-by COL  sort-aware clustered ingest: sort rows by COL at write
                    time (disjoint zone maps on COL; per-object top-k over
                    it becomes a bounded prefix read)
  --index COLS      keep a server-local secondary index on these columns
                    (repeatable or comma-separated, i64/f32 only): postings
                    are built per object at ingest and the planner serves
                    selective predicates via IndexScan probes
  --filter EXPR     predicate, e.g. 'val > 50 && flag == 1'
  --agg F:COL       aggregate (repeatable): count/sum/min/max/mean/var/median
  --group C1,C2     group-by key columns (with one or more --agg)
  --select C1,C2    projection for row queries
  --sort SPEC       order-by, e.g. 'val desc, ts' (row queries)
  --limit N         keep the first N rows (after sort; pushes down as
                    per-object top-k / head)
  --pipe PIPELINE   chained-pipeline syntax, replaces the flags above:
                    'filter val > 50 | select ts,val | sort val desc | limit 10'
                    'filter flag == 0 | agg sum:val,count:val | by sensor,flag'
                    'agg count:val | by sensor | having count(val) > 100'
  --explain         print the staged plan first: per-operator offload side,
                    the cost model's per-stage estimates, and — on clustered
                    datasets — which stages exploit the sorted layout
  --force-mode M    pin every sub-query to one side: push|client
                    (default: the planner picks the cheaper side per object)
  --client-side     shorthand for --force-mode client
  --if-needed       `compact` only when the driver's thresholds say so
                    (tombstone fraction or unsorted row-group fraction);
                    without it, compaction is unconditional
  --requests N      synthetic requests for `serve`
  --concurrency N   client threads for `serve` (default 1): requests are
                    issued through the router's query-admission gate from
                    N threads, each tagged with a rotating tenant
";

fn require_dataset(f: &Flags) -> Result<String> {
    f.get("dataset")
        .map(str::to_string)
        .ok_or_else(|| Error::Invalid("--dataset required".into()))
}

fn parse_layout(s: &str) -> Result<Layout> {
    match s {
        "row" => Ok(Layout::Row),
        "col" => Ok(Layout::Col),
        other => Err(Error::Invalid(format!("layout must be row|col, got {other}"))),
    }
}

/// The partition spec a command's flags/config describe.
fn partition_spec(cfg: &Config, target: u64) -> PartitionSpec {
    PartitionSpec {
        target_bytes: target,
        cluster_by: cfg.dataset.cluster_by.clone(),
        index_cols: cfg.dataset.index.clone(),
        ..Default::default()
    }
}

/// Create a synthetic dataset if it doesn't exist (the store is
/// in-memory, so each CLI invocation starts empty). Honors the
/// `--cluster-by` / `[dataset] cluster_by` knob, so a single `query`
/// invocation exercises the full clustered path: ingest → plan → read.
fn hydrate(stack: &Stack, cfg: &Config, dataset: &str, layout: Layout, out: &mut String) -> Result<()> {
    if metadata::load_meta(&stack.cluster, 0.0, dataset).is_err() {
        let batch = gen::sensor_table(20_000, cfg.cluster.seed);
        stack
            .driver
            .write_table(dataset, &batch, layout, &partition_spec(cfg, 64 * 1024), None)?;
        let how = match &cfg.dataset.cluster_by {
            Some(col) => format!(", clustered by {col:?}"),
            None => String::new(),
        };
        let _ = writeln!(out, "(hydrated synthetic dataset {dataset:?}: 20000 rows{how})");
    }
    Ok(())
}

fn cmd_demo(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let _ = writeln!(
        out,
        "cluster: {} OSDs, {} replicas, pjrt={}",
        cfg.cluster.osds,
        cfg.cluster.replicas,
        stack.engine.is_some()
    );
    let batch = gen::sensor_table(20_000, cfg.cluster.seed);
    let rep = stack.driver.write_table(
        "demo",
        &batch,
        Layout::Col,
        &partition_spec(&cfg, 64 * 1024),
        None,
    )?;
    let _ = writeln!(
        out,
        "put: {} rows -> {} objects ({}), sim {:.3}s",
        batch.nrows(),
        rep.objects,
        fmt_size(rep.bytes_written),
        rep.sim_seconds
    );
    let q = Query::scan("demo")
        .filter(parse_predicate("val > 60")?)
        .aggregate(crate::skyhook::AggFunc::Count, "val")
        .aggregate(crate::skyhook::AggFunc::Mean, "val");
    for (mode, label) in [
        (Some(ExecMode::Pushdown), "pushdown"),
        (Some(ExecMode::ClientSide), "client-side"),
    ] {
        let r = stack.driver.execute(&q, mode)?;
        let _ = writeln!(
            out,
            "{label:>12}: count={} mean={:.3} bytes_moved={} sim={:.4}s",
            r.aggregates[0],
            r.aggregates[1],
            fmt_size(r.stats.bytes_moved),
            r.stats.sim_seconds
        );
    }
    // A chained pipeline with per-operator offload: the filter and the
    // per-object top-k partial run server-side, merge+sort+truncate at
    // the driver.
    let tq = Query::scan("demo")
        .filter(parse_predicate("val > 60")?)
        .select(&["ts"])
        .top_k("val", true, 10);
    out.push_str(&stack.driver.explain(&tq, None)?);
    let r = stack.driver.execute(&tq, None)?;
    let _ = writeln!(
        out,
        "top-10 by val: {} rows returned, {} moved",
        r.rows.as_ref().map(|b| b.nrows()).unwrap_or(0),
        fmt_size(r.stats.bytes_moved)
    );
    let _ = writeln!(out, "demo OK");
    Ok(())
}

fn cmd_put(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let dataset = require_dataset(f)?;
    let rows: usize = f
        .get("rows")
        .unwrap_or("10000")
        .parse()
        .map_err(|_| Error::Invalid("bad --rows".into()))?;
    let layout = parse_layout(f.get("layout").unwrap_or("col"))?;
    let target = parse_size(f.get("object-size").unwrap_or("256KiB"))?;
    let batch = gen::sensor_table(rows, cfg.cluster.seed);
    let rep = stack
        .driver
        .write_table(&dataset, &batch, layout, &partition_spec(&cfg, target), None)?;
    let mut how = match &cfg.dataset.cluster_by {
        Some(col) => format!(" clustered by {col:?},"),
        None => String::new(),
    };
    if !cfg.dataset.index.is_empty() {
        let _ = write!(how, " indexed on {},", cfg.dataset.index.join(","));
    }
    let _ = writeln!(
        out,
        "wrote {} rows to {:?}:{} {} objects, {} total, sim {:.3}s wall {:.3}s",
        rows,
        dataset,
        how,
        rep.objects,
        fmt_size(rep.bytes_written),
        rep.sim_seconds,
        rep.wall_seconds
    );
    Ok(())
}

fn cmd_query(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let dataset = require_dataset(f)?;
    hydrate(&stack, &cfg, &dataset, Layout::Col, out)?;
    let q = if let Some(pipe) = f.get("pipe") {
        parse_pipeline(&dataset, pipe)?
    } else {
        let mut q = Query::scan(&dataset);
        if let Some(expr) = f.get("filter") {
            q = q.filter(parse_predicate(expr)?);
        }
        for spec in f.get_all("agg") {
            let a = parse_aggregate(spec)?;
            q = q.aggregate(a.func, &a.col);
        }
        if let Some(g) = f.get("group") {
            for col in g.split(',').map(str::trim).filter(|c| !c.is_empty()) {
                q = q.group(col);
            }
        }
        if let Some(sel) = f.get("select") {
            let cols: Vec<&str> = sel.split(',').map(str::trim).collect();
            q = q.select(&cols);
        }
        if let Some(spec) = f.get("sort") {
            q = q.sort_by(&parse_sort(spec)?);
        }
        if let Some(n) = f.get("limit") {
            q = q.limit(n.parse().map_err(|_| Error::Invalid("bad --limit".into()))?);
        }
        q
    };
    let mode = match f.get("force-mode") {
        Some("push") | Some("pushdown") | Some("server") => Some(ExecMode::Pushdown),
        Some("client") | Some("client-side") => Some(ExecMode::ClientSide),
        Some(other) => {
            return Err(Error::Invalid(format!(
                "--force-mode must be push|client, got {other:?}"
            )))
        }
        None => f.has("client-side").then_some(ExecMode::ClientSide),
    };
    if f.has("explain") {
        out.push_str(&stack.driver.explain(&q, mode)?);
    }
    let r = stack.driver.execute(&q, mode)?;
    if let Some(groups) = &r.groups {
        let keys = q.group_by.join(",");
        let aggs: Vec<String> = q.aggregates.iter().map(|a| a.to_string()).collect();
        let _ = writeln!(out, "{keys:<20} {}", aggs.join("  "));
        for (k, vals) in groups.iter().take(20) {
            let key: Vec<String> = k.iter().map(|x| x.to_string()).collect();
            let v: Vec<String> = vals.iter().map(|x| format!("{x:.4}")).collect();
            let _ = writeln!(out, "{:<20} {}", key.join(","), v.join("  "));
        }
        if groups.len() > 20 {
            let _ = writeln!(out, "... ({} groups)", groups.len());
        }
    } else if !r.aggregates.is_empty() {
        for (a, v) in q.aggregates.iter().zip(&r.aggregates) {
            let _ = writeln!(out, "{}({}) = {v:.6}", a.func.name(), a.col);
        }
    } else if let Some(rows) = &r.rows {
        let _ = writeln!(out, "{} rows, {} cols", rows.nrows(), rows.ncols());
        let show = rows.nrows().min(10);
        let names: Vec<&str> = rows.schema.columns.iter().map(|c| c.name.as_str()).collect();
        let _ = writeln!(out, "{}", names.join("\t"));
        for i in 0..show {
            let vals: Vec<String> = rows.columns.iter().map(|c| c.get_display(i)).collect();
            let _ = writeln!(out, "{}", vals.join("\t"));
        }
        if rows.nrows() > show {
            let _ = writeln!(out, "... ({} rows)", rows.nrows());
        }
    }
    let ratio = r
        .stats
        .est_ratio
        .map(|x| format!(", act/est {x:.2}"))
        .unwrap_or_default();
    let _ = writeln!(
        out,
        "-- {} objects ({} pruned, {} skipped), {} moved (est {}{ratio}), \
         {} reads coalesced, {} prefix reads, {} rows short-circuited, \
         {} index probes ({} postings), sim {:.4}s, wall {:.4}s, modes {}p/{}c",
        r.stats.objects,
        r.stats.objects_pruned,
        fmt_size(r.stats.bytes_skipped),
        fmt_size(r.stats.bytes_moved),
        fmt_size(r.stats.bytes_estimated),
        r.stats.reads_coalesced,
        r.stats.prefix_reads,
        r.stats.rows_short_circuited,
        r.stats.index_probes,
        r.stats.index_postings,
        r.stats.sim_seconds,
        r.stats.wall_seconds,
        r.stats.objects_pushdown,
        r.stats.objects_client
    );
    Ok(())
}

fn cmd_index(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let dataset = require_dataset(f)?;
    let column = f
        .get("column")
        .ok_or_else(|| Error::Invalid("--column required".into()))?;
    hydrate(&stack, &cfg, &dataset, Layout::Col, out)?;
    let n = stack.driver.build_index(&dataset, column)?;
    let _ = writeln!(out, "indexed {n} rows on {column:?}");
    Ok(())
}

fn cmd_transform(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let dataset = require_dataset(f)?;
    let layout = parse_layout(
        f.get("layout")
            .ok_or_else(|| Error::Invalid("--layout required".into()))?,
    )?;
    hydrate(&stack, &cfg, &dataset, Layout::Row, out)?;
    let rep = stack.driver.transform_layout(&dataset, layout)?;
    let _ = writeln!(
        out,
        "transformed {} objects to {} layout, sim {:.3}s",
        rep.objects,
        layout.name(),
        rep.sim_seconds
    );
    Ok(())
}

fn cmd_compact(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let dataset = require_dataset(f)?;
    hydrate(&stack, &cfg, &dataset, Layout::Col, out)?;
    let before = metadata::load_meta(&stack.cluster, 0.0, &dataset)?
        .0
        .mutability()
        .map(|m| m.total_tombstones())
        .unwrap_or(0);
    if f.has("if-needed") {
        if !stack.driver.maybe_compact(&dataset)? {
            let _ = writeln!(
                out,
                "compaction not needed for {dataset:?} (thresholds not met)"
            );
            return Ok(());
        }
    } else {
        stack.driver.compact(&dataset)?;
    }
    let (meta, _) = metadata::load_meta(&stack.cluster, 0.0, &dataset)?;
    let m = meta
        .mutability()
        .ok_or_else(|| Error::Query(format!("{dataset} is not a table dataset")))?;
    let _ = writeln!(
        out,
        "compacted {dataset:?}: generation {}, {} objects, {} live rows, {} tombstones dropped",
        m.generation,
        meta.object_names(&dataset).len(),
        meta.total_items(),
        before
    );
    Ok(())
}

fn cmd_inspect(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    // Hydrate something to look at.
    let batch = gen::sensor_table(5_000, cfg.cluster.seed);
    stack.driver.write_table(
        "inspect-demo",
        &batch,
        Layout::Col,
        &partition_spec(&cfg, 32 * 1024),
        None,
    )?;
    let _ = writeln!(out, "datasets:");
    for ds in metadata::list_datasets(&stack.cluster) {
        let (meta, _) = metadata::load_meta(&stack.cluster, 0.0, &ds)?;
        let clustered = match meta.cluster_column() {
            Some(c) => format!(", clustered by {c:?}"),
            None => String::new(),
        };
        let _ = writeln!(
            out,
            "  {ds}: {} objects, {} items{clustered}",
            meta.object_names(&ds).len(),
            meta.total_items()
        );
    }
    let _ = writeln!(out, "object distribution:");
    for (osd, n) in stack.cluster.object_distribution() {
        let _ = writeln!(out, "  osd.{osd}: {n} objects");
    }
    let _ = writeln!(
        out,
        "total stored: {}",
        fmt_size(stack.cluster.total_bytes_stored())
    );
    Ok(())
}

fn cmd_serve(f: &Flags, out: &mut String) -> Result<()> {
    let cfg = build_config(f)?;
    let stack = Stack::build(&cfg)?;
    let requests: usize = f
        .get("requests")
        .unwrap_or("200")
        .parse()
        .map_err(|_| Error::Invalid("bad --requests".into()))?;
    let concurrency: usize = f
        .get("concurrency")
        .unwrap_or("1")
        .parse()
        .map_err(|_| Error::Invalid("bad --concurrency".into()))?;
    if concurrency == 0 {
        return Err(Error::Invalid("--concurrency must be >= 1".into()));
    }
    // Seed data.
    stack.router.handle(Request::WriteTable {
        dataset: "served".into(),
        batch: gen::sensor_table(50_000, cfg.cluster.seed),
        layout: Layout::Col,
        spec: partition_spec(&cfg, 128 * 1024),
    })?;
    let seed = cfg.cluster.seed;
    let router = &stack.router;
    let shed = std::sync::atomic::AtomicUsize::new(0);
    let start = std::time::Instant::now();
    // N client threads share the router by reference; the query gate
    // bounds how many run at once, and an `Overloaded` shed is a normal
    // serving outcome here (counted, not fatal).
    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(concurrency);
        for t in 0..concurrency {
            let shed = &shed;
            handles.push(s.spawn(move || -> Result<()> {
                let mut rng = crate::util::rng::Xoshiro256::new(seed ^ (t as u64 + 1));
                let mut i = t;
                while i < requests {
                    let threshold = 30.0 + rng.f64() * 50.0;
                    let q = Query::scan("served")
                        .filter(crate::skyhook::Predicate::cmp(
                            "val",
                            crate::skyhook::CmpOp::Gt,
                            threshold,
                        ))
                        .aggregate(crate::skyhook::AggFunc::Mean, "val");
                    match router.handle(Request::Query {
                        query: q,
                        force_mode: None,
                        tenant: Some(format!("t{}", t % 4)),
                    }) {
                        Ok(Response::Query(_)) => {}
                        Ok(_) => unreachable!(),
                        Err(Error::Overloaded(_)) => {
                            shed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                        Err(e) => return Err(e),
                    }
                    i += concurrency;
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("serve worker panicked")?;
        }
        Ok(())
    })?;
    let dt = start.elapsed().as_secs_f64();
    // A serving deployment is also the write path: route a mutation mix
    // through the router once the query storm drains. Every mutation
    // consults the driver's compaction thresholds on the way out (and
    // SKYHOOK_FORCE_COMPACT=1 forces a re-clustering pass right here),
    // so this is the serve-integrated compaction trigger.
    stack.router.handle(Request::Append {
        dataset: "served".into(),
        batch: gen::sensor_table(2_000, seed ^ 0xbeef),
        target_bytes: 128 * 1024,
    })?;
    stack.router.handle(Request::Delete {
        dataset: "served".into(),
        object_index: 0,
        rows: (0..64).collect(),
    })?;
    let live = match stack.router.handle(Request::Query {
        query: Query::scan("served").aggregate(crate::skyhook::AggFunc::Count, "val"),
        force_mode: None,
        tenant: None,
    })? {
        Response::Query(r) => r.aggregates[0],
        _ => unreachable!(),
    };
    let _ = writeln!(
        out,
        "mutations: appended 2000 rows, tombstoned 64, compactions {}, live rows {}",
        router.metrics.counter("driver.compactions"),
        live
    );
    let _ = writeln!(
        out,
        "served {requests} requests in {dt:.2}s ({:.1} req/s, {concurrency} threads)",
        requests as f64 / dt
    );
    let _ = writeln!(
        out,
        "serving: rejected {}, shared-scan hits {}, in-flight now {}, query credits {}/{}",
        shed.load(std::sync::atomic::Ordering::Relaxed),
        router.metrics.counter("router.shared_scan_hits"),
        router.metrics.counter("router.queries_inflight"),
        router.query_credits_available(),
        router.query_gate().capacity()
    );
    let _ = writeln!(out, "{}", stack.router.metrics.report());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(a: &[&str]) -> Vec<String> {
        a.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_and_unknown_commands() {
        let out = run(&args(&["help"])).unwrap();
        assert!(out.contains("--cluster-by"));
        assert!(run(&args(&["frobnicate"])).is_err());
    }

    #[test]
    fn put_reports_clustering() {
        let out = run(&args(&[
            "put",
            "--dataset",
            "d",
            "--rows",
            "2000",
            "--cluster-by",
            "val",
        ]))
        .unwrap();
        assert!(out.contains("clustered by \"val\""), "{out}");
        // Ghost columns fail before any write.
        assert!(run(&args(&["put", "--dataset", "d", "--cluster-by", "nope"])).is_err());
    }

    #[test]
    fn put_with_index_builds_and_reports() {
        let out = run(&args(&[
            "put",
            "--dataset",
            "d",
            "--rows",
            "2000",
            "--index",
            "val,sensor",
        ]))
        .unwrap();
        assert!(out.contains("indexed on val,sensor"), "{out}");
        // Repeatable form parses the same list.
        let out = run(&args(&[
            "put",
            "--dataset",
            "d",
            "--rows",
            "500",
            "--index",
            "val",
            "--index",
            "sensor",
        ]))
        .unwrap();
        assert!(out.contains("indexed on val,sensor"), "{out}");
        // Ghost / non-indexable columns fail before any write.
        assert!(run(&args(&["put", "--dataset", "d", "--index", "nope"])).is_err());
        assert!(run(&args(&["put", "--dataset", "d", "--index", "val,val"])).is_err());
    }

    #[test]
    fn query_footer_carries_index_counters() {
        let out = run(&args(&[
            "query",
            "--dataset",
            "d",
            "--index",
            "val",
            "--filter",
            "val > 99",
            "--agg",
            "count:val",
            "--explain",
        ]))
        .unwrap();
        assert!(out.contains("index probes"), "{out}");
    }

    #[test]
    fn serve_concurrent_footer_reports_admission() {
        let out = run(&args(&[
            "serve",
            "--requests",
            "24",
            "--concurrency",
            "4",
            "--osds",
            "4",
        ]))
        .unwrap();
        assert!(out.contains("4 threads"), "{out}");
        assert!(out.contains("serving: rejected "), "{out}");
        assert!(out.contains("shared-scan hits"), "{out}");
        // The post-storm mutation mix routed through the router and the
        // query afterwards sees exactly the mutated row count — whether
        // or not SKYHOOK_FORCE_COMPACT=1 compacted in between.
        assert!(out.contains("live rows 51936"), "{out}");
        // All credits come back and nothing is left in flight once the
        // burst drains.
        assert!(out.contains("in-flight now 0"), "{out}");
        assert!(run(&args(&["serve", "--requests", "4", "--concurrency", "0"])).is_err());
    }

    #[test]
    fn compact_command_reports_generation() {
        let out = run(&args(&[
            "compact",
            "--dataset",
            "d",
            "--cluster-by",
            "val",
        ]))
        .unwrap();
        assert!(out.contains("generation 1"), "{out}");
        assert!(out.contains("20000 live rows"), "{out}");
        // A freshly hydrated dataset never meets the thresholds.
        let out = run(&args(&["compact", "--dataset", "d", "--if-needed"])).unwrap();
        let forced = std::env::var("SKYHOOK_FORCE_COMPACT").map_or(false, |v| v == "1");
        if forced {
            assert!(out.contains("generation 1"), "{out}");
        } else {
            assert!(out.contains("not needed"), "{out}");
        }
        assert!(run(&args(&["compact"])).is_err(), "--dataset required");
    }

    #[test]
    fn query_footer_carries_sortedness_counters() {
        let out = run(&args(&[
            "query",
            "--dataset",
            "d",
            "--select",
            "ts",
            "--sort",
            "val",
            "--limit",
            "5",
        ]))
        .unwrap();
        assert!(out.contains("prefix reads"), "{out}");
        assert!(out.contains("rows short-circuited"), "{out}");
    }
}
