//! The AOT compute runtime: PJRT client wrapper that loads the
//! JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and executes them from
//! the request path — Python is build-time only.

pub mod pjrt;

pub use pjrt::{
    empty_moments, merge_moments, BatchedCompute, EngineStats, Moments, PjrtEngine, COLS, ROWS,
};
