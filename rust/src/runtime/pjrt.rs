//! The PJRT compute runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (HLO text, see `python/compile/aot.py`) and executes them for the
//! Skyhook-Extension's pushdown hot path. Python is never involved: the
//! artifacts are self-contained HLO modules compiled by the PJRT CPU
//! client at startup.
//!
//! Threading: the `xla` crate's `PjRtClient` holds an `Rc` internally, so
//! it is confined to one **owner thread**; callers talk to it through a
//! channel. This also gives a natural dynamic-batching point — the owner
//! thread drains the queue and `masked_moments_multi` packs up to
//! [`COLS`] columns into one (ROWS, COLS) kernel launch (see
//! `coordinator::batcher` for the policy layer).

use crate::coordinator::batcher::{BatchPolicy, BatchStats, Batcher};
use crate::error::{Error, Result};
use crate::skyhook::ChunkCompute;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Fixed kernel chunk length (must match python/compile/kernels).
pub const ROWS: usize = 16384;
/// Fixed matrix width (must match python/compile/kernels/stats.py).
pub const COLS: usize = 8;

/// Moments vector layout: [count, sum, sumsq, min, max].
pub type Moments = [f64; 5];

/// Merge two moment partials.
pub fn merge_moments(a: Moments, b: Moments) -> Moments {
    [
        a[0] + b[0],
        a[1] + b[1],
        a[2] + b[2],
        if b[0] > 0.0 { a[3].min(b[3]) } else { a[3] },
        if b[0] > 0.0 { a[4].max(b[4]) } else { a[4] },
    ]
}

/// Identity element for [`merge_moments`].
pub fn empty_moments() -> Moments {
    [0.0, 0.0, 0.0, f64::INFINITY, f64::NEG_INFINITY]
}

enum Req {
    Moments {
        values: Vec<f32>,
        mask: Vec<bool>,
        resp: mpsc::Sender<Result<Moments>>,
    },
    MomentsMulti {
        cols: Vec<Vec<f32>>,
        mask: Vec<bool>,
        resp: mpsc::Sender<Result<Vec<Moments>>>,
    },
    Pipeline {
        matrix: Vec<f32>, // (ROWS, COLS) row-major
        col: usize,
        threshold: f32,
        valid: Vec<bool>,
        resp: mpsc::Sender<Result<Vec<Moments>>>,
    },
    Transform {
        data: Vec<f32>,
        to_col: bool,
        resp: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Runtime counters.
#[derive(Debug, Default)]
pub struct EngineStats {
    pub kernel_launches: AtomicU64,
    pub elements_processed: AtomicU64,
}

/// Handle to the engine's owner thread. Cheap to clone via `Arc`.
pub struct PjrtEngine {
    tx: Mutex<mpsc::Sender<Req>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    stats: Arc<EngineStats>,
}

impl PjrtEngine {
    /// Start the engine: spawn the owner thread, create the PJRT CPU
    /// client, and eagerly compile every artifact in `dir`. Fails if the
    /// client cannot start or any artifact is missing/invalid.
    pub fn load(dir: impl AsRef<Path>) -> Result<Arc<Self>> {
        let dir = dir.as_ref().to_path_buf();
        let stats = Arc::new(EngineStats::default());
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats2 = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || owner_thread(dir, rx, ready_tx, stats2))
            .map_err(|e| Error::Runtime(format!("spawn engine: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died during init".into()))??;
        Ok(Arc::new(Self {
            tx: Mutex::new(tx),
            handle: Mutex::new(Some(handle)),
            stats,
        }))
    }

    fn send(&self, req: Req) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| Error::Runtime("engine thread gone".into()))
    }

    /// Masked moments of an arbitrary-length column (padded/looped over
    /// fixed-size kernel chunks; partials merged here).
    pub fn moments(&self, values: &[f32], mask: &[bool]) -> Result<Moments> {
        if values.len() != mask.len() {
            return Err(Error::Invalid("values/mask length mismatch".into()));
        }
        let (tx, rx) = mpsc::channel();
        self.send(Req::Moments {
            values: values.to_vec(),
            mask: mask.to_vec(),
            resp: tx,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))?
    }

    /// Masked moments of several equal-length columns sharing one mask —
    /// batched into (ROWS, COLS) matrix kernel launches.
    pub fn moments_multi(&self, cols: &[&[f32]], mask: &[bool]) -> Result<Vec<Moments>> {
        if cols.is_empty() {
            return Ok(Vec::new());
        }
        for c in cols {
            if c.len() != mask.len() {
                return Err(Error::Invalid("column/mask length mismatch".into()));
            }
        }
        let (tx, rx) = mpsc::channel();
        self.send(Req::MomentsMulti {
            cols: cols.iter().map(|c| c.to_vec()).collect(),
            mask: mask.to_vec(),
            resp: tx,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))?
    }

    /// The fused predicate+aggregate pipeline over one (ROWS, COLS) chunk.
    pub fn chunk_pipeline(
        &self,
        matrix: &[f32],
        col: usize,
        threshold: f32,
        valid: &[bool],
    ) -> Result<Vec<Moments>> {
        if matrix.len() != ROWS * COLS || valid.len() != ROWS || col >= COLS {
            return Err(Error::Invalid("bad pipeline shapes".into()));
        }
        let (tx, rx) = mpsc::channel();
        self.send(Req::Pipeline {
            matrix: matrix.to_vec(),
            col,
            threshold,
            valid: valid.to_vec(),
            resp: tx,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))?
    }

    /// Layout transform of one (ROWS, COLS) chunk (row→col or back).
    pub fn transform(&self, data: &[f32], to_col: bool) -> Result<Vec<f32>> {
        if data.len() != ROWS * COLS {
            return Err(Error::Invalid("bad transform shape".into()));
        }
        let (tx, rx) = mpsc::channel();
        self.send(Req::Transform {
            data: data.to_vec(),
            to_col,
            resp: tx,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine dropped request".into()))?
    }

    /// Total kernel launches so far.
    pub fn kernel_launches(&self) -> u64 {
        self.stats.kernel_launches.load(Ordering::Relaxed)
    }

    /// Total elements processed.
    pub fn elements_processed(&self) -> u64 {
        self.stats.elements_processed.load(Ordering::Relaxed)
    }
}

impl Drop for PjrtEngine {
    fn drop(&mut self) {
        let _ = self.send(Req::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl ChunkCompute for PjrtEngine {
    fn masked_moments(&self, values: &[f32], mask: &[bool]) -> Result<[f64; 5]> {
        self.moments(values, mask)
    }

    fn masked_moments_multi(&self, cols: &[&[f32]], mask: &[bool]) -> Result<Vec<[f64; 5]>> {
        self.moments_multi(cols, mask)
    }
}

/// [`ChunkCompute`] adapter that funnels moment requests through the
/// dynamic [`Batcher`] in front of the engine's owner thread, so
/// concurrent sub-queries amortize dispatch over one queue drain.
///
/// Each submitted item is a whole multi-column request; within an item
/// `moments_multi` already packs up to [`COLS`] columns per kernel
/// launch. Items are *not* fused across sub-queries — the `stats`
/// executable shares one mask across its matrix, and different
/// sub-queries carry different masks — so the batcher amortizes queue
/// dispatch and channel round-trips, not launches.
pub struct BatchedCompute {
    batcher: Arc<Batcher<MomentsReq, Result<Vec<Moments>>>>,
}

type MomentsReq = (Vec<Vec<f32>>, Vec<bool>);

impl BatchedCompute {
    pub fn new(engine: Arc<PjrtEngine>) -> Self {
        let batcher = Batcher::new(BatchPolicy::default(), move |reqs: Vec<MomentsReq>| {
            reqs.into_iter()
                .map(|(cols, mask)| {
                    let refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
                    engine.moments_multi(&refs, &mask)
                })
                .collect()
        });
        Self { batcher }
    }

    /// Batching counters (batches flushed, items submitted, full batches).
    pub fn batch_stats(&self) -> BatchStats {
        self.batcher.stats()
    }
}

impl ChunkCompute for BatchedCompute {
    fn masked_moments(&self, values: &[f32], mask: &[bool]) -> Result<[f64; 5]> {
        let out = self
            .batcher
            .submit((vec![values.to_vec()], mask.to_vec()))?;
        out.into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("empty moments response".into()))
    }

    fn masked_moments_multi(&self, cols: &[&[f32]], mask: &[bool]) -> Result<Vec<[f64; 5]>> {
        self.batcher
            .submit((cols.iter().map(|c| c.to_vec()).collect(), mask.to_vec()))
    }
}

// ---- owner thread ----------------------------------------------------------

struct Exes {
    // Held so executables outlive the client that compiled them.
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: HashMap<&'static str, xla::PjRtLoadedExecutable>,
}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

fn compile(client: &xla::PjRtClient, dir: &Path, name: &str) -> Result<xla::PjRtLoadedExecutable> {
    let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
    let path_s = path
        .to_str()
        .ok_or_else(|| Error::Runtime("bad artifact path".into()))?;
    if !path.exists() {
        return Err(Error::Runtime(format!(
            "missing artifact {path_s} — run `make artifacts`"
        )));
    }
    let proto = xla::HloModuleProto::from_text_file(path_s).map_err(xerr)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(xerr)
}

fn owner_thread(
    dir: PathBuf,
    rx: mpsc::Receiver<Req>,
    ready: mpsc::Sender<Result<()>>,
    stats: Arc<EngineStats>,
) {
    let init = (|| -> Result<Exes> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        let mut exes = HashMap::new();
        for name in [
            "filter_agg",
            "stats",
            "chunk_pipeline",
            "transform_r2c",
            "transform_c2r",
        ] {
            exes.insert(name, compile(&client, &dir, name)?);
        }
        Ok(Exes { client, exes })
    })();
    let exes = match init {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Moments { values, mask, resp } => {
                let _ = resp.send(run_moments(&exes, &stats, &values, &mask));
            }
            Req::MomentsMulti { cols, mask, resp } => {
                let refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
                let _ = resp.send(run_moments_multi(&exes, &stats, &refs, &mask));
            }
            Req::Pipeline {
                matrix,
                col,
                threshold,
                valid,
                resp,
            } => {
                let _ = resp.send(run_pipeline(&exes, &stats, &matrix, col, threshold, &valid));
            }
            Req::Transform { data, to_col, resp } => {
                let _ = resp.send(run_transform(&exes, &stats, &data, to_col));
            }
        }
    }
}

fn literal_1d(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

fn literal_2d(xs: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(xs)
        .reshape(&[rows as i64, cols as i64])
        .map_err(xerr)
}

fn exec_to_f32s(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<f32>> {
    let result = exe.execute::<xla::Literal>(args).map_err(xerr)?;
    let lit = result[0][0].to_literal_sync().map_err(xerr)?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(xerr)?;
    out.to_vec::<f32>().map_err(xerr)
}

fn mask_to_f32(mask: &[bool], out: &mut [f32]) {
    for (o, &m) in out.iter_mut().zip(mask) {
        *o = if m { 1.0 } else { 0.0 };
    }
}

fn moments_from_row(row: &[f32]) -> Moments {
    [
        row[0] as f64,
        row[1] as f64,
        row[2] as f64,
        row[3] as f64,
        row[4] as f64,
    ]
}

fn run_moments(
    exes: &Exes,
    stats: &EngineStats,
    values: &[f32],
    mask: &[bool],
) -> Result<Moments> {
    let exe = &exes.exes["filter_agg"];
    let mut acc = empty_moments();
    let mut vbuf = vec![0f32; ROWS];
    let mut mbuf = vec![0f32; ROWS];
    let mut off = 0;
    // Always run at least one chunk so empty input returns zeros.
    loop {
        let n = (values.len() - off).min(ROWS);
        vbuf[..n].copy_from_slice(&values[off..off + n]);
        vbuf[n..].fill(0.0);
        mask_to_f32(&mask[off..off + n], &mut mbuf[..n]);
        mbuf[n..].fill(0.0);
        let out = exec_to_f32s(exe, &[literal_1d(&vbuf), literal_1d(&mbuf)])?;
        stats.kernel_launches.fetch_add(1, Ordering::Relaxed);
        stats
            .elements_processed
            .fetch_add(ROWS as u64, Ordering::Relaxed);
        let part = moments_from_row(&out);
        acc = merge_moments(acc, part);
        off += n;
        if off >= values.len() {
            break;
        }
    }
    Ok(acc)
}

fn run_moments_multi(
    exes: &Exes,
    stats: &EngineStats,
    cols: &[&[f32]],
    mask: &[bool],
) -> Result<Vec<Moments>> {
    let exe = &exes.exes["stats"];
    let n_cols = cols.len();
    let mut acc = vec![empty_moments(); n_cols];
    let len = mask.len();
    let mut matrix = vec![0f32; ROWS * COLS];
    let mut mbuf = vec![0f32; ROWS];
    let mut off = 0;
    loop {
        let n = (len - off).min(ROWS);
        // Pack column groups of COLS at a time.
        for group_start in (0..n_cols).step_by(COLS) {
            let group = &cols[group_start..(group_start + COLS).min(n_cols)];
            matrix.fill(0.0);
            for (ci, col) in group.iter().enumerate() {
                for r in 0..n {
                    matrix[r * COLS + ci] = col[off + r];
                }
            }
            mask_to_f32(&mask[off..off + n], &mut mbuf[..n]);
            mbuf[n..].fill(0.0);
            let out = exec_to_f32s(
                exe,
                &[literal_2d(&matrix, ROWS, COLS)?, literal_1d(&mbuf)],
            )?;
            stats.kernel_launches.fetch_add(1, Ordering::Relaxed);
            stats
                .elements_processed
                .fetch_add((ROWS * COLS) as u64, Ordering::Relaxed);
            for (ci, _) in group.iter().enumerate() {
                let row = &out[ci * 8..ci * 8 + 8];
                acc[group_start + ci] = merge_moments(acc[group_start + ci], moments_from_row(row));
            }
        }
        off += n;
        if off >= len {
            break;
        }
    }
    Ok(acc)
}

fn run_pipeline(
    exes: &Exes,
    stats: &EngineStats,
    matrix: &[f32],
    col: usize,
    threshold: f32,
    valid: &[bool],
) -> Result<Vec<Moments>> {
    let exe = &exes.exes["chunk_pipeline"];
    let mut sel = vec![0f32; COLS];
    sel[col] = 1.0;
    let mut vbuf = vec![0f32; ROWS];
    mask_to_f32(valid, &mut vbuf);
    let out = exec_to_f32s(
        exe,
        &[
            literal_2d(matrix, ROWS, COLS)?,
            literal_1d(&sel),
            literal_1d(&[threshold]),
            literal_1d(&vbuf),
        ],
    )?;
    stats.kernel_launches.fetch_add(1, Ordering::Relaxed);
    stats
        .elements_processed
        .fetch_add((ROWS * COLS) as u64, Ordering::Relaxed);
    Ok((0..COLS)
        .map(|c| moments_from_row(&out[c * 8..c * 8 + 8]))
        .collect())
}

fn run_transform(
    exes: &Exes,
    stats: &EngineStats,
    data: &[f32],
    to_col: bool,
) -> Result<Vec<f32>> {
    let name = if to_col { "transform_r2c" } else { "transform_c2r" };
    let exe = &exes.exes[name];
    let lit = if to_col {
        literal_2d(data, ROWS, COLS)?
    } else {
        literal_2d(data, COLS, ROWS)?
    };
    let out = exec_to_f32s(exe, &[lit])?;
    stats.kernel_launches.fetch_add(1, Ordering::Relaxed);
    stats
        .elements_processed
        .fetch_add((ROWS * COLS) as u64, Ordering::Relaxed);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use once_cell::sync::Lazy;

    /// One engine for the whole test binary (artifact compile ~seconds).
    static ENGINE: Lazy<Option<Arc<PjrtEngine>>> =
        Lazy::new(|| PjrtEngine::load(artifacts_dir()).ok());

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Arc<PjrtEngine>> {
        ENGINE.clone()
    }

    macro_rules! require_engine {
        () => {
            match engine() {
                Some(e) => e,
                None => {
                    eprintln!("skipping: artifacts not built (run `make artifacts`)");
                    return;
                }
            }
        };
    }

    #[test]
    fn moments_match_direct() {
        let e = require_engine!();
        let values: Vec<f32> = (0..1000).map(|i| (i as f32) * 0.5 - 100.0).collect();
        let mask: Vec<bool> = (0..1000).map(|i| i % 3 == 0).collect();
        let m = e.moments(&values, &mask).unwrap();
        let mut want = empty_moments();
        for (i, &v) in values.iter().enumerate() {
            if mask[i] {
                want = merge_moments(want, [1.0, v as f64, (v * v) as f64, v as f64, v as f64]);
            }
        }
        assert_eq!(m[0], want[0]);
        assert!((m[1] - want[1]).abs() < 1e-2, "{} vs {}", m[1], want[1]);
        assert!((m[2] - want[2]).abs() / want[2].abs() < 1e-4);
        assert_eq!(m[3], want[3]);
        assert_eq!(m[4], want[4]);
    }

    #[test]
    fn moments_longer_than_one_chunk() {
        let e = require_engine!();
        let n = ROWS * 2 + 77;
        let values: Vec<f32> = (0..n).map(|i| ((i * 31) % 1000) as f32).collect();
        let mask = vec![true; n];
        let m = e.moments(&values, &mask).unwrap();
        assert_eq!(m[0] as usize, n);
        let want_sum: f64 = values.iter().map(|&v| v as f64).sum();
        assert!((m[1] - want_sum).abs() / want_sum < 1e-5);
        assert_eq!(m[3], 0.0);
        assert_eq!(m[4], 999.0);
    }

    #[test]
    fn moments_empty_and_all_false() {
        let e = require_engine!();
        let m = e.moments(&[], &[]).unwrap();
        assert_eq!(m[0], 0.0);
        let m = e.moments(&[1.0, 2.0], &[false, false]).unwrap();
        assert_eq!(m[0], 0.0);
        assert_eq!(m[1], 0.0);
    }

    #[test]
    fn moments_multi_matches_single() {
        let e = require_engine!();
        let a: Vec<f32> = (0..500).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..500).map(|i| (i as f32) * -2.0).collect();
        let mask: Vec<bool> = (0..500).map(|i| i % 2 == 0).collect();
        let multi = e.moments_multi(&[&a, &b], &mask).unwrap();
        let sa = e.moments(&a, &mask).unwrap();
        let sb = e.moments(&b, &mask).unwrap();
        assert_eq!(multi.len(), 2);
        for k in 0..5 {
            assert!((multi[0][k] - sa[k]).abs() < 1e-3, "col a comp {k}");
            assert!((multi[1][k] - sb[k]).abs() < 1e-3, "col b comp {k}");
        }
    }

    #[test]
    fn moments_multi_more_than_cols_columns() {
        let e = require_engine!();
        let cols: Vec<Vec<f32>> = (0..COLS + 3)
            .map(|c| (0..100).map(|i| (i + c) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let mask = vec![true; 100];
        let out = e.moments_multi(&refs, &mask).unwrap();
        assert_eq!(out.len(), COLS + 3);
        for (c, m) in out.iter().enumerate() {
            assert_eq!(m[0], 100.0);
            assert_eq!(m[3], c as f64); // min = c
            assert_eq!(m[4], (99 + c) as f64);
        }
    }

    #[test]
    fn pipeline_matches_manual() {
        let e = require_engine!();
        let mut matrix = vec![0f32; ROWS * COLS];
        for r in 0..ROWS {
            for c in 0..COLS {
                matrix[r * COLS + c] = ((r * 7 + c * 13) % 100) as f32;
            }
        }
        let valid = vec![true; ROWS];
        let col = 2;
        let threshold = 50.0;
        let out = e.chunk_pipeline(&matrix, col, threshold, &valid).unwrap();
        // Manual.
        let mut want = vec![empty_moments(); COLS];
        for r in 0..ROWS {
            if matrix[r * COLS + col] > threshold {
                for c in 0..COLS {
                    let v = matrix[r * COLS + c] as f64;
                    want[c] = merge_moments(want[c], [1.0, v, v * v, v, v]);
                }
            }
        }
        for c in 0..COLS {
            assert_eq!(out[c][0], want[c][0], "count col {c}");
            assert!((out[c][1] - want[c][1]).abs() / want[c][1].max(1.0) < 1e-4);
            assert_eq!(out[c][3], want[c][3]);
            assert_eq!(out[c][4], want[c][4]);
        }
    }

    #[test]
    fn transform_roundtrip() {
        let e = require_engine!();
        let data: Vec<f32> = (0..ROWS * COLS).map(|i| i as f32).collect();
        let t = e.transform(&data, true).unwrap();
        // t is (COLS, ROWS): element (c, r) = data[r * COLS + c].
        assert_eq!(t.len(), ROWS * COLS);
        assert_eq!(t[0], data[0]);
        assert_eq!(t[1], data[COLS]); // (0,1) <- row 1, col 0
        let back = e.transform(&t, false).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn engine_is_usable_from_many_threads() {
        let e = require_engine!();
        let mut handles = Vec::new();
        for t in 0..4 {
            let e = Arc::clone(&e);
            handles.push(std::thread::spawn(move || {
                let values: Vec<f32> = (0..200).map(|i| (i + t) as f32).collect();
                let mask = vec![true; 200];
                let m = e.moments(&values, &mask).unwrap();
                assert_eq!(m[0], 200.0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stats_counters_advance() {
        let e = require_engine!();
        let before = e.kernel_launches();
        e.moments(&[1.0; 10], &[true; 10]).unwrap();
        assert!(e.kernel_launches() > before);
        assert!(e.elements_processed() > 0);
    }

    #[test]
    fn shape_validation() {
        let e = require_engine!();
        assert!(e.moments(&[1.0], &[true, false]).is_err());
        assert!(e.chunk_pipeline(&[0.0; 8], 0, 0.0, &[true; ROWS]).is_err());
        assert!(e
            .chunk_pipeline(&vec![0.0; ROWS * COLS], COLS, 0.0, &vec![true; ROWS])
            .is_err());
        assert!(e.transform(&[0.0; 3], true).is_err());
    }

    #[test]
    fn batched_compute_matches_direct_engine() {
        let e = require_engine!();
        let batched = BatchedCompute::new(Arc::clone(&e));
        let a: Vec<f32> = (0..700).map(|i| (i as f32) * 0.25).collect();
        let b: Vec<f32> = (0..700).map(|i| 350.0 - i as f32).collect();
        let mask: Vec<bool> = (0..700).map(|i| i % 5 != 0).collect();
        let direct = e.moments_multi(&[&a, &b], &mask).unwrap();
        let via_multi = batched.masked_moments_multi(&[&a, &b], &mask).unwrap();
        assert_eq!(via_multi, direct);
        let via_single = batched.masked_moments(&a, &mask).unwrap();
        assert_eq!(via_single, direct[0]);
        let s = batched.batch_stats();
        assert_eq!(s.items, 2);
        assert!(s.batches >= 1);
        // Errors propagate through the batcher unchanged.
        assert!(batched.masked_moments(&a, &mask[..10]).is_err());
    }

    #[test]
    fn missing_artifacts_fail_cleanly() {
        let err = PjrtEngine::load("/nonexistent/dir");
        assert!(err.is_err());
    }
}
