//! The application-facing access-library API (Figure 1a's top half).
//!
//! Mirrors the miniature of HDF5 that the paper's discussion needs: files
//! contain named n-dimensional f32 datasets with chunked layout; reads and
//! writes are hyperslab selections; datasets carry string attributes.
//! Applications program against [`VolFile`]; the storage-facing half is a
//! [`VolBackend`] chosen at open time — swapping the backend never changes
//! application code, which is the paper's independent-evolution goal
//! (§2 goal 3).

use crate::dataset::{Dataspace, Hyperslab};
use crate::error::{Error, Result};

/// Virtual time + value pair re-exported for backends.
pub use crate::store::Timed;

/// The storage-facing interface (the VOL boundary, Figure 1b). All
/// methods carry virtual time so experiments can measure makespan.
pub trait VolBackend: Send {
    /// Human-readable backend name (for reports).
    fn name(&self) -> &'static str;

    /// Create a chunked f32 dataset.
    fn create(
        &mut self,
        at: f64,
        dataset: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<Timed<()>>;

    /// Write a hyperslab (data is row-major, `slab.numel()` long).
    fn write_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        data: &[f32],
    ) -> Result<Timed<()>>;

    /// Read a hyperslab.
    fn read_slab(&mut self, at: f64, dataset: &str, slab: &Hyperslab)
        -> Result<Timed<Vec<f32>>>;

    /// Dataset's dataspace + chunk shape.
    fn shape(&mut self, at: f64, dataset: &str) -> Result<Timed<(Dataspace, Vec<u64>)>>;

    /// Set / get a string attribute on a dataset.
    fn set_attr(&mut self, at: f64, dataset: &str, key: &str, value: &str) -> Result<Timed<()>>;
    fn get_attr(&mut self, at: f64, dataset: &str, key: &str) -> Result<Timed<Option<String>>>;

    /// Datasets in this file.
    fn list(&mut self, at: f64) -> Result<Timed<Vec<String>>>;
}

/// An open "file" — the application-facing handle.
pub struct VolFile {
    backend: Box<dyn VolBackend>,
    /// Virtual clock of this client session.
    now: f64,
}

impl VolFile {
    /// Open with an explicit backend (the VOL plugin selection).
    pub fn open(backend: Box<dyn VolBackend>) -> Self {
        Self { backend, now: 0.0 }
    }

    /// Backend name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The session's current virtual time (advances with every call).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Reset the session clock (between bench cases).
    pub fn reset_clock(&mut self) {
        self.now = 0.0;
    }

    /// Create a chunked f32 dataset.
    pub fn create_dataset(
        &mut self,
        name: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<()> {
        if chunk.len() != space.ndim() {
            return Err(Error::Invalid("chunk rank != space rank".into()));
        }
        let t = self.backend.create(self.now, name, space, chunk)?;
        self.now = t.finish;
        Ok(())
    }

    /// Write a hyperslab of data.
    pub fn write(&mut self, dataset: &str, slab: &Hyperslab, data: &[f32]) -> Result<()> {
        if data.len() as u64 != slab.numel() {
            return Err(Error::Invalid(format!(
                "data len {} != slab numel {}",
                data.len(),
                slab.numel()
            )));
        }
        let t = self.backend.write_slab(self.now, dataset, slab, data)?;
        self.now = t.finish;
        Ok(())
    }

    /// Write the full dataset.
    pub fn write_all(&mut self, dataset: &str, data: &[f32]) -> Result<()> {
        let (space, _) = self.shape(dataset)?;
        self.write(dataset, &Hyperslab::whole(&space), data)
    }

    /// Read a hyperslab.
    pub fn read(&mut self, dataset: &str, slab: &Hyperslab) -> Result<Vec<f32>> {
        let t = self.backend.read_slab(self.now, dataset, slab)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// Read the full dataset.
    pub fn read_all(&mut self, dataset: &str) -> Result<Vec<f32>> {
        let (space, _) = self.shape(dataset)?;
        self.read(dataset, &Hyperslab::whole(&space))
    }

    /// Dataspace + chunk shape of a dataset.
    pub fn shape(&mut self, dataset: &str) -> Result<(Dataspace, Vec<u64>)> {
        let t = self.backend.shape(self.now, dataset)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// Attributes.
    pub fn set_attr(&mut self, dataset: &str, key: &str, value: &str) -> Result<()> {
        let t = self.backend.set_attr(self.now, dataset, key, value)?;
        self.now = t.finish;
        Ok(())
    }

    pub fn get_attr(&mut self, dataset: &str, key: &str) -> Result<Option<String>> {
        let t = self.backend.get_attr(self.now, dataset, key)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// List datasets.
    pub fn list_datasets(&mut self) -> Result<Vec<String>> {
        let t = self.backend.list(self.now)?;
        self.now = t.finish;
        Ok(t.value)
    }
}

/// Shared conformance suite: every backend must pass these behaviours.
/// Called by each backend's tests (and the integration tests) — the
/// executable statement of "the application sees the same data model"
/// (§4.1).
#[cfg(test)]
pub fn conformance(make: impl Fn() -> VolFile) {
    use crate::dataset::Dataspace;

    // create / shape / list
    let mut f = make();
    let space = Dataspace::new(&[8, 10]).unwrap();
    f.create_dataset("d", &space, &[4, 5]).unwrap();
    let (sp, ch) = f.shape("d").unwrap();
    assert_eq!(sp, space);
    assert_eq!(ch, vec![4, 5]);
    assert_eq!(f.list_datasets().unwrap(), vec!["d".to_string()]);

    // duplicate create fails
    assert!(f.create_dataset("d", &space, &[4, 5]).is_err());
    // missing dataset fails
    assert!(f.read_all("nope").is_err());

    // full write + read
    let data: Vec<f32> = (0..80).map(|i| i as f32).collect();
    f.write_all("d", &data).unwrap();
    assert_eq!(f.read_all("d").unwrap(), data);

    // partial hyperslab read (crosses chunk boundaries)
    let slab = Hyperslab::new(&[1, 3], &[3, 4]).unwrap();
    let got = f.read("d", &slab).unwrap();
    let mut want = Vec::new();
    for r in 1..4 {
        for c in 3..7 {
            want.push((r * 10 + c) as f32);
        }
    }
    assert_eq!(got, want);

    // partial hyperslab write (read-modify-write across chunks)
    let wslab = Hyperslab::new(&[2, 2], &[2, 3]).unwrap();
    f.write("d", &wslab, &[100.0, 101.0, 102.0, 110.0, 111.0, 112.0])
        .unwrap();
    let all = f.read_all("d").unwrap();
    assert_eq!(all[2 * 10 + 2], 100.0);
    assert_eq!(all[3 * 10 + 4], 112.0);
    assert_eq!(all[2 * 10 + 5], 25.0, "untouched element changed");

    // wrong data length rejected
    assert!(f.write("d", &wslab, &[1.0]).is_err());
    // out-of-bounds slab rejected
    let oob = Hyperslab::new(&[7, 9], &[2, 2]).unwrap();
    assert!(f.read("d", &oob).is_err());

    // attributes
    f.set_attr("d", "units", "kelvin").unwrap();
    assert_eq!(f.get_attr("d", "units").unwrap().unwrap(), "kelvin");
    assert!(f.get_attr("d", "none").unwrap().is_none());
    assert!(f.set_attr("ghost", "k", "v").is_err());

    // virtual time advances
    assert!(f.now() > 0.0);

    // 1-d dataset
    let mut f = make();
    let space1 = Dataspace::new(&[100]).unwrap();
    f.create_dataset("one", &space1, &[32]).unwrap();
    let data: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
    f.write_all("one", &data).unwrap();
    let tail = f.read("one", &Hyperslab::new(&[90], &[10]).unwrap()).unwrap();
    assert_eq!(tail, &data[90..]);

    // 3-d dataset with uneven chunks
    let mut f = make();
    let space3 = Dataspace::new(&[3, 5, 7]).unwrap();
    f.create_dataset("three", &space3, &[2, 3, 4]).unwrap();
    let data: Vec<f32> = (0..105).map(|i| i as f32 * 0.25).collect();
    f.write_all("three", &data).unwrap();
    assert_eq!(f.read_all("three").unwrap(), data);
    let slab = Hyperslab::new(&[1, 2, 3], &[2, 2, 2]).unwrap();
    let got = f.read("three", &slab).unwrap();
    let strides = space3.strides();
    let mut want = Vec::new();
    for a in 1..3u64 {
        for b in 2..4u64 {
            for c in 3..5u64 {
                want.push((a * strides[0] + b * strides[1] + c) as f32 * 0.25);
            }
        }
    }
    assert_eq!(got, want);
}
