//! The application-facing access-library API (Figure 1a's top half).
//!
//! Mirrors the miniature of HDF5 that the paper's discussion needs: files
//! contain named n-dimensional f32 datasets with chunked layout; reads and
//! writes are hyperslab selections; datasets carry string attributes.
//! Applications program against [`VolFile`]; the storage-facing half is a
//! [`VolBackend`] chosen at open time — swapping the backend never changes
//! application code, which is the paper's independent-evolution goal
//! (§2 goal 3).

use crate::dataset::table::{Batch, Column};
use crate::dataset::{DType, Dataspace, Hyperslab, TableSchema};
use crate::error::{Error, Result};
use crate::skyhook::exec_kernel::filter_mask;
use crate::skyhook::query::Predicate;

/// Virtual time + value pair re-exported for backends.
pub use crate::store::Timed;

/// Mask a dense value buffer against a predicate over the implicit
/// value column `"v"`: matching elements keep their stored bits,
/// non-matching ones become canonical `f32::NAN`. Returns the masked
/// buffer plus how many elements the filter kept. The single
/// definition every client-side filtered read goes through — it runs
/// the same `filter_mask` kernel as the `hdf5.read_slab_where` server
/// handler, so the mask is bit-identical on both sides of the offload
/// boundary.
pub fn apply_value_mask(vals: Vec<f32>, predicate: &Predicate) -> Result<(Vec<f32>, u64)> {
    if matches!(predicate, Predicate::True) {
        let n = vals.len() as u64;
        return Ok((vals, n));
    }
    for col in predicate.columns() {
        if col != "v" {
            return Err(Error::Invalid(format!(
                "filtered reads see a single value column \"v\", got \"{col}\""
            )));
        }
    }
    let schema = TableSchema::new(&[("v", DType::F32)]);
    let batch = Batch::new(schema, vec![Column::F32(vals)])?;
    let (mask, _work) = filter_mask(&batch, predicate, &[])?;
    let Some(Column::F32(mut vals)) = batch.columns.into_iter().next() else {
        return Err(Error::Runtime("value column changed dtype".into()));
    };
    let mut matched = 0u64;
    for (v, keep) in vals.iter_mut().zip(&mask) {
        if *keep {
            matched += 1;
        } else {
            *v = f32::NAN;
        }
    }
    Ok((vals, matched))
}

/// The storage-facing interface (the VOL boundary, Figure 1b). All
/// methods carry virtual time so experiments can measure makespan.
pub trait VolBackend: Send {
    /// Human-readable backend name (for reports).
    fn name(&self) -> &'static str;

    /// Create a chunked f32 dataset.
    fn create(
        &mut self,
        at: f64,
        dataset: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<Timed<()>>;

    /// Write a hyperslab (data is row-major, `slab.numel()` long).
    fn write_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        data: &[f32],
    ) -> Result<Timed<()>>;

    /// Read a hyperslab.
    fn read_slab(&mut self, at: f64, dataset: &str, slab: &Hyperslab)
        -> Result<Timed<Vec<f32>>>;

    /// Read a hyperslab keeping only elements that match a value
    /// predicate over the implicit column `"v"`; non-matching elements
    /// read as `f32::NAN` ([`Predicate::True`] is exactly `read_slab`).
    /// The default evaluates client-side after a plain `read_slab`;
    /// backends with a storage-side plugin override it to compile the
    /// selection into a plan and push the filter down.
    fn read_slab_where(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        predicate: &Predicate,
    ) -> Result<Timed<Vec<f32>>> {
        let t = self.read_slab(at, dataset, slab)?;
        let finish = t.finish;
        let (vals, _matched) = apply_value_mask(t.value, predicate)?;
        Ok(Timed::new(vals, finish))
    }

    /// Dataset's dataspace + chunk shape.
    fn shape(&mut self, at: f64, dataset: &str) -> Result<Timed<(Dataspace, Vec<u64>)>>;

    /// Set / get a string attribute on a dataset.
    fn set_attr(&mut self, at: f64, dataset: &str, key: &str, value: &str) -> Result<Timed<()>>;
    fn get_attr(&mut self, at: f64, dataset: &str, key: &str) -> Result<Timed<Option<String>>>;

    /// Datasets in this file.
    fn list(&mut self, at: f64) -> Result<Timed<Vec<String>>>;
}

/// An open "file" — the application-facing handle.
pub struct VolFile {
    backend: Box<dyn VolBackend>,
    /// Virtual clock of this client session.
    now: f64,
}

impl VolFile {
    /// Open with an explicit backend (the VOL plugin selection).
    pub fn open(backend: Box<dyn VolBackend>) -> Self {
        Self { backend, now: 0.0 }
    }

    /// Backend name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The session's current virtual time (advances with every call).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Reset the session clock (between bench cases).
    pub fn reset_clock(&mut self) {
        self.now = 0.0;
    }

    /// Create a chunked f32 dataset.
    pub fn create_dataset(
        &mut self,
        name: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<()> {
        if chunk.len() != space.ndim() {
            return Err(Error::Invalid("chunk rank != space rank".into()));
        }
        let t = self.backend.create(self.now, name, space, chunk)?;
        self.now = t.finish;
        Ok(())
    }

    /// Write a hyperslab of data.
    pub fn write(&mut self, dataset: &str, slab: &Hyperslab, data: &[f32]) -> Result<()> {
        if data.len() as u64 != slab.numel() {
            return Err(Error::Invalid(format!(
                "data len {} != slab numel {}",
                data.len(),
                slab.numel()
            )));
        }
        let t = self.backend.write_slab(self.now, dataset, slab, data)?;
        self.now = t.finish;
        Ok(())
    }

    /// Write the full dataset.
    pub fn write_all(&mut self, dataset: &str, data: &[f32]) -> Result<()> {
        let (space, _) = self.shape(dataset)?;
        self.write(dataset, &Hyperslab::whole(&space), data)
    }

    /// Read a hyperslab.
    pub fn read(&mut self, dataset: &str, slab: &Hyperslab) -> Result<Vec<f32>> {
        let t = self.backend.read_slab(self.now, dataset, slab)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// Read the full dataset.
    pub fn read_all(&mut self, dataset: &str) -> Result<Vec<f32>> {
        let (space, _) = self.shape(dataset)?;
        self.read(dataset, &Hyperslab::whole(&space))
    }

    /// Read a hyperslab, keeping only elements that match `predicate`
    /// over the implicit value column `"v"`; masked elements read as
    /// `f32::NAN`.
    pub fn read_where(
        &mut self,
        dataset: &str,
        slab: &Hyperslab,
        predicate: &Predicate,
    ) -> Result<Vec<f32>> {
        let t = self
            .backend
            .read_slab_where(self.now, dataset, slab, predicate)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// Dataspace + chunk shape of a dataset.
    pub fn shape(&mut self, dataset: &str) -> Result<(Dataspace, Vec<u64>)> {
        let t = self.backend.shape(self.now, dataset)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// Attributes.
    pub fn set_attr(&mut self, dataset: &str, key: &str, value: &str) -> Result<()> {
        let t = self.backend.set_attr(self.now, dataset, key, value)?;
        self.now = t.finish;
        Ok(())
    }

    pub fn get_attr(&mut self, dataset: &str, key: &str) -> Result<Option<String>> {
        let t = self.backend.get_attr(self.now, dataset, key)?;
        self.now = t.finish;
        Ok(t.value)
    }

    /// List datasets.
    pub fn list_datasets(&mut self) -> Result<Vec<String>> {
        let t = self.backend.list(self.now)?;
        self.now = t.finish;
        Ok(t.value)
    }
}

/// Shared conformance suite: every backend must pass these behaviours.
/// Called by each backend's tests (and the integration tests) — the
/// executable statement of "the application sees the same data model"
/// (§4.1).
#[cfg(test)]
pub fn conformance(make: impl Fn() -> VolFile) {
    use crate::dataset::Dataspace;

    // create / shape / list
    let mut f = make();
    let space = Dataspace::new(&[8, 10]).unwrap();
    f.create_dataset("d", &space, &[4, 5]).unwrap();
    let (sp, ch) = f.shape("d").unwrap();
    assert_eq!(sp, space);
    assert_eq!(ch, vec![4, 5]);
    assert_eq!(f.list_datasets().unwrap(), vec!["d".to_string()]);

    // duplicate create fails
    assert!(f.create_dataset("d", &space, &[4, 5]).is_err());
    // missing dataset fails
    assert!(f.read_all("nope").is_err());

    // full write + read
    let data: Vec<f32> = (0..80).map(|i| i as f32).collect();
    f.write_all("d", &data).unwrap();
    assert_eq!(f.read_all("d").unwrap(), data);

    // partial hyperslab read (crosses chunk boundaries)
    let slab = Hyperslab::new(&[1, 3], &[3, 4]).unwrap();
    let got = f.read("d", &slab).unwrap();
    let mut want = Vec::new();
    for r in 1..4 {
        for c in 3..7 {
            want.push((r * 10 + c) as f32);
        }
    }
    assert_eq!(got, want);

    // partial hyperslab write (read-modify-write across chunks)
    let wslab = Hyperslab::new(&[2, 2], &[2, 3]).unwrap();
    f.write("d", &wslab, &[100.0, 101.0, 102.0, 110.0, 111.0, 112.0])
        .unwrap();
    let all = f.read_all("d").unwrap();
    assert_eq!(all[2 * 10 + 2], 100.0);
    assert_eq!(all[3 * 10 + 4], 112.0);
    assert_eq!(all[2 * 10 + 5], 25.0, "untouched element changed");

    // wrong data length rejected
    assert!(f.write("d", &wslab, &[1.0]).is_err());
    // out-of-bounds slab rejected
    let oob = Hyperslab::new(&[7, 9], &[2, 2]).unwrap();
    assert!(f.read("d", &oob).is_err());

    // attributes
    f.set_attr("d", "units", "kelvin").unwrap();
    assert_eq!(f.get_attr("d", "units").unwrap().unwrap(), "kelvin");
    assert!(f.get_attr("d", "none").unwrap().is_none());
    assert!(f.set_attr("ghost", "k", "v").is_err());

    // virtual time advances
    assert!(f.now() > 0.0);

    // 1-d dataset
    let mut f = make();
    let space1 = Dataspace::new(&[100]).unwrap();
    f.create_dataset("one", &space1, &[32]).unwrap();
    let data: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
    f.write_all("one", &data).unwrap();
    let tail = f.read("one", &Hyperslab::new(&[90], &[10]).unwrap()).unwrap();
    assert_eq!(tail, &data[90..]);

    // filtered read: kept elements bit-exact, masked ones NaN
    use crate::skyhook::query::CmpOp;
    let whole = Hyperslab::new(&[0], &[100]).unwrap();
    let got = f
        .read_where("one", &whole, &Predicate::cmp("v", CmpOp::Ge, 0.0))
        .unwrap();
    assert_eq!(got.len(), 100);
    for (g, d) in got.iter().zip(&data) {
        if *d >= 0.0 {
            assert_eq!(g, d);
        } else {
            assert!(g.is_nan(), "rejected element must read NaN");
        }
    }
    // Predicate::True is exactly read_slab
    let got = f
        .read_where("one", &Hyperslab::new(&[90], &[10]).unwrap(), &Predicate::True)
        .unwrap();
    assert_eq!(got, &data[90..]);
    // a predicate no element satisfies masks everything
    let got = f
        .read_where("one", &whole, &Predicate::cmp("v", CmpOp::Gt, 2.0))
        .unwrap();
    assert!(got.iter().all(|v| v.is_nan()));
    // foreign predicate columns are rejected
    assert!(f
        .read_where("one", &whole, &Predicate::cmp("x", CmpOp::Lt, 0.0))
        .is_err());

    // 3-d dataset with uneven chunks
    let mut f = make();
    let space3 = Dataspace::new(&[3, 5, 7]).unwrap();
    f.create_dataset("three", &space3, &[2, 3, 4]).unwrap();
    let data: Vec<f32> = (0..105).map(|i| i as f32 * 0.25).collect();
    f.write_all("three", &data).unwrap();
    assert_eq!(f.read_all("three").unwrap(), data);
    let slab = Hyperslab::new(&[1, 2, 3], &[2, 2, 2]).unwrap();
    let got = f.read("three", &slab).unwrap();
    let strides = space3.strides();
    let mut want = Vec::new();
    for a in 1..3u64 {
        for b in 2..4u64 {
            for c in 3..5u64 {
                want.push((a * strides[0] + b * strides[1] + c) as f32 * 0.25);
            }
        }
    }
    assert_eq!(got, want);
}
