//! The object-storage-layer VOL plugin (Figure 2, bottom): object-class
//! handlers that give each storage object an HDF5-flavoured interface —
//! "each of which offer the HDF5 API via an unmodified HDF access library
//! and map it to ... the local object storage layer" (§1).
//!
//! Handlers (`hdf5` class), all executing on the OSD holding the chunk:
//! - `hdf5.read_slab`  — return only the selected elements of the chunk
//!   (server-side selection: the network carries `slab.numel()*4` bytes,
//!   not the whole chunk),
//! - `hdf5.write_slab` — server-side read-modify-write of a sub-slab,
//! - `hdf5.stat`       — the chunk's stored dims.

use crate::dataset::array::copy_slab_f32;
use crate::dataset::layout::{decode_array_chunk, encode_array_chunk};
use crate::dataset::{Dataspace, Hyperslab};
use crate::error::{Error, Result};
use crate::store::objclass::ClassRegistry;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Encode a slab selection (+ optional payload) as handler input.
pub fn encode_slab_arg(slab: &Hyperslab, payload: Option<&[f32]>) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(slab.ndim() as u8);
    for &s in &slab.start {
        w.u64(s);
    }
    for &c in &slab.count {
        w.u64(c);
    }
    if let Some(p) = payload {
        w.raw(&crate::util::bytes::f32s_to_bytes(p));
    }
    w.finish()
}

fn decode_slab_arg(input: &[u8], want_payload: bool) -> Result<(Hyperslab, Vec<f32>)> {
    let mut r = ByteReader::new(input);
    let ndim = r.u8()? as usize;
    if ndim == 0 || ndim > 32 {
        return Err(Error::Invalid(format!("bad slab ndim {ndim}")));
    }
    let mut start = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        start.push(r.u64()?);
    }
    let mut count = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        count.push(r.u64()?);
    }
    let slab = Hyperslab::new(&start, &count)?;
    let payload = if want_payload {
        let bytes = r.raw(r.remaining())?;
        let data = crate::util::bytes::bytes_to_f32s(bytes)?;
        if data.len() as u64 != slab.numel() {
            return Err(Error::Invalid(format!(
                "payload {} elements != slab numel {}",
                data.len(),
                slab.numel()
            )));
        }
        data
    } else {
        if r.remaining() != 0 {
            return Err(Error::Invalid("unexpected payload".into()));
        }
        Vec::new()
    };
    Ok((slab, payload))
}

/// Register the `hdf5` object class. Call once when building the cluster's
/// [`ClassRegistry`] (every storage server gets the same plugins, §4.2).
pub fn register_hdf5_class(r: &mut ClassRegistry) {
    r.register("hdf5", "stat", |b, _| {
        let data = b.read()?;
        let (_, dims) = decode_array_chunk(&data)?;
        let mut w = ByteWriter::new();
        w.u8(dims.len() as u8);
        for &d in &dims {
            w.u64(d);
        }
        Ok(w.finish())
    });

    r.register("hdf5", "read_slab", |b, input| {
        let (slab, _) = decode_slab_arg(input, false)?;
        let raw = b.read()?;
        let (data, dims) = decode_array_chunk(&raw)?;
        let space = Dataspace::new(&dims)?;
        if !slab.fits(&space) {
            return Err(Error::Invalid("slab exceeds chunk".into()));
        }
        // CPU cost of the server-side selection copy.
        b.charge_cpu(slab.numel() as f64 * 1e-9);
        let out_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        copy_slab_f32(
            &data,
            &space,
            &slab,
            &mut out,
            &out_space,
            &Hyperslab::whole(&out_space),
        )?;
        Ok(crate::util::bytes::f32s_to_bytes(&out))
    });

    r.register("hdf5", "write_slab", |b, input| {
        let (slab, payload) = decode_slab_arg(input, true)?;
        let raw = b.read()?;
        let (mut data, dims) = decode_array_chunk(&raw)?;
        let space = Dataspace::new(&dims)?;
        if !slab.fits(&space) {
            return Err(Error::Invalid("slab exceeds chunk".into()));
        }
        b.charge_cpu(slab.numel() as f64 * 1e-9);
        let src_space = Dataspace::new(&slab.count)?;
        copy_slab_f32(
            &payload,
            &src_space,
            &Hyperslab::whole(&src_space),
            &mut data,
            &space,
            &slab,
        )?;
        b.write(&encode_array_chunk(&data, &dims)?)?;
        Ok(Vec::new())
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::objclass::MemBackend;

    fn registry() -> ClassRegistry {
        let mut r = ClassRegistry::with_builtins();
        register_hdf5_class(&mut r);
        r
    }

    fn chunk_2x4() -> Vec<u8> {
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        encode_array_chunk(&data, &[2, 4]).unwrap()
    }

    #[test]
    fn stat_returns_dims() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let out = r.get("hdf5", "stat").unwrap()(&mut b, &[]).unwrap();
        assert_eq!(out[0], 2); // ndim
        assert_eq!(u64::from_le_bytes(out[1..9].try_into().unwrap()), 2);
        assert_eq!(u64::from_le_bytes(out[9..17].try_into().unwrap()), 4);
    }

    #[test]
    fn read_slab_returns_selection_only() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 1], &[2, 2]).unwrap();
        let out = r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .unwrap();
        let vals = crate::util::bytes::bytes_to_f32s(&out).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out.len(), 16, "only the selection crosses the wire");
        assert!(b.cpu > 0.0);
    }

    #[test]
    fn read_slab_out_of_bounds() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[1, 3], &[2, 2]).unwrap();
        assert!(r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .is_err());
    }

    #[test]
    fn write_slab_rmw_on_server() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[1, 0], &[1, 2]).unwrap();
        r.get("hdf5", "write_slab").unwrap()(
            &mut b,
            &encode_slab_arg(&slab, Some(&[40.0, 50.0])),
        )
        .unwrap();
        let (data, dims) = decode_array_chunk(&b.data).unwrap();
        assert_eq!(dims, vec![2, 4]);
        assert_eq!(data, vec![0.0, 1.0, 2.0, 3.0, 40.0, 50.0, 6.0, 7.0]);
    }

    #[test]
    fn write_slab_payload_mismatch() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 0], &[1, 2]).unwrap();
        // 3 values for a 2-element slab.
        assert!(r.get("hdf5", "write_slab").unwrap()(
            &mut b,
            &encode_slab_arg(&slab, Some(&[1.0, 2.0, 3.0])),
        )
        .is_err());
    }

    #[test]
    fn slab_arg_roundtrip_and_validation() {
        let slab = Hyperslab::new(&[3, 4], &[1, 2]).unwrap();
        let enc = encode_slab_arg(&slab, None);
        let (dec, p) = decode_slab_arg(&enc, false).unwrap();
        assert_eq!(dec, slab);
        assert!(p.is_empty());
        // Truncated input.
        assert!(decode_slab_arg(&enc[..5], false).is_err());
        // Trailing garbage without payload flag.
        let mut bad = enc.clone();
        bad.push(0);
        assert!(decode_slab_arg(&bad, false).is_err());
    }

    #[test]
    fn handlers_reject_non_chunk_objects() {
        let r = registry();
        let mut b = MemBackend::new(b"not an array chunk");
        assert!(r.get("hdf5", "stat").unwrap()(&mut b, &[]).is_err());
        let slab = Hyperslab::new(&[0], &[1]).unwrap();
        assert!(r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .is_err());
    }
}
