//! The object-storage-layer VOL plugin (Figure 2, bottom): object-class
//! handlers that give each storage object an HDF5-flavoured interface —
//! "each of which offer the HDF5 API via an unmodified HDF access library
//! and map it to ... the local object storage layer" (§1).
//!
//! Handlers (`hdf5` class), all executing on the OSD holding the chunk:
//! - `hdf5.read_slab`  — return only the selected elements of the chunk
//!   (server-side selection: the network carries `slab.numel()*4` bytes,
//!   not the whole chunk),
//! - `hdf5.read_slab_where` — slab selection plus a value predicate over
//!   the implicit column `"v"`: ranged-reads only the requested rows'
//!   bytes off the device, evaluates the predicate through the shared
//!   execution kernel, and ships a sparse response (match bitmap +
//!   matching values only),
//! - `hdf5.write_slab` — server-side read-modify-write of a sub-slab,
//!   returning the chunk's recomputed whole-chunk value stats so the
//!   writer can refresh its zone map without a second read,
//! - `hdf5.stat`       — the chunk's stored dims.

use crate::dataset::array::copy_slab_f32;
use crate::dataset::layout::{
    array_chunk_header_len, decode_array_chunk, decode_array_chunk_header, encode_array_chunk,
};
use crate::dataset::metadata::ColumnStats;
use crate::dataset::table::{Batch, Column};
use crate::dataset::{DType, Dataspace, Hyperslab, TableSchema};
use crate::error::{Error, Result};
use crate::skyhook::exec_kernel::filter_mask;
use crate::skyhook::query::Predicate;
use crate::store::objclass::ClassRegistry;
use crate::util::bytes::{ByteReader, ByteWriter};

/// Encode a slab selection (+ optional payload) as handler input.
pub fn encode_slab_arg(slab: &Hyperslab, payload: Option<&[f32]>) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(slab.ndim() as u8);
    for &s in &slab.start {
        w.u64(s);
    }
    for &c in &slab.count {
        w.u64(c);
    }
    if let Some(p) = payload {
        w.raw(&crate::util::bytes::f32s_to_bytes(p));
    }
    w.finish()
}

fn decode_slab_arg(input: &[u8], want_payload: bool) -> Result<(Hyperslab, Vec<f32>)> {
    let mut r = ByteReader::new(input);
    let ndim = r.u8()? as usize;
    if ndim == 0 || ndim > 32 {
        return Err(Error::Invalid(format!("bad slab ndim {ndim}")));
    }
    let mut start = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        start.push(r.u64()?);
    }
    let mut count = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        count.push(r.u64()?);
    }
    let slab = Hyperslab::new(&start, &count)?;
    let payload = if want_payload {
        let bytes = r.raw(r.remaining())?;
        let data = crate::util::bytes::bytes_to_f32s(bytes)?;
        if data.len() as u64 != slab.numel() {
            return Err(Error::Invalid(format!(
                "payload {} elements != slab numel {}",
                data.len(),
                slab.numel()
            )));
        }
        data
    } else {
        if r.remaining() != 0 {
            return Err(Error::Invalid("unexpected payload".into()));
        }
        Vec::new()
    };
    Ok((slab, payload))
}

/// Encode a slab selection + value predicate as `hdf5.read_slab_where`
/// handler input (the request the VOL planner prices as
/// `request_bytes`).
pub fn encode_slab_where_arg(slab: &Hyperslab, pred: &Predicate) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.u8(slab.ndim() as u8);
    for &s in &slab.start {
        w.u64(s);
    }
    for &c in &slab.count {
        w.u64(c);
    }
    pred.encode_into(&mut w);
    w.finish()
}

/// Decode a `hdf5.read_slab_where` response into the dense masked slab:
/// `numel` f32s in slab row-major order, matching elements holding
/// their stored bits and masked elements `f32::NAN`. Returns
/// `(values, rows_scanned, rows_matched)`.
///
/// Wire: `tag u8 | rows_scanned u64 | rows_matched u64`, then (tag 0
/// only) an LSB-first match bitmap of `ceil(numel/8)` bytes followed by
/// the matching values. Tag 1 is the all-masked short-circuit: nothing
/// matched, no payload.
pub fn decode_where_response(buf: &[u8], numel: u64) -> Result<(Vec<f32>, u64, u64)> {
    let mut r = ByteReader::new(buf);
    let tag = r.u8()?;
    let scanned = r.u64()?;
    let matched = r.u64()?;
    if scanned != numel {
        return Err(Error::Corrupt(format!(
            "rows scanned {scanned} != slab numel {numel}"
        )));
    }
    let mut out = vec![f32::NAN; numel as usize];
    match tag {
        1 => {
            if matched != 0 || r.remaining() != 0 {
                return Err(Error::Corrupt("malformed all-masked response".into()));
            }
        }
        0 => {
            let bits = r.raw(numel.div_ceil(8) as usize)?.to_vec();
            let mut set = 0u64;
            for (i, slot) in out.iter_mut().enumerate() {
                if bits[i / 8] >> (i % 8) & 1 == 1 {
                    *slot = r.f32()?;
                    set += 1;
                }
            }
            if set != matched || r.remaining() != 0 {
                return Err(Error::Corrupt("match bitmap disagrees with count".into()));
            }
        }
        t => return Err(Error::Corrupt(format!("bad read_slab_where tag {t}"))),
    }
    Ok((out, scanned, matched))
}

/// Register the `hdf5` object class. Call once when building the cluster's
/// [`ClassRegistry`] (every storage server gets the same plugins, §4.2).
pub fn register_hdf5_class(r: &mut ClassRegistry) {
    r.register("hdf5", "stat", |b, _| {
        let data = b.read()?;
        let (_, dims) = decode_array_chunk(&data)?;
        let mut w = ByteWriter::new();
        w.u8(dims.len() as u8);
        for &d in &dims {
            w.u64(d);
        }
        Ok(w.finish())
    });

    r.register("hdf5", "read_slab", |b, input| {
        let (slab, _) = decode_slab_arg(input, false)?;
        let raw = b.read()?;
        let (data, dims) = decode_array_chunk(&raw)?;
        let space = Dataspace::new(&dims)?;
        if !slab.fits(&space) {
            return Err(Error::Invalid("slab exceeds chunk".into()));
        }
        // CPU cost of the server-side selection copy.
        b.charge_cpu(slab.numel() as f64 * 1e-9);
        let out_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        copy_slab_f32(
            &data,
            &space,
            &slab,
            &mut out,
            &out_space,
            &Hyperslab::whole(&out_space),
        )?;
        Ok(crate::util::bytes::f32s_to_bytes(&out))
    });

    r.register("hdf5", "read_slab_where", |b, input| {
        let mut r = ByteReader::new(input);
        let ndim = r.u8()? as usize;
        if ndim == 0 || ndim > 32 {
            return Err(Error::Invalid(format!("bad slab ndim {ndim}")));
        }
        let mut start = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            start.push(r.u64()?);
        }
        let mut count = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            count.push(r.u64()?);
        }
        let slab = Hyperslab::new(&start, &count)?;
        let pred = Predicate::decode_from(&mut r)?;
        if r.remaining() != 0 {
            return Err(Error::Invalid("trailing bytes after predicate".into()));
        }
        for col in pred.columns() {
            if col != "v" {
                return Err(Error::Invalid(format!(
                    "read_slab_where predicates see a single value column \"v\", got \"{col}\""
                )));
            }
        }
        // Ranged header read: learn the stored dims without touching the
        // payload. A partial read cannot verify the chunk checksum — the
        // same trade `read_projected_rows` makes for tables.
        let hlen = array_chunk_header_len(ndim);
        let dims = decode_array_chunk_header(&b.read_range(0, hlen)?)?;
        if dims.len() != ndim {
            return Err(Error::Invalid(format!(
                "slab rank {ndim} != chunk rank {}",
                dims.len()
            )));
        }
        let space = Dataspace::new(&dims)?;
        if !slab.fits(&space) {
            return Err(Error::Invalid("slab exceeds chunk".into()));
        }
        // Per-row ranged reads: exactly the requested rows' bytes come
        // off the device (header + 4·numel total), never the whole
        // chunk — this is the `scan_bytes` the planner priced.
        let mut strides = vec![1u64; ndim];
        for d in (0..ndim - 1).rev() {
            strides[d] = strides[d + 1] * dims[d + 1];
        }
        let mut vals = Vec::with_capacity(slab.numel() as usize);
        for (coord, run) in slab.rows() {
            let off: u64 = coord.iter().zip(&strides).map(|(c, s)| c * s).sum();
            let bytes = b.read_range(hlen + (off * 4) as usize, (run * 4) as usize)?;
            vals.extend(crate::util::bytes::bytes_to_f32s(&bytes)?);
        }
        // Evaluate through the shared execution kernel so the mask is
        // bit-identical to what a client-side pass would compute, and
        // charge exactly what the kernel accounts plus the sparse
        // response encode.
        let schema = TableSchema::new(&[("v", DType::F32)]);
        let batch = Batch::new(schema, vec![Column::F32(vals)])?;
        let (mask, work) = filter_mask(&batch, &pred, &[])?;
        let matched = mask.iter().filter(|&&m| m).count() as u64;
        let prof = b.exec_profile();
        b.charge_cpu(work.server_seconds(&prof) + matched as f64 * 1e-9);
        let rows = batch.nrows() as u64;
        let mut w = ByteWriter::new();
        if matched == 0 {
            // All-masked short-circuit: only the 17-byte header ships.
            w.u8(1);
            w.u64(rows);
            w.u64(0);
            return Ok(w.finish());
        }
        w.u8(0);
        w.u64(rows);
        w.u64(matched);
        let mut bits = vec![0u8; mask.len().div_ceil(8)];
        for (i, &m) in mask.iter().enumerate() {
            if m {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        w.raw(&bits);
        let Column::F32(vals) = &batch.columns[0] else {
            return Err(Error::Runtime("value column changed dtype".into()));
        };
        for (i, &m) in mask.iter().enumerate() {
            if m {
                w.f32(vals[i]);
            }
        }
        Ok(w.finish())
    });

    r.register("hdf5", "write_slab", |b, input| {
        let (slab, payload) = decode_slab_arg(input, true)?;
        let raw = b.read()?;
        let (mut data, dims) = decode_array_chunk(&raw)?;
        let space = Dataspace::new(&dims)?;
        if !slab.fits(&space) {
            return Err(Error::Invalid("slab exceeds chunk".into()));
        }
        b.charge_cpu(slab.numel() as f64 * 1e-9);
        let src_space = Dataspace::new(&slab.count)?;
        copy_slab_f32(
            &payload,
            &src_space,
            &Hyperslab::whole(&src_space),
            &mut data,
            &space,
            &slab,
        )?;
        b.write(&encode_array_chunk(&data, &dims)?)?;
        // Return the chunk's recomputed whole-chunk value stats (25
        // bytes): only the server sees the merged chunk, so only it can
        // produce the zone-map range the writer stamps — a second read
        // just for stats would defeat the server-side RMW.
        let mut w = ByteWriter::new();
        ColumnStats::from_f32s(&data).encode_into(&mut w);
        Ok(w.finish())
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::skyhook::query::CmpOp;
    use crate::store::objclass::MemBackend;

    fn registry() -> ClassRegistry {
        let mut r = ClassRegistry::with_builtins();
        register_hdf5_class(&mut r);
        r
    }

    fn chunk_2x4() -> Vec<u8> {
        let data: Vec<f32> = (0..8).map(|i| i as f32).collect();
        encode_array_chunk(&data, &[2, 4]).unwrap()
    }

    #[test]
    fn stat_returns_dims() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let out = r.get("hdf5", "stat").unwrap()(&mut b, &[]).unwrap();
        assert_eq!(out[0], 2); // ndim
        assert_eq!(u64::from_le_bytes(out[1..9].try_into().unwrap()), 2);
        assert_eq!(u64::from_le_bytes(out[9..17].try_into().unwrap()), 4);
    }

    #[test]
    fn read_slab_returns_selection_only() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 1], &[2, 2]).unwrap();
        let out = r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .unwrap();
        let vals = crate::util::bytes::bytes_to_f32s(&out).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out.len(), 16, "only the selection crosses the wire");
        assert!(b.cpu > 0.0);
    }

    #[test]
    fn read_slab_out_of_bounds() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[1, 3], &[2, 2]).unwrap();
        assert!(r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .is_err());
    }

    #[test]
    fn write_slab_rmw_on_server() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[1, 0], &[1, 2]).unwrap();
        let out = r.get("hdf5", "write_slab").unwrap()(
            &mut b,
            &encode_slab_arg(&slab, Some(&[40.0, 50.0])),
        )
        .unwrap();
        let (data, dims) = decode_array_chunk(&b.data).unwrap();
        assert_eq!(dims, vec![2, 4]);
        assert_eq!(data, vec![0.0, 1.0, 2.0, 3.0, 40.0, 50.0, 6.0, 7.0]);
        // The response carries the merged chunk's recomputed stats.
        let stats = ColumnStats::decode_from(&mut ByteReader::new(&out)).unwrap();
        assert_eq!((stats.min, stats.max, stats.nan_count), (0.0, 50.0, 0));
    }

    #[test]
    fn read_slab_where_ships_sparse_matches() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 0], &[2, 4]).unwrap();
        let pred = Predicate::cmp("v", CmpOp::Ge, 3.0);
        let out = r.get("hdf5", "read_slab_where").unwrap()(
            &mut b,
            &encode_slab_where_arg(&slab, &pred),
        )
        .unwrap();
        // tag/rows header + 1-byte bitmap + the 5 matching values only.
        assert_eq!(out.len(), 17 + 1 + 20);
        let (vals, scanned, matched) = decode_where_response(&out, 8).unwrap();
        assert_eq!((scanned, matched), (8, 5));
        for (i, v) in vals.iter().enumerate() {
            if i >= 3 {
                assert_eq!(*v, i as f32);
            } else {
                assert!(v.is_nan(), "masked element {i} must read NaN");
            }
        }
        assert!(b.cpu > 0.0);
    }

    #[test]
    fn read_slab_where_all_masked_short_circuits() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[1, 1], &[1, 2]).unwrap();
        let pred = Predicate::cmp("v", CmpOp::Gt, 100.0);
        let out = r.get("hdf5", "read_slab_where").unwrap()(
            &mut b,
            &encode_slab_where_arg(&slab, &pred),
        )
        .unwrap();
        assert_eq!(out.len(), 17, "only the header crosses the wire");
        let (vals, scanned, matched) = decode_where_response(&out, 2).unwrap();
        assert_eq!((scanned, matched), (2, 0));
        assert!(vals.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn read_slab_where_nan_matches_only_ne() {
        let r = registry();
        let data = [f32::NAN, 1.0, 2.0, 3.0];
        let mut b = MemBackend::new(&encode_array_chunk(&data, &[4]).unwrap());
        let slab = Hyperslab::new(&[0], &[4]).unwrap();
        // NaN != 2.0 holds, so the stored NaN survives the filter.
        let pred = Predicate::cmp("v", CmpOp::Ne, 2.0);
        let out = r.get("hdf5", "read_slab_where").unwrap()(
            &mut b,
            &encode_slab_where_arg(&slab, &pred),
        )
        .unwrap();
        let (vals, _, matched) = decode_where_response(&out, 4).unwrap();
        assert_eq!(matched, 3);
        assert!(vals[0].is_nan());
        assert_eq!((vals[1], vals[3]), (1.0, 3.0));
        assert!(vals[2].is_nan(), "2.0 itself is masked");
        // A comparison predicate never matches NaN rows.
        let mut b = MemBackend::new(&encode_array_chunk(&data, &[4]).unwrap());
        let pred = Predicate::cmp("v", CmpOp::Lt, 100.0);
        let out = r.get("hdf5", "read_slab_where").unwrap()(
            &mut b,
            &encode_slab_where_arg(&slab, &pred),
        )
        .unwrap();
        let (vals, _, matched) = decode_where_response(&out, 4).unwrap();
        assert_eq!(matched, 3);
        assert!(vals[0].is_nan());
    }

    #[test]
    fn read_slab_where_true_predicate_matches_read_slab() {
        let r = registry();
        let slab = Hyperslab::new(&[0, 1], &[2, 2]).unwrap();
        let mut b = MemBackend::new(&chunk_2x4());
        let dense = r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .unwrap();
        let expect = crate::util::bytes::bytes_to_f32s(&dense).unwrap();
        let mut b = MemBackend::new(&chunk_2x4());
        let out = r.get("hdf5", "read_slab_where").unwrap()(
            &mut b,
            &encode_slab_where_arg(&slab, &Predicate::True),
        )
        .unwrap();
        let (vals, _, matched) = decode_where_response(&out, 4).unwrap();
        assert_eq!(matched, 4);
        assert_eq!(vals, expect);
    }

    #[test]
    fn read_slab_where_validates() {
        let r = registry();
        let h = r.get("hdf5", "read_slab_where").unwrap();
        // Foreign predicate column.
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 0], &[1, 1]).unwrap();
        let pred = Predicate::cmp("temp", CmpOp::Gt, 0.0);
        assert!(h(&mut b, &encode_slab_where_arg(&slab, &pred)).is_err());
        // Out-of-bounds slab.
        let mut b = MemBackend::new(&chunk_2x4());
        let oob = Hyperslab::new(&[1, 3], &[2, 2]).unwrap();
        assert!(h(&mut b, &encode_slab_where_arg(&oob, &Predicate::True)).is_err());
        // Trailing bytes after the predicate.
        let mut b = MemBackend::new(&chunk_2x4());
        let mut arg = encode_slab_where_arg(&slab, &Predicate::True);
        arg.push(9);
        assert!(h(&mut b, &arg).is_err());
        // Rank mismatch against the stored chunk.
        let mut b = MemBackend::new(&chunk_2x4());
        let flat = Hyperslab::new(&[0], &[1]).unwrap();
        assert!(h(&mut b, &encode_slab_where_arg(&flat, &Predicate::True)).is_err());
    }

    #[test]
    fn write_slab_payload_mismatch() {
        let r = registry();
        let mut b = MemBackend::new(&chunk_2x4());
        let slab = Hyperslab::new(&[0, 0], &[1, 2]).unwrap();
        // 3 values for a 2-element slab.
        assert!(r.get("hdf5", "write_slab").unwrap()(
            &mut b,
            &encode_slab_arg(&slab, Some(&[1.0, 2.0, 3.0])),
        )
        .is_err());
    }

    #[test]
    fn slab_arg_roundtrip_and_validation() {
        let slab = Hyperslab::new(&[3, 4], &[1, 2]).unwrap();
        let enc = encode_slab_arg(&slab, None);
        let (dec, p) = decode_slab_arg(&enc, false).unwrap();
        assert_eq!(dec, slab);
        assert!(p.is_empty());
        // Truncated input.
        assert!(decode_slab_arg(&enc[..5], false).is_err());
        // Trailing garbage without payload flag.
        let mut bad = enc.clone();
        bad.push(0);
        assert!(decode_slab_arg(&bad, false).is_err());
    }

    #[test]
    fn handlers_reject_non_chunk_objects() {
        let r = registry();
        let mut b = MemBackend::new(b"not an array chunk");
        assert!(r.get("hdf5", "stat").unwrap()(&mut b, &[]).is_err());
        let slab = Hyperslab::new(&[0], &[1]).unwrap();
        assert!(r.get("hdf5", "read_slab").unwrap()(&mut b, &encode_slab_arg(&slab, None))
            .is_err());
    }
}
