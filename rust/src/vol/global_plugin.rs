//! The global/forwarding VOL plugin (Figure 2, top): compiles hyperslab
//! requests into a [`LogicalPlan`], prunes dead chunks against per-chunk
//! zone maps, prices each surviving chunk through the planner's cost
//! model, scatters the per-chunk sub-requests to storage objects, and
//! gathers results (§4.1).
//!
//! Cost model (drives the E1/Table 1 reproduction): the plugin pays a
//! *serial* client-side serialization cost per byte forwarded
//! (`client_fwd_bw`, the paper's forwarding overhead), while the
//! per-chunk sub-requests fan out to OSDs whose device work overlaps —
//! "enough parallelism could offset this overhead" (§4.1).
//!
//! Reads are planned ([`VolPolicy::Planned`], the default): the request
//! slab rides a `Scan` node, any value predicate rides a `Filter`, and
//! `plan_vol_read` intersects the chunk decomposition against each
//! chunk's written bounding box and value range — pruned chunks never
//! leave the planner — then picks per-chunk `ExecMode` (push
//! `hdf5.read_slab`/`hdf5.read_slab_where` vs whole-object client read)
//! from the same `AccessProfile` estimator table queries use.
//! [`VolPolicy::Static`] keeps the pre-planner rule (partial piece →
//! pushdown, whole chunk → client read, no pruning) as the measured
//! baseline.
//!
//! Writes stamp zone maps: every chunk write records its written
//! bounding box and whole-chunk value stats in the dataset metadata and
//! in a per-chunk xattr, and bumps the meta object's content-version
//! xattr so other handles' caches revalidate.

use super::api::{apply_value_mask, Timed, VolBackend};
use super::local_plugin::{decode_where_response, encode_slab_arg, encode_slab_where_arg};
use crate::coordinator::Metrics;
use crate::dataset::array::{copy_slab_f32, ChunkGrid};
use crate::dataset::layout::{decode_array_chunk, encode_array_chunk};
use crate::dataset::metadata::{self, ChunkZone, ColumnStats, DatasetMeta};
use crate::dataset::naming;
use crate::dataset::{Dataspace, Hyperslab};
use crate::error::{Error, Result};
use crate::simnet::Timeline;
use crate::skyhook::plan::{plan_vol_read, vol_mode_forced, ExecMode};
use crate::skyhook::query::Predicate;
use crate::skyhook::LogicalPlan;
use crate::store::Cluster;
use crate::util::bytes::ByteReader;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// How the forwarding plugin executes reads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VolPolicy {
    /// Compile each read into a `LogicalPlan`: zone-map chunk pruning
    /// plus cost-based per-chunk offload (the default).
    Planned,
    /// The pre-planner rule: partial pieces push `hdf5.read_slab`,
    /// whole-chunk pieces read the object client-side, nothing is
    /// pruned. Kept as the measured baseline for the E8/E9 A/B.
    Static,
    /// Plan (and prune), but pin every surviving chunk to one side —
    /// the A/B and property-test knob.
    Forced(ExecMode),
}

/// Read-path counters a [`ForwardingBackend`] accumulates across calls
/// (mirrored into `vol.*` [`Metrics`] counters when attached).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct VolStats {
    /// Chunks the planner dropped via zone maps — never fetched.
    pub chunks_pruned: u64,
    /// Surviving chunks executed storage-side.
    pub chunks_pushdown: u64,
    /// Surviving chunks fetched whole and evaluated client-side.
    pub chunks_client: u64,
    /// Total chunk objects actually touched by reads.
    pub chunks_fetched: u64,
    /// Payload bytes pruning provably kept off the wire and device.
    pub bytes_skipped: u64,
    /// Elements the value filter evaluated (either side).
    pub rows_scanned: u64,
    /// Elements the value filter kept.
    pub rows_matched: u64,
}

/// Cached per-dataset metadata plus the stamped content version it
/// mirrors (`skyhook.meta.ver` on the meta object).
struct CachedMeta {
    space: Dataspace,
    chunk: Vec<u64>,
    zones: BTreeMap<u64, ChunkZone>,
    ver: u64,
}

/// Forwarding backend over a cluster.
pub struct ForwardingBackend {
    cluster: Arc<Cluster>,
    /// Client-side serialization pipe (the forwarding overhead).
    client: Timeline,
    /// Cached dataset metadata, revalidated against the meta object's
    /// content-version xattr on every access.
    meta: HashMap<String, CachedMeta>,
    policy: VolPolicy,
    /// Zone-map pruning switch (Planned/Forced policies only).
    prune: bool,
    stats: VolStats,
    metrics: Option<Arc<Metrics>>,
}

impl ForwardingBackend {
    pub fn new(cluster: Arc<Cluster>) -> Self {
        Self {
            cluster,
            client: Timeline::new(),
            meta: HashMap::new(),
            policy: VolPolicy::Planned,
            prune: true,
            stats: VolStats::default(),
            metrics: None,
        }
    }

    /// Select the read-execution policy (default [`VolPolicy::Planned`]).
    pub fn with_policy(mut self, policy: VolPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Toggle zone-map pruning (default on; ignored under `Static`).
    pub fn with_prune(mut self, prune: bool) -> Self {
        self.prune = prune;
        self
    }

    /// Mirror read-path counters into `vol.*` metrics.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// The cluster this plugin forwards to.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Read-path counters accumulated so far.
    pub fn stats(&self) -> VolStats {
        self.stats
    }

    /// Reload the cached metadata when the meta object's stamped
    /// content-version xattr disagrees with (or is missing for) the
    /// cache. The regression this guards: the dataset name is
    /// re-provisioned with a different shape behind this handle's back,
    /// and a stale cache would decompose reads against dead geometry.
    fn revalidate(&mut self, at: f64, dataset: &str) -> Result<()> {
        let obj = naming::meta_object(dataset);
        let stamped = self
            .cluster
            .getxattr(at, &obj, metadata::META_VERSION_XATTR)
            .ok()
            .and_then(|t| t.value)
            .and_then(|b| <[u8; 8]>::try_from(b.as_slice()).ok())
            .map(u64::from_le_bytes);
        if let (Some(c), Some(v)) = (self.meta.get(dataset), stamped) {
            if c.ver == v {
                return Ok(());
            }
        }
        let (meta, _) = metadata::load_meta(&self.cluster, at, dataset)?;
        let ver = metadata::content_version(&meta.encode());
        match meta {
            DatasetMeta::Array {
                space,
                chunk,
                zones,
            } => {
                self.meta.insert(
                    dataset.to_string(),
                    CachedMeta {
                        space,
                        chunk,
                        zones,
                        ver,
                    },
                );
                Ok(())
            }
            _ => Err(Error::Invalid(format!("{dataset} is not an array dataset"))),
        }
    }

    fn grid_zones(
        &mut self,
        at: f64,
        dataset: &str,
    ) -> Result<(ChunkGrid, BTreeMap<u64, ChunkZone>)> {
        self.revalidate(at, dataset)?;
        let c = self.meta.get(dataset).expect("revalidate populated cache");
        Ok((ChunkGrid::new(c.space.clone(), &c.chunk)?, c.zones.clone()))
    }

    /// Serial client-side forwarding cost for `bytes`, starting at `at`.
    fn forward(&self, at: f64, bytes: u64) -> f64 {
        self.client.submit(at, self.cluster.cost().client_fwd_time(bytes))
    }

    /// The filtered-read entry point both `read_slab` (with
    /// [`Predicate::True`]) and `read_slab_where` funnel into.
    fn read_filtered(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        pred: &Predicate,
    ) -> Result<Timed<Vec<f32>>> {
        let (grid, zones) = self.grid_zones(at, dataset)?;
        match self.policy {
            VolPolicy::Static => self.read_static(at, dataset, &grid, slab, pred),
            VolPolicy::Planned | VolPolicy::Forced(_) => {
                self.read_planned(at, dataset, &grid, &zones, slab, pred)
            }
        }
    }

    /// Plan-compiled read: prune against zone maps, price survivors,
    /// execute each on its cost-chosen side, gather + mask.
    fn read_planned(
        &mut self,
        at: f64,
        dataset: &str,
        grid: &ChunkGrid,
        zones: &BTreeMap<u64, ChunkZone>,
        slab: &Hyperslab,
        pred: &Predicate,
    ) -> Result<Timed<Vec<f32>>> {
        // Compile the selection exactly like a table query: the Scan
        // node carries the hyperslab, the value predicate rides a
        // Filter on top.
        let has_pred = !matches!(pred, Predicate::True);
        let mut lp = LogicalPlan::scan_slab(dataset, slab.clone());
        if has_pred {
            lp = lp.filter(pred.clone());
        }
        let force = match self.policy {
            VolPolicy::Forced(m) => Some(m),
            _ => vol_mode_forced(),
        };
        let cluster = Arc::clone(&self.cluster);
        let ds = dataset.to_string();
        let exists = move |idx: u64| cluster.object_exists(&naming::array_object(&ds, idx));
        let plan = plan_vol_read(
            &lp,
            grid,
            zones,
            &exists,
            self.cluster.cost(),
            self.prune,
            force,
        )?;

        let out_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        // Planner-resolved regions cost no storage I/O; the answer is
        // known a request latency after the call.
        let mut finish = at + self.cluster.cost().net_latency_s;
        for (fslab, fill) in &plan.fills {
            let fspace = Dataspace::new(&fslab.count)?;
            let buf = vec![*fill; fslab.numel() as usize];
            copy_slab_f32(
                &buf,
                &fspace,
                &Hyperslab::whole(&fspace),
                &mut out,
                &out_space,
                &offset_into(fslab, slab)?,
            )?;
        }
        for sq in &plan.pieces {
            let obj = naming::array_object(dataset, sq.chunk_idx);
            let p = sq.piece.numel();
            let piece_space = Dataspace::new(&sq.piece.count)?;
            let (piece_data, t_finish) = match sq.mode {
                ExecMode::Pushdown if has_pred => {
                    let t = self.cluster.call(
                        at,
                        &obj,
                        "hdf5",
                        "read_slab_where",
                        &encode_slab_where_arg(&sq.local, pred),
                    )?;
                    let (vals, scanned, matched) = decode_where_response(&t.value, p)?;
                    self.stats.rows_scanned += scanned;
                    self.stats.rows_matched += matched;
                    self.stats.chunks_pushdown += 1;
                    (vals, t.finish)
                }
                ExecMode::Pushdown => {
                    let t = self.cluster.call(
                        at,
                        &obj,
                        "hdf5",
                        "read_slab",
                        &encode_slab_arg(&sq.local, None),
                    )?;
                    self.stats.rows_scanned += p;
                    self.stats.rows_matched += p;
                    self.stats.chunks_pushdown += 1;
                    (crate::util::bytes::bytes_to_f32s(&t.value)?, t.finish)
                }
                ExecMode::ClientSide => {
                    let t = self.cluster.read_object(at, &obj)?;
                    let (data, dims) = decode_array_chunk(&t.value)?;
                    let chunk_slab = grid.chunk_slab(sq.chunk_idx)?;
                    if dims != chunk_slab.count {
                        return Err(Error::Corrupt(format!("chunk {obj} dims drifted")));
                    }
                    let space = Dataspace::new(&dims)?;
                    let mut vals = vec![0.0f32; p as usize];
                    copy_slab_f32(
                        &data,
                        &space,
                        &sq.local,
                        &mut vals,
                        &piece_space,
                        &Hyperslab::whole(&piece_space),
                    )?;
                    let (vals, matched) = apply_value_mask(vals, pred)?;
                    self.stats.rows_scanned += p;
                    self.stats.rows_matched += matched;
                    self.stats.chunks_client += 1;
                    (vals, t.finish)
                }
            };
            self.stats.chunks_fetched += 1;
            copy_slab_f32(
                &piece_data,
                &piece_space,
                &Hyperslab::whole(&piece_space),
                &mut out,
                &out_space,
                &offset_into(&sq.piece, slab)?,
            )?;
            finish = finish.max(t_finish);
        }
        self.stats.chunks_pruned += plan.chunks_pruned as u64;
        self.stats.bytes_skipped += plan.bytes_skipped;
        if let Some(m) = &self.metrics {
            m.incr("vol.chunks_pruned", plan.chunks_pruned as u64);
            m.incr(
                "vol.chunks_pushdown",
                plan.pieces
                    .iter()
                    .filter(|s| s.mode == ExecMode::Pushdown)
                    .count() as u64,
            );
            m.incr("vol.bytes_skipped", plan.bytes_skipped);
        }
        Ok(Timed::new(out, finish))
    }

    /// The pre-planner read rule, kept verbatim as the measured
    /// baseline: partial piece → push `hdf5.read_slab`, whole chunk →
    /// client object read, missing chunk → zeros, no pruning. A value
    /// predicate is applied client-side over the gathered result.
    fn read_static(
        &mut self,
        at: f64,
        dataset: &str,
        grid: &ChunkGrid,
        slab: &Hyperslab,
        pred: &Predicate,
    ) -> Result<Timed<Vec<f32>>> {
        let pieces = grid.decompose(slab)?;
        let out_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        let mut finish = at;
        for (chunk_idx, piece) in pieces {
            let obj = naming::array_object(dataset, chunk_idx);
            let chunk_slab = grid.chunk_slab(chunk_idx)?;
            let local = offset_into(&piece, &chunk_slab)?;
            let piece_space = Dataspace::new(&piece.count)?;

            let whole_chunk = piece.count == chunk_slab.count;
            let piece_data: Vec<f32>;
            let t_finish: f64;
            if !self.cluster.object_exists(&obj) {
                // Never-written chunk: zeros (HDF5 fill value).
                piece_data = vec![0.0; piece.numel() as usize];
                t_finish = at + self.cluster.cost().net_latency_s;
            } else if whole_chunk {
                let t = self.cluster.read_object(at, &obj)?;
                let (data, dims) = decode_array_chunk(&t.value)?;
                if dims != chunk_slab.count {
                    return Err(Error::Corrupt(format!("chunk {obj} dims drifted")));
                }
                piece_data = data;
                t_finish = t.finish;
                self.stats.chunks_client += 1;
                self.stats.chunks_fetched += 1;
            } else {
                // Server-side selection: only selected bytes return.
                let t = self.cluster.call(
                    at,
                    &obj,
                    "hdf5",
                    "read_slab",
                    &encode_slab_arg(&local, None),
                )?;
                piece_data = crate::util::bytes::bytes_to_f32s(&t.value)?;
                t_finish = t.finish;
                self.stats.chunks_pushdown += 1;
                self.stats.chunks_fetched += 1;
            }

            copy_slab_f32(
                &piece_data,
                &piece_space,
                &Hyperslab::whole(&piece_space),
                &mut out,
                &out_space,
                &offset_into(&piece, slab)?,
            )?;
            finish = finish.max(t_finish);
        }
        let (out, matched) = apply_value_mask(out, pred)?;
        if !matches!(pred, Predicate::True) {
            self.stats.rows_scanned += slab.numel();
            self.stats.rows_matched += matched;
        }
        Ok(Timed::new(out, finish))
    }
}

/// Re-base `piece` (dataspace coordinates) into the frame of the
/// enclosing `outer` slab.
fn offset_into(piece: &Hyperslab, outer: &Hyperslab) -> Result<Hyperslab> {
    Hyperslab::new(
        &piece
            .start
            .iter()
            .zip(&outer.start)
            .map(|(p, o)| p - o)
            .collect::<Vec<_>>(),
        &piece.count,
    )
}

impl VolBackend for ForwardingBackend {
    fn name(&self) -> &'static str {
        "forwarding"
    }

    fn create(
        &mut self,
        at: f64,
        dataset: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<Timed<()>> {
        ChunkGrid::new(space.clone(), chunk)?; // validate
        // Whatever happens next, this handle must not keep trusting a
        // cache entry for a name being (re-)created.
        self.meta.remove(dataset);
        let meta = DatasetMeta::Array {
            space: space.clone(),
            chunk: chunk.to_vec(),
            zones: BTreeMap::new(),
        };
        let finish = metadata::save_meta(&self.cluster, at, dataset, &meta, false)?;
        let ver = metadata::content_version(&meta.encode());
        self.meta.insert(
            dataset.to_string(),
            CachedMeta {
                space: space.clone(),
                chunk: chunk.to_vec(),
                zones: BTreeMap::new(),
                ver,
            },
        );
        Ok(Timed::new((), finish))
    }

    fn write_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        data: &[f32],
    ) -> Result<Timed<()>> {
        let (grid, mut zones) = self.grid_zones(at, dataset)?;
        let pieces = grid.decompose(slab)?;
        let src_space = Dataspace::new(&slab.count)?;
        // Phase 1 (serial): the forwarding plugin serializes/mirrors the
        // whole request stream on the client — Table 1's constant `a`
        // term. Storage writes only start once their request stream
        // exists, so the phases do not overlap (the paper's t(n) = a + b/n
        // fit has a strictly serial client phase).
        let mut client_done = at;
        for (_, piece) in &pieces {
            client_done = self.forward(client_done, piece.numel() * 4);
        }
        let mut finish = client_done;
        for (chunk_idx, piece) in pieces {
            let obj = naming::array_object(dataset, chunk_idx);
            let chunk_slab = grid.chunk_slab(chunk_idx)?;
            let stored_dims = chunk_slab.count.clone();

            // Gather the piece's data out of the request buffer.
            let piece_space = Dataspace::new(&piece.count)?;
            let mut piece_data = vec![0.0f32; piece.numel() as usize];
            copy_slab_f32(
                data,
                &src_space,
                &offset_into(&piece, slab)?,
                &mut piece_data,
                &piece_space,
                &Hyperslab::whole(&piece_space),
            )?;

            // Phase 2: storage ops fan out after the client phase,
            // overlapping across OSDs ("enough parallelism could offset
            // this overhead", §4.1).
            let depart = client_done;

            let whole_chunk = piece.count == stored_dims;
            let (zone, t_finish) = if whole_chunk {
                // Whole-chunk overwrite: the piece *is* the chunk, so
                // its stats are the chunk's stats.
                let bytes = encode_array_chunk(&piece_data, &stored_dims)?;
                let zone = ChunkZone {
                    written: piece.clone(),
                    stats: ColumnStats::from_f32s(&piece_data),
                };
                (zone, self.cluster.write_object(depart, &obj, &bytes)?.finish)
            } else if self.cluster.object_exists(&obj) {
                // Partial update of an existing chunk: push the RMW
                // down. The handler returns the merged chunk's
                // recomputed stats — only the server sees that data.
                let local = offset_into(&piece, &chunk_slab)?;
                let t = self.cluster.call(
                    depart,
                    &obj,
                    "hdf5",
                    "write_slab",
                    &encode_slab_arg(&local, Some(&piece_data)),
                )?;
                let stats = ColumnStats::decode_from(&mut ByteReader::new(&t.value))?;
                let written = match zones.get(&chunk_idx) {
                    Some(z) => z.written.bbox_union(&piece)?,
                    None => piece.clone(),
                };
                (ChunkZone { written, stats }, t.finish)
            } else {
                // First touch of this chunk: materialize it zero-filled
                // with the piece applied, then write the whole object.
                // Stats cover the full stored buffer — padding zeros
                // included — so the zone bounds every byte a reader can
                // see.
                let space = Dataspace::new(&stored_dims)?;
                let mut chunk_data = vec![0.0f32; space.numel() as usize];
                copy_slab_f32(
                    &piece_data,
                    &piece_space,
                    &Hyperslab::whole(&piece_space),
                    &mut chunk_data,
                    &space,
                    &offset_into(&piece, &chunk_slab)?,
                )?;
                let bytes = encode_array_chunk(&chunk_data, &stored_dims)?;
                let zone = ChunkZone {
                    written: piece.clone(),
                    stats: ColumnStats::from_f32s(&chunk_data),
                };
                (zone, self.cluster.write_object(depart, &obj, &bytes)?.finish)
            };
            // Stamp the zone beside the chunk so storage-side tools can
            // recover it without the meta object.
            let x = self
                .cluster
                .setxattr(t_finish, &obj, metadata::CHUNK_ZONE_XATTR, &zone.encode())?;
            zones.insert(chunk_idx, zone);
            finish = finish.max(x.finish);
        }
        // Publish the refreshed zones: rewrite the meta object, which
        // also bumps the stamped content version readers revalidate
        // against.
        let meta = DatasetMeta::Array {
            space: grid.space.clone(),
            chunk: grid.chunk.clone(),
            zones: zones.clone(),
        };
        let finish = metadata::save_meta(&self.cluster, finish, dataset, &meta, true)?;
        let ver = metadata::content_version(&meta.encode());
        self.meta.insert(
            dataset.to_string(),
            CachedMeta {
                space: grid.space.clone(),
                chunk: grid.chunk.clone(),
                zones,
                ver,
            },
        );
        Ok(Timed::new((), finish))
    }

    fn read_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
    ) -> Result<Timed<Vec<f32>>> {
        self.read_filtered(at, dataset, slab, &Predicate::True)
    }

    fn read_slab_where(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        predicate: &Predicate,
    ) -> Result<Timed<Vec<f32>>> {
        self.read_filtered(at, dataset, slab, predicate)
    }

    fn shape(&mut self, at: f64, dataset: &str) -> Result<Timed<(Dataspace, Vec<u64>)>> {
        let (grid, _) = self.grid_zones(at, dataset)?;
        Ok(Timed::new(
            (grid.space.clone(), grid.chunk.clone()),
            at + self.cluster.cost().net_latency_s,
        ))
    }

    fn set_attr(&mut self, at: f64, dataset: &str, key: &str, value: &str) -> Result<Timed<()>> {
        let obj = naming::meta_object(dataset);
        if !self.cluster.object_exists(&obj) {
            return Err(Error::NotFound(format!("dataset {dataset}")));
        }
        self.cluster
            .setxattr(at, &obj, &format!("attr.{key}"), value.as_bytes())
            .map(|t| t.map(|_| ()))
    }

    fn get_attr(
        &mut self,
        at: f64,
        dataset: &str,
        key: &str,
    ) -> Result<Timed<Option<String>>> {
        let obj = naming::meta_object(dataset);
        if !self.cluster.object_exists(&obj) {
            return Err(Error::NotFound(format!("dataset {dataset}")));
        }
        let t = self.cluster.getxattr(at, &obj, &format!("attr.{key}"))?;
        Ok(t.map(|v| v.map(|b| String::from_utf8_lossy(&b).into_owned())))
    }

    fn list(&mut self, at: f64) -> Result<Timed<Vec<String>>> {
        let names = metadata::list_datasets(&self.cluster);
        Ok(Timed::new(
            names,
            at + self.cluster.cost().net_latency_s,
        ))
    }
}

/// Build a registry with all classes the forwarding plugin needs.
pub fn vol_registry() -> crate::store::ClassRegistry {
    let mut r = crate::store::ClassRegistry::with_builtins();
    super::local_plugin::register_hdf5_class(&mut r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::skyhook::query::CmpOp;
    use crate::vol::api::VolFile;

    fn make_cluster(osds: usize) -> Arc<Cluster> {
        let cfg = ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        };
        Cluster::new(&cfg, vol_registry())
    }

    fn file() -> VolFile {
        VolFile::open(Box::new(ForwardingBackend::new(make_cluster(4))))
    }

    fn file_with(policy: VolPolicy, cluster: &Arc<Cluster>) -> VolFile {
        VolFile::open(Box::new(
            ForwardingBackend::new(Arc::clone(cluster)).with_policy(policy),
        ))
    }

    #[test]
    fn conformance() {
        crate::vol::api::conformance(file);
    }

    #[test]
    fn conformance_static_policy() {
        crate::vol::api::conformance(|| {
            VolFile::open(Box::new(
                ForwardingBackend::new(make_cluster(4)).with_policy(VolPolicy::Static),
            ))
        });
    }

    #[test]
    fn conformance_forced_modes() {
        for mode in [ExecMode::Pushdown, ExecMode::ClientSide] {
            crate::vol::api::conformance(|| {
                VolFile::open(Box::new(
                    ForwardingBackend::new(make_cluster(4)).with_policy(VolPolicy::Forced(mode)),
                ))
            });
        }
    }

    #[test]
    fn chunks_become_objects() {
        let c = make_cluster(4);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        f.create_dataset("grid", &space, &[4, 4]).unwrap();
        f.write_all("grid", &(0..64).map(|i| i as f32).collect::<Vec<_>>())
            .unwrap();
        let objs = c.list_objects();
        // 4 chunk objects + 1 meta object.
        assert_eq!(objs.len(), 5);
        assert!(objs.contains(&"grid/a/00000000".to_string()));
        assert!(objs.contains(&"grid/_meta".to_string()));
    }

    #[test]
    fn unwritten_chunks_read_as_zero() {
        let mut f = file();
        let space = Dataspace::new(&[8, 8]).unwrap();
        f.create_dataset("z", &space, &[4, 4]).unwrap();
        // Write only the top-left chunk.
        let slab = Hyperslab::new(&[0, 0], &[4, 4]).unwrap();
        f.write("z", &slab, &vec![5.0; 16]).unwrap();
        let all = f.read_all("z").unwrap();
        assert_eq!(all[0], 5.0);
        assert_eq!(all[63], 0.0);
    }

    #[test]
    fn partial_write_pushes_rmw_down() {
        let c = make_cluster(2);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[4, 4]).unwrap();
        f.create_dataset("d", &space, &[4, 4]).unwrap();
        f.write_all("d", &vec![1.0; 16]).unwrap();
        // Partial update to one element — goes via hdf5.write_slab.
        f.write("d", &Hyperslab::new(&[1, 1], &[1, 1]).unwrap(), &[9.0])
            .unwrap();
        let all = f.read_all("d").unwrap();
        assert_eq!(all[5], 9.0);
        assert_eq!(all[0], 1.0);
    }

    #[test]
    fn partial_read_moves_fewer_bytes() {
        // Read 1 element from a 64x64 chunk: pushdown should move ~4
        // bytes, not 16 KiB.
        let c = make_cluster(2);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[64, 64]).unwrap();
        f.create_dataset("big", &space, &[64, 64]).unwrap();
        f.write_all("big", &(0..4096).map(|i| i as f32).collect::<Vec<_>>())
            .unwrap();
        let v = f
            .read("big", &Hyperslab::new(&[10, 10], &[1, 1]).unwrap())
            .unwrap();
        assert_eq!(v, vec![(10 * 64 + 10) as f32]);
    }

    #[test]
    fn parallelism_reduces_makespan() {
        // The Table-1 effect in miniature: same total data, more OSDs →
        // smaller virtual makespan.
        let elems = 1u64 << 18;
        let mut makespans = Vec::new();
        for osds in [1usize, 2, 4] {
            let c = make_cluster(osds);
            let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
            let space = Dataspace::new(&[elems]).unwrap();
            f.create_dataset("d", &space, &[elems / 8]).unwrap();
            let t0 = f.now();
            f.write_all("d", &vec![1.0f32; elems as usize]).unwrap();
            makespans.push(f.now() - t0);
        }
        assert!(
            makespans[1] < makespans[0] * 0.85,
            "2 OSDs should beat 1: {makespans:?}"
        );
        assert!(
            makespans[2] < makespans[1],
            "4 OSDs should beat 2: {makespans:?}"
        );
    }

    #[test]
    fn shape_errors_on_table_dataset() {
        let c = make_cluster(2);
        let meta = DatasetMeta::Table {
            schema: crate::dataset::TableSchema::new(&[("a", crate::dataset::DType::F32)]),
            layout: crate::dataset::Layout::Row,
            row_groups: vec![],
            localities: vec![],
            cluster_by: String::new(),
            index_cols: vec![],
            muta: Default::default(),
        };
        metadata::save_meta(&c, 0.0, "tab", &meta, false).unwrap();
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(c)));
        assert!(f.shape("tab").is_err());
    }

    #[test]
    fn writes_stamp_zone_maps() {
        let c = make_cluster(2);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        f.create_dataset("zm", &space, &[4, 4]).unwrap();
        // Touch chunk 0 fully, chunk 1 partially.
        f.write(
            "zm",
            &Hyperslab::new(&[0, 0], &[4, 4]).unwrap(),
            &(0..16).map(|i| i as f32).collect::<Vec<_>>(),
        )
        .unwrap();
        f.write("zm", &Hyperslab::new(&[1, 4], &[1, 2]).unwrap(), &[7.0, 8.0])
            .unwrap();
        let (meta, _) = metadata::load_meta(&c, 1.0, "zm").unwrap();
        let DatasetMeta::Array { zones, .. } = meta else {
            panic!("array meta expected");
        };
        // Chunk 0: whole-chunk write, full bbox, exact value range.
        let z0 = zones.get(&0).expect("chunk 0 zone");
        assert_eq!(z0.written, Hyperslab::new(&[0, 0], &[4, 4]).unwrap());
        assert_eq!((z0.stats.min, z0.stats.max), (0.0, 15.0));
        // Chunk 1: first-touch partial write; stats cover the padding
        // zeros too, so min is 0 even though only 7.0/8.0 were written.
        let z1 = zones.get(&1).expect("chunk 1 zone");
        assert_eq!(z1.written, Hyperslab::new(&[1, 4], &[1, 2]).unwrap());
        assert_eq!((z1.stats.min, z1.stats.max), (0.0, 8.0));
        // Unwritten chunks have no zone.
        assert!(!zones.contains_key(&2));
        // The per-chunk xattr mirrors the meta entry.
        let x = c
            .getxattr(1.0, "zm/a/00000001", metadata::CHUNK_ZONE_XATTR)
            .unwrap()
            .value
            .expect("zone xattr stamped");
        assert_eq!(ChunkZone::decode(&x).unwrap(), *z1);
        // RMW extends the written bbox and refreshes the value range.
        let mut f2 = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        f2.write("zm", &Hyperslab::new(&[3, 6], &[1, 1]).unwrap(), &[-2.0])
            .unwrap();
        let (meta, _) = metadata::load_meta(&c, 2.0, "zm").unwrap();
        let DatasetMeta::Array { zones, .. } = meta else {
            panic!("array meta expected");
        };
        let z1 = zones.get(&1).expect("chunk 1 zone after RMW");
        assert_eq!(z1.written, Hyperslab::new(&[1, 4], &[3, 3]).unwrap());
        assert_eq!((z1.stats.min, z1.stats.max), (-2.0, 8.0));
    }

    #[test]
    fn planned_read_prunes_and_matches_static() {
        let c = make_cluster(4);
        let mut w = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        w.create_dataset("p", &space, &[4, 4]).unwrap();
        // Only the left half of the dataset is ever written.
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        w.write("p", &Hyperslab::new(&[0, 0], &[8, 4]).unwrap(), &data)
            .unwrap();

        let read_slab = Hyperslab::new(&[2, 0], &[4, 8]).unwrap();
        let pred = Predicate::cmp("v", CmpOp::Ge, 100.0); // matches nothing
        let mut planned = file_with(VolPolicy::Planned, &c);
        let got_planned = planned.read_where("p", &read_slab, &pred).unwrap();
        let mut baseline = file_with(VolPolicy::Static, &c);
        let got_static = baseline.read_where("p", &read_slab, &pred).unwrap();
        assert_eq!(got_planned.len(), got_static.len());
        for (a, b) in got_planned.iter().zip(&got_static) {
            assert_eq!(a.to_bits(), b.to_bits(), "planned != static");
        }
        // The value range [0,31] proves Ge 100 matches nothing: every
        // existing chunk is pruned, nothing is fetched.
        // (Stats live on the backend; re-open to inspect via a fresh
        // backend handle instead.)
        let mut fb = ForwardingBackend::new(Arc::clone(&c));
        let t = fb
            .read_slab_where(0.0, "p", &read_slab, &pred)
            .unwrap();
        assert!(t.value.iter().all(|v| v.is_nan()));
        let s = fb.stats();
        assert_eq!(s.chunks_fetched, 0, "pruned chunks must not be fetched");
        assert_eq!(s.chunks_pruned, 2, "both written chunks value-pruned");
        // Each pruned piece is 2 rows x 4 cols of f32.
        assert_eq!(s.bytes_skipped, 2 * 8 * 4);
    }

    #[test]
    fn forced_modes_agree_bitwise() {
        let c = make_cluster(4);
        let mut w = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        w.create_dataset("f", &space, &[4, 4]).unwrap();
        let data: Vec<f32> = (0..64).map(|i| (i as f32 * 0.7).sin()).collect();
        w.write_all("f", &data).unwrap();
        let slab = Hyperslab::new(&[1, 1], &[6, 6]).unwrap();
        let pred = Predicate::cmp("v", CmpOp::Gt, 0.0);
        let mut push = ForwardingBackend::new(Arc::clone(&c))
            .with_policy(VolPolicy::Forced(ExecMode::Pushdown));
        let mut cli = ForwardingBackend::new(Arc::clone(&c))
            .with_policy(VolPolicy::Forced(ExecMode::ClientSide));
        let a = push.read_slab_where(0.0, "f", &slab, &pred).unwrap().value;
        let b = cli.read_slab_where(0.0, "f", &slab, &pred).unwrap().value;
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "push vs client diverged");
        }
        assert_eq!(push.stats().chunks_pushdown, push.stats().chunks_fetched);
        assert_eq!(cli.stats().chunks_client, cli.stats().chunks_fetched);
        assert_eq!(push.stats().rows_matched, cli.stats().rows_matched);
    }

    #[test]
    fn stale_meta_cache_revalidates_on_reprovision() {
        // Regression: handle B caches "d" as 8x8/[4,4]; the name is then
        // re-provisioned as 4x16/[2,8] behind its back. Without the
        // content-version check B would decompose reads against the dead
        // geometry.
        let c = make_cluster(2);
        let mut a = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        a.create_dataset("d", &space, &[4, 4]).unwrap();
        a.write_all("d", &vec![1.0; 64]).unwrap();

        let mut b = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        assert_eq!(b.shape("d").unwrap().0, space); // cache primed

        // Re-provision the name with a different shape (driver-side
        // path: overwrite the meta object directly).
        let new_space = Dataspace::new(&[4, 16]).unwrap();
        let meta = DatasetMeta::Array {
            space: new_space.clone(),
            chunk: vec![2, 8],
            zones: BTreeMap::new(),
        };
        metadata::save_meta(&c, 1.0, "d", &meta, true).unwrap();

        let (sp, ch) = b.shape("d").unwrap();
        assert_eq!(sp, new_space, "stale cached shape served");
        assert_eq!(ch, vec![2, 8]);
        // And a fresh create over the name invalidates A's cache even
        // though the create itself fails (the object exists).
        assert!(a.create_dataset("d", &space, &[4, 4]).is_err());
        assert_eq!(a.shape("d").unwrap().0, new_space);
    }

    #[test]
    fn metrics_counters_track_planned_reads() {
        let c = make_cluster(2);
        let m = Arc::new(Metrics::new());
        let mut w = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[4, 4]).unwrap();
        w.create_dataset("m", &space, &[2, 2]).unwrap();
        w.write(
            "m",
            &Hyperslab::new(&[0, 0], &[2, 2]).unwrap(),
            &[1.0, 2.0, 3.0, 4.0],
        )
        .unwrap();
        let mut fb = ForwardingBackend::new(Arc::clone(&c)).with_metrics(Arc::clone(&m));
        let slab = Hyperslab::new(&[0, 0], &[4, 4]).unwrap();
        let pred = Predicate::cmp("v", CmpOp::Gt, 10.0); // prunes chunk 0
        let _ = fb.read_slab_where(0.0, "m", &slab, &pred).unwrap();
        assert_eq!(m.counter("vol.chunks_pruned"), 1);
        assert_eq!(m.counter("vol.bytes_skipped"), 16);
        assert_eq!(m.counter("vol.chunks_pushdown"), 0);
    }
}
