//! The global/forwarding VOL plugin (Figure 2, top): decomposes hyperslab
//! requests into per-chunk sub-requests, scatters them to storage objects,
//! and gathers results (§4.1).
//!
//! Cost model (drives the E1/Table 1 reproduction): the plugin pays a
//! *serial* client-side serialization cost per byte forwarded
//! (`client_fwd_bw`, the paper's forwarding overhead), while the
//! per-chunk sub-requests fan out to OSDs whose device work overlaps —
//! "enough parallelism could offset this overhead" (§4.1).
//!
//! Read/write of partial chunks pushes `hdf5.read_slab`/`hdf5.write_slab`
//! down to the server-local plugin so only selected bytes cross the
//! network; whole-chunk requests use plain object reads/writes.

use super::api::{Timed, VolBackend};
use super::local_plugin::encode_slab_arg;
use crate::dataset::array::{copy_slab_f32, ChunkGrid};
use crate::dataset::layout::{decode_array_chunk, encode_array_chunk};
use crate::dataset::metadata::{self, DatasetMeta};
use crate::dataset::naming;
use crate::dataset::{Dataspace, Hyperslab};
use crate::error::{Error, Result};
use crate::simnet::Timeline;
use crate::store::Cluster;
use std::collections::HashMap;
use std::sync::Arc;

/// Forwarding backend over a cluster.
pub struct ForwardingBackend {
    cluster: Arc<Cluster>,
    /// Client-side serialization pipe (the forwarding overhead).
    client: Timeline,
    /// Cached immutable dataset metadata.
    meta: HashMap<String, (Dataspace, Vec<u64>)>,
}

impl ForwardingBackend {
    pub fn new(cluster: Arc<Cluster>) -> Self {
        Self {
            cluster,
            client: Timeline::new(),
            meta: HashMap::new(),
        }
    }

    /// The cluster this plugin forwards to.
    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    fn grid(&mut self, at: f64, dataset: &str) -> Result<ChunkGrid> {
        if let Some((space, chunk)) = self.meta.get(dataset) {
            return ChunkGrid::new(space.clone(), chunk);
        }
        let (meta, _) = metadata::load_meta(&self.cluster, at, dataset)?;
        match meta {
            DatasetMeta::Array { space, chunk } => {
                self.meta
                    .insert(dataset.to_string(), (space.clone(), chunk.clone()));
                ChunkGrid::new(space, &chunk)
            }
            _ => Err(Error::Invalid(format!("{dataset} is not an array dataset"))),
        }
    }

    /// Serial client-side forwarding cost for `bytes`, starting at `at`.
    fn forward(&self, at: f64, bytes: u64) -> f64 {
        self.client.submit(at, self.cluster.cost().client_fwd_time(bytes))
    }
}

impl VolBackend for ForwardingBackend {
    fn name(&self) -> &'static str {
        "forwarding"
    }

    fn create(
        &mut self,
        at: f64,
        dataset: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<Timed<()>> {
        ChunkGrid::new(space.clone(), chunk)?; // validate
        let meta = DatasetMeta::Array {
            space: space.clone(),
            chunk: chunk.to_vec(),
        };
        let finish = metadata::save_meta(&self.cluster, at, dataset, &meta, false)?;
        self.meta
            .insert(dataset.to_string(), (space.clone(), chunk.to_vec()));
        Ok(Timed::new((), finish))
    }

    fn write_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        data: &[f32],
    ) -> Result<Timed<()>> {
        let grid = self.grid(at, dataset)?;
        let pieces = grid.decompose(slab)?;
        let src_space = Dataspace::new(&slab.count)?;
        // Phase 1 (serial): the forwarding plugin serializes/mirrors the
        // whole request stream on the client — Table 1's constant `a`
        // term. Storage writes only start once their request stream
        // exists, so the phases do not overlap (the paper's t(n) = a + b/n
        // fit has a strictly serial client phase).
        let mut client_done = at;
        for (_, piece) in &pieces {
            client_done = self.forward(client_done, piece.numel() * 4);
        }
        let mut finish = client_done;
        for (chunk_idx, piece) in pieces {
            let obj = naming::array_object(dataset, chunk_idx);
            let chunk_slab = grid.chunk_slab(chunk_idx)?;
            let stored_dims = chunk_slab.count.clone();

            // Gather the piece's data out of the request buffer.
            let piece_space = Dataspace::new(&piece.count)?;
            let mut piece_data = vec![0.0f32; piece.numel() as usize];
            let src_slab = Hyperslab::new(
                &piece
                    .start
                    .iter()
                    .zip(&slab.start)
                    .map(|(p, s)| p - s)
                    .collect::<Vec<_>>(),
                &piece.count,
            )?;
            copy_slab_f32(
                data,
                &src_space,
                &src_slab,
                &mut piece_data,
                &piece_space,
                &Hyperslab::whole(&piece_space),
            )?;

            // Phase 2: storage ops fan out after the client phase,
            // overlapping across OSDs ("enough parallelism could offset
            // this overhead", §4.1).
            let depart = client_done;

            let whole_chunk = piece.count == stored_dims;
            let t = if whole_chunk {
                let bytes = encode_array_chunk(&piece_data, &stored_dims)?;
                self.cluster.write_object(depart, &obj, &bytes)?
            } else if self.cluster.object_exists(&obj) {
                // Partial update of an existing chunk: push the RMW down.
                let local = Hyperslab::new(
                    &piece
                        .start
                        .iter()
                        .zip(&chunk_slab.start)
                        .map(|(p, c)| p - c)
                        .collect::<Vec<_>>(),
                    &piece.count,
                )?;
                self.cluster
                    .call(
                        depart,
                        &obj,
                        "hdf5",
                        "write_slab",
                        &encode_slab_arg(&local, Some(&piece_data)),
                    )?
                    .map(|_| ())
            } else {
                // First touch of this chunk: materialize it zero-filled
                // with the piece applied, then write the whole object.
                let space = Dataspace::new(&stored_dims)?;
                let mut chunk_data = vec![0.0f32; space.numel() as usize];
                let local = Hyperslab::new(
                    &piece
                        .start
                        .iter()
                        .zip(&chunk_slab.start)
                        .map(|(p, c)| p - c)
                        .collect::<Vec<_>>(),
                    &piece.count,
                )?;
                copy_slab_f32(
                    &piece_data,
                    &piece_space,
                    &Hyperslab::whole(&piece_space),
                    &mut chunk_data,
                    &space,
                    &local,
                )?;
                let bytes = encode_array_chunk(&chunk_data, &stored_dims)?;
                self.cluster.write_object(depart, &obj, &bytes)?
            };
            finish = finish.max(t.finish);
        }
        Ok(Timed::new((), finish))
    }

    fn read_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
    ) -> Result<Timed<Vec<f32>>> {
        let grid = self.grid(at, dataset)?;
        let pieces = grid.decompose(slab)?;
        let out_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        let mut finish = at;
        for (chunk_idx, piece) in pieces {
            let obj = naming::array_object(dataset, chunk_idx);
            let chunk_slab = grid.chunk_slab(chunk_idx)?;
            let local = Hyperslab::new(
                &piece
                    .start
                    .iter()
                    .zip(&chunk_slab.start)
                    .map(|(p, c)| p - c)
                    .collect::<Vec<_>>(),
                &piece.count,
            )?;
            let piece_space = Dataspace::new(&piece.count)?;

            let whole_chunk = piece.count == chunk_slab.count;
            let piece_data: Vec<f32>;
            let t_finish: f64;
            if !self.cluster.object_exists(&obj) {
                // Never-written chunk: zeros (HDF5 fill value).
                piece_data = vec![0.0; piece.numel() as usize];
                t_finish = at + self.cluster.cost().net_latency_s;
            } else if whole_chunk {
                let t = self.cluster.read_object(at, &obj)?;
                let (data, dims) = decode_array_chunk(&t.value)?;
                if dims != chunk_slab.count {
                    return Err(Error::Corrupt(format!("chunk {obj} dims drifted")));
                }
                piece_data = data;
                t_finish = t.finish;
            } else {
                // Server-side selection: only selected bytes return.
                let t = self.cluster.call(
                    at,
                    &obj,
                    "hdf5",
                    "read_slab",
                    &encode_slab_arg(&local, None),
                )?;
                piece_data = crate::util::bytes::bytes_to_f32s(&t.value)?;
                t_finish = t.finish;
            }

            // Scatter into the output buffer.
            let dst_slab = Hyperslab::new(
                &piece
                    .start
                    .iter()
                    .zip(&slab.start)
                    .map(|(p, s)| p - s)
                    .collect::<Vec<_>>(),
                &piece.count,
            )?;
            copy_slab_f32(
                &piece_data,
                &piece_space,
                &Hyperslab::whole(&piece_space),
                &mut out,
                &out_space,
                &dst_slab,
            )?;
            finish = finish.max(t_finish);
        }
        Ok(Timed::new(out, finish))
    }

    fn shape(&mut self, at: f64, dataset: &str) -> Result<Timed<(Dataspace, Vec<u64>)>> {
        let grid = self.grid(at, dataset)?;
        Ok(Timed::new(
            (grid.space.clone(), grid.chunk.clone()),
            at + self.cluster.cost().net_latency_s,
        ))
    }

    fn set_attr(&mut self, at: f64, dataset: &str, key: &str, value: &str) -> Result<Timed<()>> {
        let obj = naming::meta_object(dataset);
        if !self.cluster.object_exists(&obj) {
            return Err(Error::NotFound(format!("dataset {dataset}")));
        }
        self.cluster
            .setxattr(at, &obj, &format!("attr.{key}"), value.as_bytes())
            .map(|t| t.map(|_| ()))
    }

    fn get_attr(
        &mut self,
        at: f64,
        dataset: &str,
        key: &str,
    ) -> Result<Timed<Option<String>>> {
        let obj = naming::meta_object(dataset);
        if !self.cluster.object_exists(&obj) {
            return Err(Error::NotFound(format!("dataset {dataset}")));
        }
        let t = self.cluster.getxattr(at, &obj, &format!("attr.{key}"))?;
        Ok(t.map(|v| v.map(|b| String::from_utf8_lossy(&b).into_owned())))
    }

    fn list(&mut self, at: f64) -> Result<Timed<Vec<String>>> {
        let names = metadata::list_datasets(&self.cluster);
        Ok(Timed::new(
            names,
            at + self.cluster.cost().net_latency_s,
        ))
    }
}

/// Build a registry with all classes the forwarding plugin needs.
pub fn vol_registry() -> crate::store::ClassRegistry {
    let mut r = crate::store::ClassRegistry::with_builtins();
    super::local_plugin::register_hdf5_class(&mut r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::vol::api::VolFile;

    fn make_cluster(osds: usize) -> Arc<Cluster> {
        let cfg = ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        };
        Cluster::new(&cfg, vol_registry())
    }

    fn file() -> VolFile {
        VolFile::open(Box::new(ForwardingBackend::new(make_cluster(4))))
    }

    #[test]
    fn conformance() {
        crate::vol::api::conformance(file);
    }

    #[test]
    fn chunks_become_objects() {
        let c = make_cluster(4);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[8, 8]).unwrap();
        f.create_dataset("grid", &space, &[4, 4]).unwrap();
        f.write_all("grid", &(0..64).map(|i| i as f32).collect::<Vec<_>>())
            .unwrap();
        let objs = c.list_objects();
        // 4 chunk objects + 1 meta object.
        assert_eq!(objs.len(), 5);
        assert!(objs.contains(&"grid/a/00000000".to_string()));
        assert!(objs.contains(&"grid/_meta".to_string()));
    }

    #[test]
    fn unwritten_chunks_read_as_zero() {
        let mut f = file();
        let space = Dataspace::new(&[8, 8]).unwrap();
        f.create_dataset("z", &space, &[4, 4]).unwrap();
        // Write only the top-left chunk.
        let slab = Hyperslab::new(&[0, 0], &[4, 4]).unwrap();
        f.write("z", &slab, &vec![5.0; 16]).unwrap();
        let all = f.read_all("z").unwrap();
        assert_eq!(all[0], 5.0);
        assert_eq!(all[63], 0.0);
    }

    #[test]
    fn partial_write_pushes_rmw_down() {
        let c = make_cluster(2);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[4, 4]).unwrap();
        f.create_dataset("d", &space, &[4, 4]).unwrap();
        f.write_all("d", &vec![1.0; 16]).unwrap();
        // Partial update to one element — goes via hdf5.write_slab.
        f.write("d", &Hyperslab::new(&[1, 1], &[1, 1]).unwrap(), &[9.0])
            .unwrap();
        let all = f.read_all("d").unwrap();
        assert_eq!(all[5], 9.0);
        assert_eq!(all[0], 1.0);
        // The objclass got invoked on some OSD.
        let cls_calls: u64 = (0..c.size() as u32)
            .map(|_| 0) // per-OSD counters checked via cluster counters below
            .sum();
        let _ = cls_calls;
    }

    #[test]
    fn partial_read_moves_fewer_bytes() {
        // Read 1 element from a 64x64 chunk: pushdown should move ~4
        // bytes, not 16 KiB.
        let c = make_cluster(2);
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
        let space = Dataspace::new(&[64, 64]).unwrap();
        f.create_dataset("big", &space, &[64, 64]).unwrap();
        f.write_all("big", &(0..4096).map(|i| i as f32).collect::<Vec<_>>())
            .unwrap();
        let v = f
            .read("big", &Hyperslab::new(&[10, 10], &[1, 1]).unwrap())
            .unwrap();
        assert_eq!(v, vec![(10 * 64 + 10) as f32]);
    }

    #[test]
    fn parallelism_reduces_makespan() {
        // The Table-1 effect in miniature: same total data, more OSDs →
        // smaller virtual makespan.
        let elems = 1u64 << 18;
        let mut makespans = Vec::new();
        for osds in [1usize, 2, 4] {
            let c = make_cluster(osds);
            let mut f = VolFile::open(Box::new(ForwardingBackend::new(Arc::clone(&c))));
            let space = Dataspace::new(&[elems]).unwrap();
            f.create_dataset("d", &space, &[elems / 8]).unwrap();
            let t0 = f.now();
            f.write_all("d", &vec![1.0f32; elems as usize]).unwrap();
            makespans.push(f.now() - t0);
        }
        assert!(
            makespans[1] < makespans[0] * 0.85,
            "2 OSDs should beat 1: {makespans:?}"
        );
        assert!(
            makespans[2] < makespans[1],
            "4 OSDs should beat 2: {makespans:?}"
        );
    }

    #[test]
    fn shape_errors_on_table_dataset() {
        let c = make_cluster(2);
        let meta = DatasetMeta::Table {
            schema: crate::dataset::TableSchema::new(&[("a", crate::dataset::DType::F32)]),
            layout: crate::dataset::Layout::Row,
            row_groups: vec![],
            localities: vec![],
            cluster_by: String::new(),
            index_cols: vec![],
        };
        metadata::save_meta(&c, 0.0, "tab", &meta, false).unwrap();
        let mut f = VolFile::open(Box::new(ForwardingBackend::new(c)));
        assert!(f.shape("tab").is_err());
    }
}
