//! The native single-file backend — the paper's baseline (Figure 1a).
//!
//! Models an unmodified access library writing one HDF5 file through the
//! local filesystem: datasets live contiguously in one in-memory "file",
//! all I/O is serviced by a single client-local device timeline at
//! `native_bw` (Table 1's 26.28 s for 3 GiB), and nothing scales out —
//! exactly the single-workstation limitation §1 and §6 call out.

use super::api::{Timed, VolBackend};
use crate::dataset::array::copy_slab_f32;
use crate::dataset::{Dataspace, Hyperslab};
use crate::error::{Error, Result};
use crate::simnet::{CostParams, Timeline};
use std::collections::BTreeMap;

struct NativeDataset {
    space: Dataspace,
    chunk: Vec<u64>,
    data: Vec<f32>,
    attrs: BTreeMap<String, String>,
}

/// Single-node, single-file backend.
pub struct NativeBackend {
    datasets: BTreeMap<String, NativeDataset>,
    device: Timeline,
    cost: CostParams,
}

impl NativeBackend {
    pub fn new(cost: CostParams) -> Self {
        Self {
            datasets: BTreeMap::new(),
            device: Timeline::new(),
            cost,
        }
    }

    fn dataset(&self, name: &str) -> Result<&NativeDataset> {
        self.datasets
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("dataset {name}")))
    }

    fn dataset_mut(&mut self, name: &str) -> Result<&mut NativeDataset> {
        self.datasets
            .get_mut(name)
            .ok_or_else(|| Error::NotFound(format!("dataset {name}")))
    }

    fn charge(&self, at: f64, bytes: u64) -> f64 {
        self.device
            .submit(at, self.cost.native_write_time(bytes))
    }
}

impl VolBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn create(
        &mut self,
        at: f64,
        dataset: &str,
        space: &Dataspace,
        chunk: &[u64],
    ) -> Result<Timed<()>> {
        if self.datasets.contains_key(dataset) {
            return Err(Error::AlreadyExists(format!("dataset {dataset}")));
        }
        self.datasets.insert(
            dataset.to_string(),
            NativeDataset {
                space: space.clone(),
                chunk: chunk.to_vec(),
                data: vec![0.0; space.numel() as usize],
                attrs: BTreeMap::new(),
            },
        );
        let finish = self.device.submit(at, self.cost.op_overhead_s);
        Ok(Timed::new((), finish))
    }

    fn write_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
        data: &[f32],
    ) -> Result<Timed<()>> {
        let cost_bytes = slab.numel() * 4;
        let ds = self.dataset_mut(dataset)?;
        if !slab.fits(&ds.space) {
            return Err(Error::Invalid("slab exceeds dataspace".into()));
        }
        let src_space = Dataspace::new(&slab.count)?;
        let space = ds.space.clone();
        copy_slab_f32(
            data,
            &src_space,
            &Hyperslab::whole(&src_space),
            &mut ds.data,
            &space,
            slab,
        )?;
        let finish = self.charge(at, cost_bytes);
        Ok(Timed::new((), finish))
    }

    fn read_slab(
        &mut self,
        at: f64,
        dataset: &str,
        slab: &Hyperslab,
    ) -> Result<Timed<Vec<f32>>> {
        let ds = self.dataset(dataset)?;
        if !slab.fits(&ds.space) {
            return Err(Error::Invalid("slab exceeds dataspace".into()));
        }
        let dst_space = Dataspace::new(&slab.count)?;
        let mut out = vec![0.0f32; slab.numel() as usize];
        copy_slab_f32(
            &ds.data,
            &ds.space,
            slab,
            &mut out,
            &dst_space,
            &Hyperslab::whole(&dst_space),
        )?;
        // Reads go through the same local device at read bandwidth.
        let finish = self
            .device
            .submit(at, self.cost.dev_read_time(slab.numel() * 4));
        Ok(Timed::new(out, finish))
    }

    fn shape(&mut self, at: f64, dataset: &str) -> Result<Timed<(Dataspace, Vec<u64>)>> {
        let ds = self.dataset(dataset)?;
        let v = (ds.space.clone(), ds.chunk.clone());
        let finish = self.device.submit(at, self.cost.op_overhead_s);
        Ok(Timed::new(v, finish))
    }

    fn set_attr(&mut self, at: f64, dataset: &str, key: &str, value: &str) -> Result<Timed<()>> {
        let ds = self.dataset_mut(dataset)?;
        ds.attrs.insert(key.to_string(), value.to_string());
        let finish = self.device.submit(at, self.cost.op_overhead_s);
        Ok(Timed::new((), finish))
    }

    fn get_attr(
        &mut self,
        at: f64,
        dataset: &str,
        key: &str,
    ) -> Result<Timed<Option<String>>> {
        let ds = self.dataset(dataset)?;
        let v = ds.attrs.get(key).cloned();
        let finish = self.device.submit(at, self.cost.op_overhead_s);
        Ok(Timed::new(v, finish))
    }

    fn list(&mut self, at: f64) -> Result<Timed<Vec<String>>> {
        let v: Vec<String> = self.datasets.keys().cloned().collect();
        let finish = self.device.submit(at, self.cost.op_overhead_s);
        Ok(Timed::new(v, finish))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vol::api::VolFile;

    fn file() -> VolFile {
        VolFile::open(Box::new(NativeBackend::new(CostParams::paper_testbed())))
    }

    #[test]
    fn conformance() {
        crate::vol::api::conformance(file);
    }

    #[test]
    fn writes_serialize_on_one_device() {
        // The native library cannot scale out: two dataset writes queue.
        let mut b = NativeBackend::new(CostParams::paper_testbed());
        let space = Dataspace::new(&[1 << 18]).unwrap();
        b.create(0.0, "a", &space, &[1 << 14]).unwrap();
        b.create(0.0, "b", &space, &[1 << 14]).unwrap();
        let data = vec![1.0f32; 1 << 18];
        let whole = Hyperslab::whole(&space);
        let t1 = b.write_slab(0.0, "a", &whole, &data).unwrap().finish;
        let t2 = b.write_slab(0.0, "b", &whole, &data).unwrap().finish;
        assert!(t2 > 1.9 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn native_rate_matches_calibration() {
        let mut b = NativeBackend::new(CostParams::paper_testbed());
        let n = 1u64 << 20; // elements
        let space = Dataspace::new(&[n]).unwrap();
        b.create(0.0, "d", &space, &[1 << 16]).unwrap();
        let data = vec![0.5f32; n as usize];
        let t = b
            .write_slab(0.0, "d", &Hyperslab::whole(&space), &data)
            .unwrap()
            .finish;
        let expect = (n * 4) as f64 / CostParams::paper_testbed().native_bw;
        assert!((t - expect).abs() / expect < 0.05, "t={t} expect={expect}");
    }

    #[test]
    fn backend_name() {
        let mut f = file();
        assert_eq!(f.backend_name(), "native");
        let space = Dataspace::new(&[4]).unwrap();
        f.create_dataset("d", &space, &[2]).unwrap();
        assert_eq!(f.list_datasets().unwrap().len(), 1);
    }
}
