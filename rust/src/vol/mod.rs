//! The HDF5-VOL-like access library (§4.1): one application-facing API,
//! swappable storage-facing backends.
//!
//! - [`api`] — `VolFile` + the `VolBackend` trait (the VOL boundary)
//! - [`native`] — single-file, single-node baseline backend (Figure 1a)
//! - [`global_plugin`] — forwarding plugin: decompose → scatter → gather
//! - [`local_plugin`] — per-object server-side plugin (`hdf5` objclass)

pub mod api;
pub mod global_plugin;
pub mod local_plugin;
pub mod native;

pub use api::{apply_value_mask, VolBackend, VolFile};
pub use global_plugin::{vol_registry, ForwardingBackend, VolPolicy, VolStats};
pub use local_plugin::{
    decode_where_response, encode_slab_where_arg, register_hdf5_class,
};
pub use native::NativeBackend;
