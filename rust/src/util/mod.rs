//! Foundation utilities built in-repo because the offline crate set lacks
//! the usual ecosystem crates (rand, rayon/tokio, criterion, proptest).

pub mod bench;
pub mod bytes;
pub mod pool;
pub mod quick;
pub mod rng;
pub mod stats;
