//! Byte-size formatting/parsing and little-endian codec helpers.
//!
//! The dataset layout and kv-store modules serialize fixed-width integers
//! by hand (no serde offline); these helpers centralize that and the
//! human-facing size strings used by the CLI and bench output.

use crate::error::{Error, Result};

/// Parse "4k", "16MiB", "1.5G", "512" (bytes) into a byte count.
pub fn parse_size(s: &str) -> Result<u64> {
    let s = s.trim();
    if s.is_empty() {
        return Err(Error::Config("empty size".into()));
    }
    let lower = s.to_ascii_lowercase();
    let (num_part, mult) = if let Some(p) = lower.strip_suffix("kib").or(lower.strip_suffix("kb")).or(lower.strip_suffix("k")) {
        (p, 1024u64)
    } else if let Some(p) = lower.strip_suffix("mib").or(lower.strip_suffix("mb")).or(lower.strip_suffix("m")) {
        (p, 1024 * 1024)
    } else if let Some(p) = lower.strip_suffix("gib").or(lower.strip_suffix("gb")).or(lower.strip_suffix("g")) {
        (p, 1024 * 1024 * 1024)
    } else if let Some(p) = lower.strip_suffix("b") {
        (p, 1)
    } else {
        (lower.as_str(), 1)
    };
    let num_part = num_part.trim();
    let value: f64 = num_part
        .parse()
        .map_err(|_| Error::Config(format!("bad size: {s:?}")))?;
    if value < 0.0 {
        return Err(Error::Config(format!("negative size: {s:?}")));
    }
    Ok((value * mult as f64).round() as u64)
}

/// Format a byte count as a human string ("1.50 MiB").
pub fn fmt_size(n: u64) -> String {
    const KIB: f64 = 1024.0;
    let n = n as f64;
    if n >= KIB * KIB * KIB {
        format!("{:.2} GiB", n / (KIB * KIB * KIB))
    } else if n >= KIB * KIB {
        format!("{:.2} MiB", n / (KIB * KIB))
    } else if n >= KIB {
        format!("{:.2} KiB", n / KIB)
    } else {
        format!("{n:.0} B")
    }
}

/// Incremental little-endian writer over a Vec<u8>.
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }
    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }
    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }
    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn i64(&mut self, v: i64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    /// Length-prefixed (u32) byte string.
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }
    /// Length-prefixed (u32) UTF-8 string.
    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }
    /// Raw bytes, no prefix.
    pub fn raw(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for ByteWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Incremental little-endian reader over a byte slice.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Corrupt(format!(
                "short read: need {n} at {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn str(&mut self) -> Result<&'a str> {
        let b = self.bytes()?;
        std::str::from_utf8(b).map_err(|_| Error::Corrupt("invalid utf8".into()))
    }
    pub fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    pub fn pos(&self) -> usize {
        self.pos
    }
}

/// Reinterpret a `&[f32]` as little-endian bytes (copy).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Parse little-endian bytes into f32s. Errors on misaligned length.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(Error::Corrupt(format!("f32 byte length {} % 4 != 0", b.len())));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("512").unwrap(), 512);
        assert_eq!(parse_size("512b").unwrap(), 512);
        assert_eq!(parse_size("4k").unwrap(), 4096);
        assert_eq!(parse_size("4KiB").unwrap(), 4096);
        assert_eq!(parse_size("4KB").unwrap(), 4096);
        assert_eq!(parse_size("2m").unwrap(), 2 * 1024 * 1024);
        assert_eq!(parse_size("1.5M").unwrap(), 3 * 512 * 1024);
        assert_eq!(parse_size("1g").unwrap(), 1 << 30);
        assert_eq!(parse_size(" 8k ").unwrap(), 8192);
    }

    #[test]
    fn parse_size_errors() {
        assert!(parse_size("").is_err());
        assert!(parse_size("abc").is_err());
        assert!(parse_size("-4k").is_err());
    }

    #[test]
    fn fmt_sizes() {
        assert_eq!(fmt_size(100), "100 B");
        assert_eq!(fmt_size(2048), "2.00 KiB");
        assert_eq!(fmt_size(3 * 1024 * 1024 / 2), "1.50 MiB");
        assert!(fmt_size(5 * 1024 * 1024 * 1024).contains("GiB"));
    }

    #[test]
    fn roundtrip_scalars() {
        let mut w = ByteWriter::new();
        w.u8(7).u16(300).u32(70_000).u64(1 << 40).i64(-5).f32(1.5).f64(-2.25);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i64().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn roundtrip_strings() {
        let mut w = ByteWriter::new();
        w.str("hello").bytes(&[1, 2, 3]).str("");
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.str().unwrap(), "");
    }

    #[test]
    fn short_read_is_error() {
        let buf = [1u8, 2];
        let mut r = ByteReader::new(&buf);
        assert!(r.u64().is_err());
    }

    #[test]
    fn invalid_utf8_is_error() {
        let mut w = ByteWriter::new();
        w.bytes(&[0xff, 0xfe]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert!(r.str().is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let xs = vec![1.0f32, -2.5, 0.0, f32::MAX];
        let b = f32s_to_bytes(&xs);
        assert_eq!(bytes_to_f32s(&b).unwrap(), xs);
        assert!(bytes_to_f32s(&b[..5]).is_err());
    }
}
