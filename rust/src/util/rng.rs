//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own small PRNG
//! family: [`SplitMix64`] for seeding/hashing and [`Xoshiro256`]
//! (xoshiro256++) as the workhorse generator. Both are well-studied,
//! public-domain algorithms; determinism matters more than
//! cryptographic strength here (placement hashing, synthetic workload
//! generation, property-test case generation all want reproducibility).

/// SplitMix64: tiny, fast, and the canonical way to seed xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Stateless one-shot mix of a 64-bit value; used for stable placement
/// hashing (CRUSH-style straw draws) where we need `hash(a, b)` without
/// carrying generator state.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Combine two 64-bit values into one well-mixed hash.
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    mix64(a ^ mix64(b))
}

/// xoshiro256++ — the general-purpose generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Widening multiply; rejection keeps the distribution exact.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == hi {
            return lo;
        }
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[lo, hi]` inclusive for usize.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Zipf-like draw over `[0, n)` with exponent `theta` using inverse
    /// transform on the truncated harmonic CDF. Used by the workload
    /// generators to model skewed object popularity.
    pub fn zipf(&mut self, n: usize, theta: f64) -> usize {
        debug_assert!(n > 0);
        if theta <= 0.0 {
            return self.range(0, n - 1);
        }
        // Sample by rejection against the integral envelope; O(1) per draw.
        let n_f = n as f64;
        if (theta - 1.0).abs() < 1e-9 {
            let h = n_f.ln();
            loop {
                let u = self.f64() * h;
                let x = u.exp();
                if x < n_f + 1.0 {
                    return (x as usize).min(n - 1);
                }
            }
        }
        let a = 1.0 - theta;
        let h = ((n_f + 1.0).powf(a) - 1.0) / a;
        loop {
            let u = self.f64() * h;
            let x = (u * a + 1.0).powf(1.0 / a);
            if x < n_f + 1.0 {
                return ((x - 1.0) as usize).min(n - 1);
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.range(0, j);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn xoshiro_below_bounds() {
        let mut r = Xoshiro256::new(42);
        for n in [1u64, 2, 3, 7, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn xoshiro_range_inclusive() {
        let mut r = Xoshiro256::new(1);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi, "range should hit both endpoints");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Xoshiro256::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn zipf_in_bounds_and_skewed() {
        let mut r = Xoshiro256::new(8);
        let n = 1000;
        let mut counts = vec![0usize; n];
        for _ in 0..50_000 {
            let v = r.zipf(n, 0.99);
            assert!(v < n);
            counts[v] += 1;
        }
        // Head should be much hotter than the tail.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[n - 10..].iter().sum();
        assert!(head > tail * 5, "head={head} tail={tail}");
    }

    #[test]
    fn zipf_theta_zero_is_uniformish() {
        let mut r = Xoshiro256::new(8);
        let n = 10;
        let mut counts = vec![0usize; n];
        for _ in 0..10_000 {
            counts[r.zipf(n, 0.0)] += 1;
        }
        for &c in &counts {
            assert!(c > 500, "uniform draw too skewed: {counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(2);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Xoshiro256::new(4);
        for _ in 0..100 {
            let v = r.sample_indices(50, 10);
            assert_eq!(v.len(), 10);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(*v.last().unwrap() < 50);
        }
    }

    #[test]
    fn sample_indices_full() {
        let mut r = Xoshiro256::new(4);
        let v = r.sample_indices(5, 5);
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fill_bytes_varies() {
        let mut r = Xoshiro256::new(6);
        let mut a = [0u8; 33];
        let mut b = [0u8; 33];
        r.fill_bytes(&mut a);
        r.fill_bytes(&mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn mix64_stable() {
        // Placement depends on these being stable across runs/builds.
        assert_eq!(mix64(0), mix64(0));
        assert_ne!(mix64(1), mix64(2));
        assert_ne!(mix2(1, 2), mix2(2, 1));
    }
}
