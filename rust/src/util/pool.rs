//! A small fixed-size thread pool with scoped joins.
//!
//! The offline crate set has no tokio/rayon, so the Skyhook driver/worker
//! layer and the simulated OSD service threads run on this pool. It is a
//! plain work-queue pool: submit boxed jobs, optionally wait on a
//! [`WaitGroup`], and shut down on drop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Mutex<mpsc::Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("skyhook-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        Self {
            tx: Mutex::new(tx),
            workers,
            size,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .lock()
            .unwrap()
            .send(Msg::Run(Box::new(f)))
            .expect("pool closed");
    }

    /// Submit a job tracked by a wait group.
    pub fn spawn_tracked<F: FnOnce() + Send + 'static>(&self, wg: &WaitGroup, f: F) {
        let guard = wg.add();
        self.spawn(move || {
            f();
            drop(guard);
        });
    }

    /// Run `f` over every item of `items` on the pool, collecting results
    /// in input order. Blocks until all complete.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let wg = WaitGroup::new();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.spawn_tracked(&wg, move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        wg.wait();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("worker did not report"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let tx = self.tx.lock().unwrap();
            for _ in 0..self.workers.len() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counter + condvar rendezvous: `add()` before submitting work, drop the
/// guard when the work finishes, `wait()` until the count returns to zero.
#[derive(Clone)]
pub struct WaitGroup {
    inner: Arc<WgInner>,
}

struct WgInner {
    count: AtomicUsize,
    mu: Mutex<()>,
    cv: Condvar,
}

/// RAII token returned by [`WaitGroup::add`].
pub struct WgGuard {
    inner: Arc<WgInner>,
}

impl WaitGroup {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(WgInner {
                count: AtomicUsize::new(0),
                mu: Mutex::new(()),
                cv: Condvar::new(),
            }),
        }
    }

    pub fn add(&self) -> WgGuard {
        self.inner.count.fetch_add(1, Ordering::SeqCst);
        WgGuard {
            inner: Arc::clone(&self.inner),
        }
    }

    pub fn pending(&self) -> usize {
        self.inner.count.load(Ordering::SeqCst)
    }

    pub fn wait(&self) {
        let mut g = self.inner.mu.lock().unwrap();
        while self.inner.count.load(Ordering::SeqCst) != 0 {
            g = self.inner.cv.wait(g).unwrap();
        }
    }
}

impl Default for WaitGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WgGuard {
    fn drop(&mut self) {
        if self.inner.count.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = self.inner.mu.lock().unwrap();
            self.inner.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let wg = WaitGroup::new();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn_tracked(&wg, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn pool_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_min_size_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn waitgroup_zero_waits_immediately() {
        let wg = WaitGroup::new();
        wg.wait(); // must not hang
        assert_eq!(wg.pending(), 0);
    }

    #[test]
    fn waitgroup_tracks_pending() {
        let wg = WaitGroup::new();
        let g1 = wg.add();
        let g2 = wg.add();
        assert_eq!(wg.pending(), 2);
        drop(g1);
        assert_eq!(wg.pending(), 1);
        drop(g2);
        wg.wait();
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        let wg = WaitGroup::new();
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.spawn_tracked(&wg, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        wg.wait();
        drop(pool);
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
