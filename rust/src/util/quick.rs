//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` generates random inputs with `gen`,
//! checks `prop`, and on failure greedily shrinks via the input's
//! [`Shrink`] implementation before panicking with the minimal
//! counterexample. Used across the store/dataset/coordinator tests for
//! invariants (placement stability, hyperslab algebra, batching bounds).

use super::rng::Xoshiro256;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-"smaller" values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self > 0 {
                out.push(self - 1);
            } else {
                out.push(self + 1);
            }
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out.retain(|x| x != self);
        out
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, then single elements, then shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 8 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for s in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = s;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

const MAX_SHRINK_STEPS: usize = 500;

/// Run `prop` against `cases` random inputs from `gen`; shrink and panic on
/// the first failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Xoshiro256::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // Shrink: repeatedly take the first failing candidate.
        let mut minimal = input;
        let mut steps = 0;
        'outer: while steps < MAX_SHRINK_STEPS {
            for cand in minimal.shrink() {
                steps += 1;
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if steps >= MAX_SHRINK_STEPS {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={seed}, case={case}):\n  minimal counterexample: {minimal:?}"
        );
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` so tests
/// can attach a reason.
pub fn forall_explain<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let last_reason = std::cell::RefCell::new(String::new());
    let wrapped = |t: &T| match prop(t) {
        Ok(()) => true,
        Err(e) => {
            *last_reason.borrow_mut() = e;
            false
        }
    };
    let mut rng = Xoshiro256::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if wrapped(&input) {
            continue;
        }
        let mut minimal = input;
        let mut steps = 0;
        'outer: while steps < MAX_SHRINK_STEPS {
            for cand in minimal.shrink() {
                steps += 1;
                if !wrapped(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if steps >= MAX_SHRINK_STEPS {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={seed}, case={case}): {}\n  minimal counterexample: {minimal:?}",
            last_reason.borrow()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(1, 200, |r| r.range_u64(0, 1000), |&x| x <= 1000);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 200, |r| r.range_u64(0, 1000), |&x| x < 500);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "x < 500" fails for x>=500; shrinker should reach 500.
        let result = std::panic::catch_unwind(|| {
            forall(3, 500, |r| r.range_u64(0, 100_000), |&x| x < 500);
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("500"), "expected minimal 500 in: {msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![1u64, 2, 3, 4];
        let cands = v.shrink();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4u64, 6u64);
        let cands = t.shrink();
        assert!(cands.iter().any(|c| c.0 < 4));
        assert!(cands.iter().any(|c| c.1 < 6));
    }

    #[test]
    fn forall_explain_reports_reason() {
        let result = std::panic::catch_unwind(|| {
            forall_explain(
                4,
                100,
                |r| r.range_u64(0, 100),
                |&x| {
                    if x < 90 {
                        Ok(())
                    } else {
                        Err(format!("too big: {x}"))
                    }
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("too big"), "{msg}");
    }
}
