//! Summary statistics and histograms for metrics and the bench harness.

/// Online summary of a stream of f64 samples (Welford for mean/variance,
/// plus min/max/sum). Cheap enough for per-request accounting.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Log-bucketed latency histogram (HdrHistogram-lite). Buckets grow
/// geometrically from `min_value`; quantile queries interpolate within a
/// bucket. Good to ~±5% which is plenty for bench reporting.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    min_value: f64,
    growth: f64,
    summary: Summary,
}

impl Histogram {
    /// `min_value`: smallest resolvable sample; `growth`: per-bucket factor.
    pub fn new(min_value: f64, growth: f64, buckets: usize) -> Self {
        assert!(min_value > 0.0 && growth > 1.0 && buckets > 1);
        Self {
            buckets: vec![0; buckets],
            min_value,
            growth,
            summary: Summary::new(),
        }
    }

    /// Defaults sized for latencies in seconds: 1 µs .. ~80 s.
    pub fn for_latency() -> Self {
        Self::new(1e-6, 1.12, 160)
    }

    fn index_of(&self, x: f64) -> usize {
        if x <= self.min_value {
            return 0;
        }
        let idx = (x / self.min_value).ln() / self.growth.ln();
        (idx as usize).min(self.buckets.len() - 1)
    }

    fn bucket_low(&self, i: usize) -> f64 {
        self.min_value * self.growth.powi(i as i32)
    }

    pub fn record(&mut self, x: f64) {
        let i = self.index_of(x.max(0.0));
        self.buckets[i] += 1;
        self.summary.record(x);
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.summary.merge(&other.summary);
    }

    pub fn count(&self) -> u64 {
        self.summary.count()
    }
    pub fn mean(&self) -> f64 {
        self.summary.mean()
    }
    pub fn min(&self) -> f64 {
        self.summary.min()
    }
    pub fn max(&self) -> f64 {
        self.summary.max()
    }

    /// Quantile in `[0,1]`; linear interpolation inside the bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.summary.count();
        if n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let into = (target - seen) as f64 / c as f64;
                let lo = self.bucket_low(i);
                let hi = self.bucket_low(i + 1);
                return (lo + (hi - lo) * into).clamp(self.summary.min(), self.summary.max());
            }
            seen += c;
        }
        self.summary.max()
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::for_latency()
    }
}

/// Exact percentile over a finite sample set (for bench reporting where we
/// keep all samples anyway). `q` in `[0,1]`; nearest-rank with interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn summary_merge_matches_sequential() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            if i % 2 == 0 {
                a.record(x)
            } else {
                b.record(x)
            }
            all.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
    }

    #[test]
    fn summary_merge_with_empty() {
        let mut a = Summary::new();
        a.record(5.0);
        let b = Summary::new();
        a.merge(&b);
        assert_eq!(a.count(), 1);
        let mut c = Summary::new();
        c.merge(&a);
        assert_eq!(c.count(), 1);
        assert_eq!(c.mean(), 5.0);
    }

    #[test]
    fn histogram_quantiles_close() {
        let mut h = Histogram::for_latency();
        // 1..=1000 ms uniform
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.p50();
        assert!((p50 - 0.5).abs() / 0.5 < 0.15, "p50={p50}");
        let p95 = h.p95();
        assert!((p95 - 0.95).abs() / 0.95 < 0.15, "p95={p95}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_single_value() {
        let mut h = Histogram::for_latency();
        h.record(0.010);
        assert_eq!(h.count(), 1);
        let p50 = h.p50();
        assert!((p50 - 0.010).abs() < 0.002, "p50={p50}");
        assert_eq!(h.min(), 0.010);
        assert_eq!(h.max(), 0.010);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::for_latency();
        let mut b = Histogram::for_latency();
        for i in 1..=100 {
            a.record(i as f64 * 1e-3);
            b.record(i as f64 * 2e-3);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.max() >= 0.2 * 0.99);
    }

    #[test]
    fn histogram_empty_quantile() {
        let h = Histogram::for_latency();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_out_of_range_clamps() {
        let mut h = Histogram::new(1e-3, 2.0, 8);
        h.record(1e9); // beyond last bucket
        h.record(1e-9); // below first bucket
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn percentile_exact() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert!((percentile(&v, 0.25) - 2.0).abs() < 1e-12);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
