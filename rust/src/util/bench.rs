//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Every file in `benches/` is a `harness = false` binary built on this
//! module: warmup, fixed sample count, mean/p50/p95, optional throughput,
//! and aligned table printing so each bench can emit the paper-style rows
//! the experiment reproduces.

use super::stats::percentile;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub bytes_per_iter: Option<u64>,
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.sorted(), 0.50)
    }
    pub fn p95(&self) -> f64 {
        percentile(&self.sorted(), 0.95)
    }
    pub fn min(&self) -> f64 {
        self.sorted().first().copied().unwrap_or(0.0)
    }

    /// MB/s if bytes_per_iter is set.
    pub fn throughput_mbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / (1024.0 * 1024.0) / self.mean())
    }

    /// items/s if items_per_iter is set.
    pub fn items_per_sec(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n as f64 / self.mean())
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    warmup_iters: usize,
    sample_iters: usize,
    min_samples: usize,
    max_seconds: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            sample_iters: 10,
            min_samples: 3,
            max_seconds: 20.0,
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }
    pub fn samples(mut self, n: usize) -> Self {
        self.sample_iters = n;
        self
    }
    pub fn max_seconds(mut self, s: f64) -> Self {
        self.max_seconds = s;
        self
    }

    /// Time `f` (called once per sample).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let started = Instant::now();
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            if started.elapsed().as_secs_f64() > self.max_seconds
                && samples.len() >= self.min_samples
            {
                break;
            }
        }
        BenchResult {
            name: name.to_string(),
            samples,
            bytes_per_iter: None,
            items_per_iter: None,
        }
    }

    /// Time `f` and annotate with bytes processed per iteration.
    pub fn run_bytes<F: FnMut()>(&self, name: &str, bytes: u64, f: F) -> BenchResult {
        let mut r = self.run(name, f);
        r.bytes_per_iter = Some(bytes);
        r
    }

    /// Time `f` and annotate with logical items per iteration.
    pub fn run_items<F: FnMut()>(&self, name: &str, items: u64, f: F) -> BenchResult {
        let mut r = self.run(name, f);
        r.items_per_iter = Some(items);
        r
    }
}

fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Print a criterion-style report for a set of results.
pub fn report(title: &str, results: &[BenchResult]) {
    println!();
    println!("=== {title} ===");
    let name_w = results
        .iter()
        .map(|r| r.name.len())
        .max()
        .unwrap_or(4)
        .max(4);
    println!(
        "{:<name_w$}  {:>12}  {:>12}  {:>12}  {:>14}",
        "case", "mean", "p50", "p95", "throughput"
    );
    for r in results {
        let thr = if let Some(m) = r.throughput_mbps() {
            format!("{m:.1} MB/s")
        } else if let Some(i) = r.items_per_sec() {
            format!("{i:.0} items/s")
        } else {
            "-".to_string()
        };
        println!(
            "{:<name_w$}  {:>12}  {:>12}  {:>12}  {:>14}",
            r.name,
            fmt_secs(r.mean()),
            fmt_secs(r.p50()),
            fmt_secs(r.p95()),
            thr
        );
    }
}

/// Print an arbitrary labelled table (for paper-style rows that are not
/// simple timings, e.g. bytes moved or speedup factors).
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!();
    println!("=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let header_line: Vec<String> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| format!("{:<w$}", h, w = widths[i]))
        .collect();
    println!("{}", header_line.join("  "));
    println!(
        "{}",
        widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>()
            .join("  ")
    );
    for row in rows {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
            .collect();
        println!("{}", line.join("  "));
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches have one import site).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench::new().warmup(1).samples(5);
        let r = b.run("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() >= 0.0);
        assert!(r.p50() <= r.p95() || (r.p50() - r.p95()).abs() < 1e-9);
    }

    #[test]
    fn bench_throughput() {
        let b = Bench::new().warmup(0).samples(3);
        let r = b.run_bytes("copy", 1024 * 1024, || {
            let v = vec![0u8; 1024 * 1024];
            black_box(v);
        });
        let t = r.throughput_mbps().unwrap();
        assert!(t > 0.0);
    }

    #[test]
    fn bench_items() {
        let b = Bench::new().warmup(0).samples(3);
        let r = b.run_items("iter", 1000, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.items_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn bench_time_budget_stops_early() {
        let b = Bench::new().warmup(0).samples(1000).max_seconds(0.05);
        let r = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(r.samples.len() < 1000);
        assert!(r.samples.len() >= 3);
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
