//! The Skyhook-Extension: object-class handlers that process table
//! objects *inside* the storage servers (§4.2) — remote select / project /
//! filter / aggregate, group-by partials, and an omap-backed secondary
//! index (the RocksDB-based "remote indexing system").
//!
//! `skyhook.exec` is the chained-pipeline entry point: it decodes one
//! [`PipelineSpec`] (filter → carry-projection → multi-aggregate /
//! multi-key grouped partials, or per-object top-k/head) and executes
//! the whole operator chain in a single pass over the object — one call,
//! one read set, one result. The evaluation itself lives in the shared
//! [`super::exec_kernel`]: the very same evaluator the client-side
//! worker runs (here with `ExecTier::Auto`, so the backend's profile
//! picks the compiled tier for eligible shapes it prices cheaper), so
//! both sides of the storage boundary produce bit-identical partials by
//! construction, and every CPU second charged here is priced by the
//! cluster-owned [`ExecProfile`] (`ClsBackend::exec_profile`) rather
//! than local constants. The single-operator handlers (`scan`, `agg`,
//! `group_agg`) remain for compatibility and direct use; `scan` and
//! `agg` share the zone map's sortedness markers through a windowed
//! read (binary-searched range conjuncts, prefix-bounded value-column
//! fetches).
//!
//! [`ExecProfile`]: crate::simnet::ExecProfile
//!
//! Every scan-shaped handler first consults the object's `skyhook.zonemap`
//! xattr: if the stamped per-column min/max statistics prove the predicate
//! matches zero rows, the handler answers with an empty result without
//! touching object data at all — the server-side half of the zone-map
//! pruning fast path (the planner-side half lives in `skyhook::plan`).
//!
//! When a PJRT engine is supplied (the AOT-compiled JAX/Pallas chunk
//! kernel, see `runtime::`), the masked f32 aggregation inside
//! `skyhook.agg` executes on it — the paper's storage-side compute
//! offload running the very kernel the L1/L2 layers compiled.

use super::exec_kernel::{self, run_pipeline_premasked, ExecTier};
use super::logical::{index_probe_window, IndexProbe, PipelineSpec};
use super::query::{AggState, Aggregate, Predicate};
use crate::dataset::layout::{self, decode_batch, encode_batch, Layout, RangeSource};
use crate::dataset::metadata::{ZoneMap, ZONE_MAP_XATTR};
use crate::dataset::table::{Batch, Column};
use crate::dataset::{DType, TableSchema};
use crate::error::{Error, Result};
use crate::store::objclass::{ClassRegistry, ClsBackend};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::sync::Arc;

// The compute-engine trait and pipeline output now live in the shared
// execution kernel; re-exported here so existing paths keep working.
pub use super::exec_kernel::{ChunkCompute, ExecOut};

/// Encode the input of `skyhook.scan`: predicate + projection +
/// whether the handler may consult the object's zone map (`zone_maps =
/// false` forces a real read — the unpruned bench baseline).
pub fn encode_scan_arg(pred: &Predicate, projection: Option<&[String]>, zone_maps: bool) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    match projection {
        Some(cols) => {
            w.u8(1);
            w.u32(cols.len() as u32);
            for c in cols {
                w.str(c);
            }
        }
        None => {
            w.u8(0);
        }
    }
    w.u8(zone_maps as u8);
    w.finish()
}

fn decode_scan_arg(input: &[u8]) -> Result<(Predicate, Option<Vec<String>>, bool)> {
    let mut r = ByteReader::new(input);
    let pred = Predicate::decode_from(&mut r)?;
    let projection = match r.u8()? {
        0 => None,
        1 => {
            let n = r.u32()? as usize;
            let mut cols = Vec::with_capacity(n);
            for _ in 0..n {
                cols.push(r.str()?.to_string());
            }
            Some(cols)
        }
        o => return Err(Error::Corrupt(format!("bad projection tag {o}"))),
    };
    let zone_maps = r.u8()? != 0;
    Ok((pred, projection, zone_maps))
}

/// Encode the input of `skyhook.agg`: predicate + aggregate list +
/// whether raw values must be returned (holistic finalization) + whether
/// the zone-map short-circuit is allowed.
pub fn encode_agg_arg(
    pred: &Predicate,
    aggs: &[Aggregate],
    keep_values: bool,
    zone_maps: bool,
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    w.u8(keep_values as u8);
    w.u32(aggs.len() as u32);
    for a in aggs {
        w.str(&a.col);
        w.u8(a.func.code());
    }
    w.u8(zone_maps as u8);
    w.finish()
}

fn decode_agg_arg(input: &[u8]) -> Result<(Predicate, bool, Vec<String>, bool)> {
    let mut r = ByteReader::new(input);
    let pred = Predicate::decode_from(&mut r)?;
    let keep_values = r.u8()? != 0;
    let n = r.u32()? as usize;
    let mut cols = Vec::with_capacity(n);
    for _ in 0..n {
        cols.push(r.str()?.to_string());
        let _func = r.u8()?; // per-agg func is only needed at finalize time
    }
    let zone_maps = r.u8()? != 0;
    Ok((pred, keep_values, cols, zone_maps))
}

/// Encode the input of `skyhook.group_agg`.
pub fn encode_group_arg(
    pred: &Predicate,
    group_col: &str,
    agg_col: &str,
    zone_maps: bool,
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    w.str(group_col);
    w.str(agg_col);
    w.u8(zone_maps as u8);
    w.finish()
}

/// Decode the output of `skyhook.agg`: one state per requested aggregate.
pub fn decode_agg_out(out: &[u8]) -> Result<Vec<AggState>> {
    let mut r = ByteReader::new(out);
    let n = r.u32()? as usize;
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        states.push(AggState::decode_from(&mut r)?);
    }
    Ok(states)
}

/// Decode the output of `skyhook.group_agg`: (group key, state) pairs.
pub fn decode_group_out(out: &[u8]) -> Result<Vec<(i64, AggState)>> {
    let mut r = ByteReader::new(out);
    let n = r.u32()? as usize;
    let mut groups = Vec::with_capacity(n);
    for _ in 0..n {
        let key = r.i64()?;
        groups.push((key, AggState::decode_from(&mut r)?));
    }
    Ok(groups)
}

/// Execution counters a `skyhook.exec` response carries back alongside
/// its payload — the storage server's own account of the sortedness
/// fast paths it took, so `QueryStats` can report prefix reads and
/// short-circuited rows for pushdown exactly like for client-side
/// execution (where the worker counts them itself).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecCounters {
    /// Rows the kernel never charged for thanks to binary-searched run
    /// boundaries on a sorted column.
    pub rows_short_circuited: u64,
    /// Did the handler serve the partial from a bounded prefix read?
    pub prefix_read: bool,
    /// Fixed-size chunks the compiled execution tier launched (0 = the
    /// scalar tier ran) — the server's report of which tier executed.
    pub compiled_chunks: u64,
    /// Rows the compiled tier's chunked pass covered.
    pub compiled_rows: u64,
    /// Secondary-index probes the handler served the request with (0 or
    /// 1 per object: one `ix1/` omap range scan pre-masking the read).
    pub index_probes: u64,
    /// Row-id postings the probe returned (the pre-mask's population).
    pub index_postings: u64,
}

/// Frame tag of a counter-carrying `skyhook.exec` response (payload tags
/// are 0/1/2; unframed responses decode with zero counters).
const EXEC_FRAME_TAG: u8 = 4;

fn frame_exec_out(counters: ExecCounters, inner: Vec<u8>) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(inner.len() + 42);
    w.u8(EXEC_FRAME_TAG);
    w.u64(counters.rows_short_circuited);
    w.u8(counters.prefix_read as u8);
    w.u64(counters.compiled_chunks);
    w.u64(counters.compiled_rows);
    w.u64(counters.index_probes);
    w.u64(counters.index_postings);
    w.raw(&inner);
    w.finish()
}

/// Decode a `skyhook.exec` result (payload only; counters discarded).
/// `nkeys`/`naggs` come from the [`PipelineSpec`] the caller sent.
pub fn decode_exec_out(out: &[u8], nkeys: usize, naggs: usize) -> Result<ExecOut> {
    decode_exec_out_full(out, nkeys, naggs).map(|(o, _)| o)
}

/// Decode a `skyhook.exec` result with its execution counters.
pub fn decode_exec_out_full(
    out: &[u8],
    nkeys: usize,
    naggs: usize,
) -> Result<(ExecOut, ExecCounters)> {
    if out.first() == Some(&EXEC_FRAME_TAG) {
        let mut r = ByteReader::new(&out[1..]);
        let counters = ExecCounters {
            rows_short_circuited: r.u64()?,
            prefix_read: r.u8()? != 0,
            compiled_chunks: r.u64()?,
            compiled_rows: r.u64()?,
            index_probes: r.u64()?,
            index_postings: r.u64()?,
        };
        let inner = r.raw(r.remaining())?.to_vec();
        return Ok((decode_exec_payload(&inner, nkeys, naggs)?, counters));
    }
    Ok((
        decode_exec_payload(out, nkeys, naggs)?,
        ExecCounters::default(),
    ))
}

fn decode_exec_payload(out: &[u8], nkeys: usize, naggs: usize) -> Result<ExecOut> {
    let Some((&tag, rest)) = out.split_first() else {
        return Err(Error::Corrupt("empty exec output".into()));
    };
    match tag {
        0 => Ok(ExecOut::Rows(decode_batch(rest)?.0)),
        1 => {
            let mut r = ByteReader::new(rest);
            let mut states = Vec::with_capacity(naggs);
            for _ in 0..naggs {
                states.push(AggState::decode_from(&mut r)?);
            }
            Ok(ExecOut::Aggs(states))
        }
        2 => {
            let mut r = ByteReader::new(rest);
            let n = r.u32()? as usize;
            let mut groups = Vec::with_capacity(n);
            for _ in 0..n {
                let mut key = Vec::with_capacity(nkeys);
                for _ in 0..nkeys {
                    key.push(r.i64()?);
                }
                let mut states = Vec::with_capacity(naggs);
                for _ in 0..naggs {
                    states.push(AggState::decode_from(&mut r)?);
                }
                groups.push((key, states));
            }
            Ok(ExecOut::Groups(groups))
        }
        o => Err(Error::Corrupt(format!("bad exec output tag {o}"))),
    }
}

/// Order-preserving big-endian encoding of i64 (for omap index keys).
pub fn index_key_i64(x: i64) -> [u8; 8] {
    ((x as u64) ^ (1u64 << 63)).to_be_bytes()
}

/// Order-preserving total-order encoding of f32 (for omap index keys):
/// flip the sign bit of non-negatives, complement negatives. Byte order
/// then matches `f32::total_cmp` exactly — `-NaN < -inf < … < -0.0 <
/// +0.0 < … < +inf < NaN` — so every value, NaN included, has a
/// well-defined slot and range probes over encoded keys are value-range
/// probes.
pub fn index_key_f32(x: f32) -> [u8; 4] {
    let b = x.to_bits();
    let b = if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    };
    b.to_be_bytes()
}

/// Versioned omap key prefix of one column's postings: `ix1/<col>/`.
/// Full posting keys append the order-preserving value encoding plus the
/// big-endian row id (making keys unique per row); values hold the row
/// id little-endian. Bumping the `ix1` version retires old postings
/// without a migration — probes only read their own scheme. Public so
/// `metadata::verify_index`'s debug re-scan can recompute the exact
/// posting set an object ought to carry.
pub fn index_prefix(col: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(col.len() + 5);
    p.extend_from_slice(b"ix1/");
    p.extend_from_slice(col.as_bytes());
    p.push(b'/');
    p
}

/// Versioned omap key of an object's delete vector (the `dv1/` scheme):
/// one bitmap covering every row of the object, bit set = row
/// tombstoned. The whole bitmap lives under a single key and is replaced
/// wholesale on every delete — `ClsBackend` has no per-key omap delete,
/// and object deletion already drops all omap keys, so whole-value
/// overwrite is both the simplest and the only correct update primitive
/// the store offers. Bumping `dv1` retires old vectors without a
/// migration, exactly like `ix1/`.
pub const DV_KEY: &[u8] = b"dv1/bitmap";

/// Delete-vector wire magic; followed by a version byte, a little-endian
/// u32 row count, and `ceil(rows/8)` bitmap bytes (LSB-first per byte).
const DV_MAGIC: &[u8; 4] = b"SKDV";

/// Encode a delete vector (`true` = row tombstoned).
pub fn encode_dv(deleted: &[bool]) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + deleted.len() / 8 + 1);
    out.extend_from_slice(DV_MAGIC);
    out.push(1);
    out.extend_from_slice(&(deleted.len() as u32).to_le_bytes());
    let mut byte = 0u8;
    for (i, &d) in deleted.iter().enumerate() {
        if d {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if deleted.len() % 8 != 0 {
        out.push(byte);
    }
    out
}

/// Decode a delete vector. Unknown versions and length mismatches are
/// hard errors, not advisory fallbacks: a dv that cannot be read exactly
/// must never silently resurrect deleted rows.
pub fn decode_dv(raw: &[u8]) -> Result<Vec<bool>> {
    if raw.len() < 9 || &raw[..4] != DV_MAGIC {
        return Err(Error::Corrupt("bad delete-vector magic".into()));
    }
    if raw[4] != 1 {
        return Err(Error::Corrupt(format!(
            "unknown delete-vector version {}",
            raw[4]
        )));
    }
    let n = u32::from_le_bytes(raw[5..9].try_into().unwrap()) as usize;
    let bits = &raw[9..];
    if bits.len() != (n + 7) / 8 {
        return Err(Error::Corrupt("delete-vector length mismatch".into()));
    }
    Ok((0..n).map(|i| bits[i / 8] & (1 << (i % 8)) != 0).collect())
}

/// AND the object's delete vector (if any) into a handler's eval mask:
/// a tombstoned row can never contribute, whatever the handler computed
/// for it. Mask index i is object row i — every read path here returns
/// row-0-based prefixes, so truncated batches stay aligned.
fn apply_dv_mask(b: &mut dyn ClsBackend, mask: &mut [bool]) -> Result<()> {
    let Some(raw) = b.omap_get(DV_KEY) else {
        return Ok(());
    };
    let deleted = decode_dv(&raw)?;
    for (i, m) in mask.iter_mut().enumerate() {
        if deleted.get(i).copied().unwrap_or(false) {
            *m = false;
        }
    }
    Ok(())
}

/// One representable f32 step toward -inf, used to widen probe lower
/// bounds: the predicate compares in f64, the index keys in f32, and the
/// f64→f32 rounding can land up to half an ulp *past* the true bound —
/// stepping once absorbs that, and widening a probe window is always
/// safe (superset), narrowing never is. Zeros step below **-0.0**: the
/// f64 comparison cannot tell the zeros apart, the total-order key
/// encoding can.
fn f32_step_down(x: f32) -> f32 {
    if x.is_nan() || x == f32::NEG_INFINITY {
        x
    } else if x == 0.0 {
        f32::from_bits(0x8000_0001)
    } else if x.to_bits() & 0x8000_0000 == 0 {
        f32::from_bits(x.to_bits() - 1)
    } else {
        f32::from_bits(x.to_bits() + 1)
    }
}

/// One representable f32 step toward +inf (see [`f32_step_down`]).
fn f32_step_up(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        x
    } else if x == 0.0 {
        f32::from_bits(0x0000_0001)
    } else if x.to_bits() & 0x8000_0000 == 0 {
        f32::from_bits(x.to_bits() + 1)
    } else {
        f32::from_bits(x.to_bits() - 1)
    }
}

/// Smallest i64 an index probe's lower bound must include so that every
/// row satisfying `x > v` / `x >= v` (compared after i64→f64 widening,
/// like [`Predicate`] does) is covered. Exact below 2^53, where the
/// widening is lossless; above it the widening rounds by up to half an
/// ulp, so the bound absorbs a 4-epsilon relative margin instead.
fn i64_probe_lo(v: f64, inclusive: bool) -> i64 {
    const EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    if v.abs() <= EXACT {
        if inclusive {
            v.ceil() as i64
        } else {
            (v.floor() as i64).saturating_add(1)
        }
    } else {
        (v - v.abs() * (4.0 * f64::EPSILON)) as i64
    }
}

/// Largest i64 the probe's upper bound must include (see
/// [`i64_probe_lo`]).
fn i64_probe_hi(v: f64, inclusive: bool) -> i64 {
    const EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
    if v.abs() <= EXACT {
        if inclusive {
            v.floor() as i64
        } else {
            (v.ceil() as i64).saturating_sub(1)
        }
    } else {
        (v + v.abs() * (4.0 * f64::EPSILON)) as i64
    }
}

/// Outcome of encoding a probe window into the `ix1/` key space.
enum ProbeKeys {
    /// `(lo_key, hi_key, hi_inclusive)` — scan this omap range.
    Range(Vec<u8>, Vec<u8>, bool),
    /// The window is non-empty over f64 but contains no representable
    /// key of this dtype (e.g. `x > 5 AND x < 6` over i64 tightens to
    /// the inverted integer range `[6, 5]`): provably zero rows. The
    /// caller must prune — issuing the inverted range as a `scan_range`
    /// would hand `BTreeMap::range` a start past its end, which panics.
    Empty,
}

/// Encode an [`IndexProbe`]'s value window as an omap key range over the
/// column's `ix1/` postings: `(lo_key, hi_key, hi_inclusive)`, with
/// bounds widened per the dtype rules above so rounding between the f64
/// comparison domain and the stored encoding can only *add* candidate
/// rows. An unbounded side becomes the column prefix itself (lo) or the
/// prefix's exclusive successor (hi). A window that inverts once encoded
/// (lo key > hi key) is [`ProbeKeys::Empty`]. Returns `None` for a dtype
/// tag this version does not understand — the handler falls back to a
/// scan.
fn probe_key_range(col: &str, tag: &[u8], probe: &IndexProbe) -> Option<ProbeKeys> {
    let prefix = index_prefix(col);
    let enc_lo: Vec<u8>;
    let enc_hi: Option<Vec<u8>>;
    match tag {
        b"i64" => {
            enc_lo = probe
                .lo
                .map(|(v, inc)| index_key_i64(i64_probe_lo(v, inc)).to_vec())
                .unwrap_or_default();
            enc_hi = probe
                .hi
                .map(|(v, inc)| index_key_i64(i64_probe_hi(v, inc)).to_vec());
        }
        b"f32" => {
            enc_lo = probe
                .lo
                .map(|(v, _)| index_key_f32(f32_step_down(v as f32)).to_vec())
                .unwrap_or_default();
            enc_hi = probe
                .hi
                .map(|(v, _)| index_key_f32(f32_step_up(v as f32)).to_vec());
        }
        _ => return None,
    }
    // Inverted encoded window: both bounds present and the widened lower
    // key sorts above the widened upper key (same fixed width per dtype,
    // so lexicographic compare is value compare). `index_probe_window`
    // catches f64-level contradictions; this catches the ones the
    // integer tightening itself manufactures.
    if let Some(enc) = &enc_hi {
        if !enc_lo.is_empty() && enc_lo > *enc {
            return Some(ProbeKeys::Empty);
        }
    }
    let mut lo = prefix.clone();
    lo.extend_from_slice(&enc_lo);
    match enc_hi {
        Some(enc) => {
            let mut hi = prefix;
            hi.extend_from_slice(&enc);
            // Past any 4-byte row-id suffix of the bound value.
            hi.extend_from_slice(&[0xff; 4]);
            Some(ProbeKeys::Range(lo, hi, true))
        }
        None => {
            // Exclusive successor of the column prefix: bump the '/'
            // terminator (never 0xff, so this cannot overflow).
            let mut hi = prefix;
            *hi.last_mut().expect("prefix is never empty") = b'/' + 1;
            Some(ProbeKeys::Range(lo, hi, false))
        }
    }
}

/// [`RangeSource`] over a `ClsBackend`: ranged reads are metered by the
/// OSD, so untouched columns cost no simulated device time.
struct BackendRange<'a>(&'a mut dyn ClsBackend);

impl RangeSource for BackendRange<'_> {
    fn size(&mut self) -> Result<usize> {
        self.0.size()
    }
    fn read_range(&mut self, offset: usize, len: usize) -> Result<Vec<u8>> {
        self.0.read_range(offset, len)
    }
    fn read_all(&mut self) -> Result<Vec<u8>> {
        self.0.read()
    }
}

/// Read only the columns a handler needs (ranged device reads on Col
/// objects; see [`layout::read_projected`]). `needed = None` reads
/// everything. The prefix size is the cluster's configured knob.
fn read_needed(b: &mut dyn ClsBackend, needed: Option<&[String]>) -> Result<Batch> {
    let prefix = b.header_prefix();
    layout::read_projected(&mut BackendRange(b), needed, prefix)
}

/// Union of column names used by a predicate and an extra set.
fn needed_union(pred: &Predicate, extra: &[String]) -> Vec<String> {
    let mut v: Vec<String> = pred.columns().into_iter().map(str::to_string).collect();
    v.extend(extra.iter().cloned());
    v.sort();
    v.dedup();
    v
}

/// The single-operator handlers' sort-aware read: when the object's zone
/// map marks a column of the predicate sorted, probe that column alone
/// first, binary-search the matching window
/// (`exec_kernel::sorted_window`), and bound the remaining columns' read
/// to the window-covering row prefix — the clustered-layout payoff
/// `skyhook.exec` gets from `prefix_limit`, brought to handlers that
/// cannot express a row limit. Without an applicable marker this is one
/// plain projected read.
///
/// Returns the (possibly prefix-truncated) batch, the matching window
/// within it, and whether the read was actually bounded. Rows past the
/// window are provably non-matching under the marker's non-decreasing
/// promise — the same trust `prefix_limit` already places in it — so
/// truncation never changes results.
fn read_windowed(
    b: &mut dyn ClsBackend,
    pred: &Predicate,
    needed: Option<&[String]>,
    sorted_cols: &[String],
) -> Result<(Batch, (usize, usize), bool)> {
    let sorted = |c: &str| sorted_cols.iter().any(|s| s == c);
    let pcols = pred.columns();
    let probe_cols: Vec<String> = match needed {
        Some(needed) => needed
            .iter()
            .filter(|c| sorted(c) && pcols.contains(&c.as_str()))
            .cloned()
            .collect(),
        // An unprojected read cannot name "the other columns" before
        // seeing the header, so it cannot split into probe + rest.
        None => Vec::new(),
    };
    if probe_cols.is_empty() {
        let batch = read_needed(b, needed)?;
        let w = exec_kernel::sorted_window(pred, &batch, &sorted);
        return Ok((batch, w, false));
    }
    let prefix = b.header_prefix();
    let probe = layout::read_projected(&mut BackendRange(b), Some(&probe_cols), prefix)?;
    let n = probe.nrows();
    let (wlo, whi) = exec_kernel::sorted_window(pred, &probe, &sorted);
    let rest_cols: Vec<String> = needed
        .unwrap_or(&[])
        .iter()
        .filter(|c| !probe_cols.contains(c))
        .cloned()
        .collect();
    if rest_cols.is_empty() {
        return Ok((probe, (wlo, whi), false));
    }
    let (rest, bounded) = if whi < n {
        let (rest, _, bounded) = layout::read_projected_rows(
            &mut BackendRange(b),
            Some(&rest_cols),
            prefix,
            whi as u64,
        )?;
        (rest, bounded)
    } else {
        (
            layout::read_projected(&mut BackendRange(b), Some(&rest_cols), prefix)?,
            false,
        )
    };
    // Stitch probe + rest at the shorter row count (the bounded read's
    // prefix; equal when unbounded). The dropped probe tail is outside
    // the window.
    let cut = n.min(rest.nrows());
    let probe = if probe.nrows() > cut {
        probe.slice(0, cut)?
    } else {
        probe
    };
    let rest = if rest.nrows() > cut {
        rest.slice(0, cut)?
    } else {
        rest
    };
    let mut schema_cols: Vec<(&str, DType)> = Vec::new();
    let mut columns = Vec::new();
    for batch in [&probe, &rest] {
        for (cs, col) in batch.schema.columns.iter().zip(&batch.columns) {
            schema_cols.push((cs.name.as_str(), cs.dtype));
            columns.push(col.clone());
        }
    }
    let batch = Batch::new(TableSchema::new(&schema_cols), columns)?;
    Ok((batch, (wlo.min(cut), whi.min(cut)), bounded))
}

/// Decode the object's stamped zone map, if present and parseable. An
/// unknown wire version decodes to `None` like a missing xattr — the
/// advisory fast paths (pruning, sortedness) switch off, results never
/// change.
fn zone_map_of(b: &mut dyn ClsBackend) -> Option<ZoneMap> {
    ZoneMap::decode(&b.getxattr(ZONE_MAP_XATTR)?).ok()
}

/// Zone-map pruning verdict: if the stamped statistics prove `pred`
/// matches zero rows, return the object's schema so the handler can
/// answer without reading any object data. Inconclusive maps return
/// `None` (handler proceeds normally), so the check can only skip work,
/// never change results.
fn prune_verdict(zm: &ZoneMap, pred: &Predicate) -> Option<TableSchema> {
    // Error parity: a predicate that would fail evaluation (missing or
    // string-typed column) must fail identically, so never short-circuit
    // it — the normal path reports the error.
    for c in pred.columns() {
        let i = zm.schema.col_index(c).ok()?;
        if zm.schema.col(i).dtype == DType::Str {
            return None;
        }
    }
    if zm.rows == 0 || pred.prune(&|c: &str| zm.value_range(c)) {
        Some(zm.schema.clone())
    } else {
        None
    }
}

/// [`prune_verdict`] straight off the backend (the single-operator
/// handlers' path; `skyhook.exec` decodes the map once and reuses it for
/// sortedness too).
fn zone_map_prune(b: &mut dyn ClsBackend, pred: &Predicate) -> Option<TableSchema> {
    prune_verdict(&zone_map_of(b)?, pred)
}

/// The `skyhook.exec` short-circuit: synthesize the empty result of a
/// provably-dead pipeline without reading object data, reporting the
/// same validation errors the live path would (missing columns, string
/// aggregates, non-i64 group keys).
fn exec_empty_result(schema: &TableSchema, spec: &PipelineSpec) -> Result<Vec<u8>> {
    let mut w = ByteWriter::new();
    if !spec.aggs.is_empty() {
        for k in &spec.keys {
            let ki = schema.col_index(k)?;
            if schema.col(ki).dtype != DType::I64 {
                return Err(Error::Query("group_by needs an i64 column".into()));
            }
        }
        for a in &spec.aggs {
            let i = schema.col_index(&a.col)?;
            // The live scalar path rejects string aggregates even over an
            // empty mask (`update_column`); the grouped path touches the
            // value column only per matching row, so zero matches pass.
            if spec.keys.is_empty() && schema.col(i).dtype == DType::Str {
                return Err(Error::Query("cannot aggregate a string column".into()));
            }
        }
        if spec.keys.is_empty() {
            w.u8(1);
            for a in &spec.aggs {
                AggState::new(!a.func.is_algebraic()).encode_into(&mut w);
            }
        } else {
            w.u8(2);
            w.u32(0);
        }
        return Ok(w.finish());
    }
    let schema = match &spec.projection {
        Some(cols) => {
            let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
            schema.project(&refs)?
        }
        None => schema.clone(),
    };
    // The live path sorts the (projected) batch, so a sort key missing
    // from the carried schema errors there — match it.
    for k in &spec.sort {
        schema.col_index(&k.col)?;
    }
    w.u8(0);
    w.raw(&encode_batch(&Batch::empty(&schema), Layout::Col));
    Ok(w.finish())
}

/// Register the `skyhook` class with an optional PJRT compute engine.
pub fn register_skyhook_class(r: &mut ClassRegistry, engine: Option<Arc<dyn ChunkCompute>>) {
    // skyhook.scan — filter+project on the server, return a Col batch.
    r.register("skyhook", "scan", |b, input| {
        let (pred, projection, zone_maps) = decode_scan_arg(input)?;
        // Decode the stamped zone map once: pruning and sortedness both
        // read it.
        let zm = if zone_maps { zone_map_of(b) } else { None };
        // Zone-map short-circuit: provably no matching rows → answer an
        // empty batch without touching object data.
        if let Some(schema) = zm.as_ref().and_then(|zm| prune_verdict(zm, &pred)) {
            let schema = match &projection {
                Some(cols) => {
                    let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                    schema.project(&refs)?
                }
                None => schema,
            };
            return Ok(encode_batch(&Batch::empty(&schema), Layout::Col));
        }
        // Read only predicate + projection columns (ranged reads on Col),
        // bounded to the sorted-column window's row prefix when a
        // sortedness marker applies; the filter is charged only for the
        // binary-searched window.
        let sorted_cols = zm.as_ref().map(ZoneMap::sorted_columns).unwrap_or_default();
        let needed = projection.as_ref().map(|cols| needed_union(&pred, cols));
        let (batch, (wlo, whi), _) = read_windowed(b, &pred, needed.as_deref(), &sorted_cols)?;
        let prof = b.exec_profile();
        b.charge_cpu((whi - wlo) as f64 * prof.row_pred_cost_s);
        let mut mask = Vec::new();
        pred.eval_into(&batch, &mut mask)?;
        apply_dv_mask(b, &mut mask)?;
        let filtered = batch.filter(&mask)?;
        let result = match projection {
            Some(cols) => {
                let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                filtered.project(&refs)?
            }
            None => filtered,
        };
        let payload = encode_batch(&result, Layout::Col);
        b.charge_cpu(payload.len() as f64 * prof.result_enc_cost_s);
        Ok(payload)
    });

    // skyhook.exec — the chained operator pipeline, one pass: decode a
    // PipelineSpec, consult the zone map, read the union of needed
    // columns once, then hand the whole chain to the shared execution
    // kernel (`exec_kernel::run_pipeline`) — the same evaluator the
    // client-side worker runs, so pushdown and client partials are
    // bit-identical by construction. The kernel counts its work; the
    // handler prices it with the cluster's ExecProfile.
    let exec_engine = engine.clone();
    r.register("skyhook", "exec", move |b, input| {
        let spec = PipelineSpec::decode(input)?;
        // Decode the stamped zone map once: it answers both "can anything
        // here match?" (pruning) and "which columns are sorted?" (the
        // prefix-read / sort-skip / early-stop fast paths). The unpruned
        // baseline (`zone_maps = false`) ignores it entirely.
        let zm = if spec.zone_maps { zone_map_of(b) } else { None };
        if let Some(schema) = zm.as_ref().and_then(|zm| prune_verdict(zm, &spec.predicate)) {
            return exec_empty_result(&schema, &spec);
        }
        let sorted_cols = zm.as_ref().map(ZoneMap::sorted_columns).unwrap_or_default();
        let needed = exec_kernel::needed_columns(&spec);
        let prof = b.exec_profile();
        // Secondary-index probe (the IndexScan access path): the planner
        // named an indexed column whose AND-spine window the `ix1/` omap
        // postings can answer. The probe yields a *superset* row-id set —
        // the full predicate still runs over the survivors — so every
        // fallback below (missing index xattr, unknown dtype tag, no
        // probe-able window) silently degrades to the plain scan with
        // bit-identical results. Disabled with `zone_maps = false` so the
        // unpruned baseline stays an honest full scan.
        let mut postings: Option<Vec<u32>> = None;
        let mut index_probes = 0u64;
        if spec.zone_maps {
            if let Some(col) = &spec.index {
                if let Some(tag) = b.getxattr(&format!("index.{col}")) {
                    if let Some(probe) = index_probe_window(&spec.predicate, col) {
                        if probe.empty {
                            // Contradictory conjuncts: prune without even
                            // touching the index.
                            index_probes = 1;
                            postings = Some(Vec::new());
                        } else {
                            match probe_key_range(col, &tag, &probe) {
                                Some(ProbeKeys::Empty) => {
                                    // The window survived f64 but holds no
                                    // representable key: same prune, and
                                    // never hand the inverted range to the
                                    // kv store.
                                    index_probes = 1;
                                    postings = Some(Vec::new());
                                }
                                Some(ProbeKeys::Range(lo, hi, hi_inc)) => {
                                    let bound = if hi_inc {
                                        std::ops::Bound::Included(hi.as_slice())
                                    } else {
                                        std::ops::Bound::Excluded(hi.as_slice())
                                    };
                                    let hits = b.omap_scan_range(&lo, bound);
                                    // An LSM probe consults every sorted
                                    // run plus the memtable; charge the
                                    // read amplification the store
                                    // actually has right now.
                                    let amp = b.kv_stats().read_amp() as f64;
                                    b.charge_cpu(
                                        prof.index_probe_cost_s * amp
                                            + hits.len() as f64 * prof.index_posting_cost_s,
                                    );
                                    index_probes = 1;
                                    let mut rows = Vec::with_capacity(hits.len());
                                    for (_, v) in hits {
                                        rows.push(u32::from_le_bytes(
                                            v.as_slice().try_into().map_err(|_| {
                                                Error::Corrupt("bad index entry".into())
                                            })?,
                                        ));
                                    }
                                    postings = Some(rows);
                                }
                                None => {}
                            }
                        }
                    }
                }
            }
        }
        let index_postings = postings.as_ref().map_or(0, |r| r.len() as u64);
        // Zero postings + a stamped schema: the probe proved the object
        // contributes nothing — answer like a zone-map prune, but keep
        // the probe on the books. Same error-parity guard as
        // `prune_verdict`: a predicate that would fail evaluation
        // (missing or string-typed column) must take the live path and
        // fail there.
        if let (Some(rows), Some(zm)) = (&postings, &zm) {
            let evaluable = spec.predicate.columns().iter().all(|c| {
                zm.schema
                    .col_index(c)
                    .is_ok_and(|i| zm.schema.col(i).dtype != DType::Str)
            });
            if rows.is_empty() && evaluable {
                let counters = ExecCounters {
                    index_probes,
                    ..ExecCounters::default()
                };
                return Ok(frame_exec_out(counters, exec_empty_result(&zm.schema, &spec)?));
            }
        }
        // Delete vector: rows tombstoned by `skyhook.delete_rows` must
        // never reach the kernel as live input. Consulted
        // unconditionally — correctness cannot depend on the planner
        // knowing the tombstone counts — and merged into the kernel's
        // pre-mask, the same mechanism the index postings use. The
        // zone-map prune and empty-postings short-circuits above stay
        // sound without it: tombstones only remove rows.
        let dv_deleted: Option<Vec<bool>> = match b.omap_get(DV_KEY) {
            Some(raw) => {
                let d = decode_dv(&raw)?;
                b.charge_cpu(d.len() as f64 * prof.index_posting_cost_s);
                Some(d)
            }
            None => None,
        };
        // One read covering every column the chain touches (the kernel's
        // own definition of its read set) — bounded to the object's first
        // k rows when the pipeline provably needs no more: a prefix-limit
        // head/top-k, or an index probe whose highest posting row is k-1
        // (rows past it have their indexed value outside the window, so
        // the AND-spine conjunct — hence the predicate — rejects them).
        // Tombstones break `prefix_limit`'s "first k rows suffice"
        // argument (the k-th *live* row may sit past row k), so that
        // bound is disabled while a dv is present; the postings bound
        // stays sound — rows past the highest posting are rejected by
        // the indexed conjunct, dead or alive.
        let sorted = |c: &str| sorted_cols.iter().any(|s| s == c);
        let (batch, prefix_read) = if let Some(rows) = &postings {
            let k = rows.iter().max().map_or(0, |&m| m as u64 + 1);
            let prefix = b.header_prefix();
            let (batch, _, _) =
                layout::read_projected_rows(&mut BackendRange(b), needed.as_deref(), prefix, k)?;
            (batch, false)
        } else {
            match exec_kernel::prefix_limit(&spec, &sorted) {
                Some(k) if dv_deleted.is_none() => {
                    let prefix = b.header_prefix();
                    let (batch, _, bounded) = layout::read_projected_rows(
                        &mut BackendRange(b),
                        needed.as_deref(),
                        prefix,
                        k,
                    )?;
                    (batch, bounded)
                }
                _ => (read_needed(b, needed.as_deref())?, false),
            }
        };
        // The probe's row ids become the kernel's pre-mask (rows the
        // bounded read dropped are provably non-matching), with the
        // delete vector ANDed in.
        let premask: Option<Vec<bool>> = match (postings, dv_deleted) {
            (None, None) => None,
            (rows, dv) => {
                let mut pm = match rows {
                    Some(rows) => {
                        let mut pm = vec![false; batch.nrows()];
                        for r in rows {
                            if let Some(m) = pm.get_mut(r as usize) {
                                *m = true;
                            }
                        }
                        pm
                    }
                    None => vec![true; batch.nrows()],
                };
                if let Some(deleted) = dv {
                    for (i, m) in pm.iter_mut().enumerate() {
                        if deleted.get(i).copied().unwrap_or(false) {
                            *m = false;
                        }
                    }
                }
                Some(pm)
            }
        };
        // The backend's profile picks the execution tier (compiled when
        // it is enabled, the shape is eligible, and the tier wins on
        // cost); the kernel's per-tier counters are then priced at the
        // same rates the planner's estimator uses.
        let (out, work) = run_pipeline_premasked(
            &batch,
            &spec,
            exec_engine.as_deref(),
            &sorted_cols,
            ExecTier::Auto(prof),
            premask.as_deref(),
        )?;
        b.charge_cpu(work.server_seconds(&prof));
        let counters = ExecCounters {
            rows_short_circuited: work.rows_short_circuited,
            prefix_read,
            compiled_chunks: work.compiled_chunks,
            compiled_rows: work.compiled_rows,
            index_probes,
            index_postings,
        };
        let mut w = ByteWriter::new();
        match out {
            ExecOut::Aggs(states) => {
                w.u8(1);
                for st in states {
                    st.encode_into(&mut w);
                }
            }
            ExecOut::Groups(groups) => {
                w.u8(2);
                w.u32(groups.len() as u32);
                for (key, states) in groups {
                    for k in key {
                        w.i64(k);
                    }
                    for st in states {
                        st.encode_into(&mut w);
                    }
                }
            }
            ExecOut::Rows(result) => {
                // Re-serializing the row partial is server CPU the plain
                // read path never pays — the cost asymmetry that lets
                // the planner prefer client-side for unselective scans.
                let payload = encode_batch(&result, Layout::Col);
                b.charge_cpu(payload.len() as f64 * prof.result_enc_cost_s);
                w.u8(0);
                w.raw(&payload);
            }
        }
        Ok(frame_exec_out(counters, w.finish()))
    });

    // skyhook.agg — filter+aggregate on the server, return partials.
    // (`engine` moves in: the aggregate hot spot is its only consumer.)
    let eng = engine;
    r.register("skyhook", "agg", move |b, input| {
        let (pred, keep_values, cols, zone_maps) = decode_agg_arg(input)?;
        let zm = if zone_maps { zone_map_of(b) } else { None };
        if let Some(schema) = zm.as_ref().and_then(|zm| prune_verdict(zm, &pred)) {
            for c in &cols {
                // Same failures the normal path would report.
                let i = schema.col_index(c)?;
                if schema.col(i).dtype == DType::Str {
                    return Err(Error::Query("cannot aggregate a string column".into()));
                }
            }
            let mut w = ByteWriter::new();
            w.u32(cols.len() as u32);
            for _ in &cols {
                AggState::new(keep_values).encode_into(&mut w);
            }
            return Ok(w.finish());
        }
        // Sort-aware read + charging, exactly like `skyhook.scan`: the
        // value columns fetch only the window-covering prefix and the
        // filter/aggregate loops are charged for the window span.
        let sorted_cols = zm.as_ref().map(ZoneMap::sorted_columns).unwrap_or_default();
        let needed = needed_union(&pred, &cols);
        let (batch, (wlo, whi), _) = read_windowed(b, &pred, Some(&needed), &sorted_cols)?;
        let span = (whi - wlo) as f64;
        let prof = b.exec_profile();
        b.charge_cpu(span * prof.row_pred_cost_s);
        let mut mask = Vec::new();
        pred.eval_into(&batch, &mut mask)?;
        apply_dv_mask(b, &mut mask)?;
        let mut w = ByteWriter::new();
        w.u32(cols.len() as u32);
        for col_name in &cols {
            let col = batch.col(col_name)?;
            let mut st = AggState::new(keep_values);
            // Hot path: masked moments of an f32 column → PJRT kernel.
            match (col, &eng, keep_values) {
                (Column::F32(v), Some(engine), false) => {
                    let m = engine.masked_moments(v, &mask)?;
                    st.count = m[0] as u64;
                    st.sum = m[1];
                    st.sumsq = m[2];
                    if st.count > 0 {
                        st.min = m[3];
                        st.max = m[4];
                    }
                }
                _ => {
                    b.charge_cpu(span * prof.val_agg_cost_s);
                    st.update_column(col, &mask)?;
                }
            }
            st.encode_into(&mut w);
        }
        Ok(w.finish())
    });

    // skyhook.group_agg — grouped partials keyed by an i64 column.
    r.register("skyhook", "group_agg", |b, input| {
        let mut r = ByteReader::new(input);
        let pred = Predicate::decode_from(&mut r)?;
        let group_col = r.str()?.to_string();
        let agg_col = r.str()?.to_string();
        let zone_maps = r.u8()? != 0;
        if let Some(schema) = zone_maps.then(|| zone_map_prune(b, &pred)).flatten() {
            // Same failures the normal path would report.
            let gi = schema.col_index(&group_col)?;
            if schema.col(gi).dtype != DType::I64 {
                return Err(Error::Query("group_by needs an i64 column".into()));
            }
            schema.col_index(&agg_col)?;
            let mut w = ByteWriter::new();
            w.u32(0);
            return Ok(w.finish());
        }
        let batch = read_needed(
            b,
            Some(&needed_union(&pred, &[group_col.clone(), agg_col.clone()])),
        )?;
        let prof = b.exec_profile();
        b.charge_cpu(batch.nrows() as f64 * (prof.row_pred_cost_s + prof.val_agg_cost_s));
        let mut mask = Vec::new();
        pred.eval_into(&batch, &mut mask)?;
        apply_dv_mask(b, &mut mask)?;
        let keys = match batch.col(&group_col)? {
            Column::I64(v) => v,
            _ => return Err(Error::Query("group_by needs an i64 column".into())),
        };
        let vals = batch.col(&agg_col)?;
        let mut groups: std::collections::BTreeMap<i64, AggState> = Default::default();
        for (i, &keep) in mask.iter().enumerate() {
            if keep {
                groups
                    .entry(keys[i])
                    .or_insert_with(|| AggState::new(false))
                    .update(vals.get_f64(i)?);
            }
        }
        let mut w = ByteWriter::new();
        w.u32(groups.len() as u32);
        for (k, st) in groups {
            w.i64(k);
            st.encode_into(&mut w);
        }
        Ok(w.finish())
    });

    // skyhook.build_index — omap postings over an i64 or f32 column
    // under the versioned `ix1/` scheme: key = `ix1/<col>/<enc><be-row>`
    // → le-row, where `<enc>` is the dtype's order-preserving encoding
    // (`index_key_i64` / `index_key_f32`). The `index.<col>` xattr
    // records the dtype tag so probes pick the matching key encoding.
    // Every row is indexed, NaN included (its total-order slot sits above
    // +inf, where finite range probes never look). The paper's RocksDB
    // indexing.
    r.register("skyhook", "build_index", |b, input| {
        let mut r = ByteReader::new(input);
        let col_name = r.str()?.to_string();
        let raw = b.read()?;
        let (batch, _) = decode_batch(&raw)?;
        let prefix = index_prefix(&col_name);
        let nrows = batch.nrows();
        b.charge_cpu(nrows as f64 * 50e-9); // kv insert cost
        let tag: &[u8] = match batch.col(&col_name)? {
            Column::I64(v) => {
                for (row, &k) in v.iter().enumerate() {
                    let mut key = prefix.clone();
                    key.extend_from_slice(&index_key_i64(k));
                    key.extend_from_slice(&(row as u32).to_be_bytes());
                    b.omap_set(&key, &(row as u32).to_le_bytes());
                }
                b"i64"
            }
            Column::F32(v) => {
                for (row, &x) in v.iter().enumerate() {
                    let mut key = prefix.clone();
                    key.extend_from_slice(&index_key_f32(x));
                    key.extend_from_slice(&(row as u32).to_be_bytes());
                    b.omap_set(&key, &(row as u32).to_le_bytes());
                }
                b"f32"
            }
            _ => {
                return Err(Error::Query(format!(
                    "cannot index {col_name:?}: only i64 and f32 columns are indexable"
                )))
            }
        };
        b.setxattr(&format!("index.{col_name}"), tag);
        Ok((nrows as u64).to_le_bytes().to_vec())
    });

    // skyhook.index_lookup — equality lookup: rows where col == value.
    r.register("skyhook", "index_lookup", |b, input| {
        let mut r = ByteReader::new(input);
        let col_name = r.str()?.to_string();
        let value = r.i64()?;
        let Some(tag) = b.getxattr(&format!("index.{col_name}")) else {
            return Err(Error::Query(format!("no index on {col_name:?}")));
        };
        let mut prefix = index_prefix(&col_name);
        match tag.as_slice() {
            b"i64" => prefix.extend_from_slice(&index_key_i64(value)),
            b"f32" => prefix.extend_from_slice(&index_key_f32(value as f32)),
            t => {
                return Err(Error::Query(format!(
                    "unknown index version on {col_name:?}: {t:?}"
                )))
            }
        }
        let hits = b.omap_scan_prefix(&prefix);
        // Tombstoned rows still carry postings (the dv is the single
        // source of deletion truth); drop them here so direct lookups
        // agree with the masked scan paths.
        let deleted = match b.omap_get(DV_KEY) {
            Some(raw) => decode_dv(&raw)?,
            None => Vec::new(),
        };
        let mut rows = Vec::with_capacity(hits.len());
        for (_, v) in hits {
            let row = u32::from_le_bytes(
                v.as_slice()
                    .try_into()
                    .map_err(|_| Error::Corrupt("bad index entry".into()))?,
            );
            if !deleted.get(row as usize).copied().unwrap_or(false) {
                rows.push(row);
            }
        }
        let mut w = ByteWriter::new();
        w.u32(rows.len() as u32);
        for row in rows {
            w.u32(row);
        }
        Ok(w.finish())
    });

    // skyhook.quantile_sketch — the §3.2 de-composable approximation:
    // build a constant-size mergeable quantile sketch over the filtered
    // column, instead of shipping raw values for holistic functions.
    // Input: predicate + column name + zone-map flag. Output: encoded
    // QuantileSketch.
    r.register("skyhook", "quantile_sketch", |b, input| {
        let mut r = ByteReader::new(input);
        let pred = Predicate::decode_from(&mut r)?;
        let col_name = r.str()?.to_string();
        let zone_maps = r.u8()? != 0;
        if let Some(schema) = zone_maps.then(|| zone_map_prune(b, &pred)).flatten() {
            schema.col_index(&col_name)?;
            let mut w = ByteWriter::new();
            super::sketch::QuantileSketch::empty().encode_into(&mut w);
            return Ok(w.finish());
        }
        let batch = read_needed(b, Some(&needed_union(&pred, &[col_name.clone()])))?;
        let prof = b.exec_profile();
        b.charge_cpu(batch.nrows() as f64 * (prof.row_pred_cost_s + prof.val_agg_cost_s));
        let mut mask = Vec::new();
        pred.eval_into(&batch, &mut mask)?;
        apply_dv_mask(b, &mut mask)?;
        let col = batch.col(&col_name)?;
        let mut values = Vec::with_capacity(mask.iter().filter(|&&m| m).count());
        for (i, &m) in mask.iter().enumerate() {
            if m {
                values.push(col.get_f64(i)?);
            }
        }
        let sketch = super::sketch::QuantileSketch::build(&values);
        let mut w = ByteWriter::new();
        sketch.encode_into(&mut w);
        Ok(w.finish())
    });

    // skyhook.transform — rewrite the object in the other layout
    // (physical design management, §5 bullet 2).
    r.register("skyhook", "transform", |b, input| {
        let target = match input.first() {
            Some(0) => Layout::Row,
            Some(1) => Layout::Col,
            _ => return Err(Error::Invalid("transform wants layout byte".into())),
        };
        let raw = b.read()?;
        let (batch, current) = decode_batch(&raw)?;
        if current == target {
            return Ok(vec![current as u8]);
        }
        b.charge_cpu(batch.nrows() as f64 * batch.ncols() as f64 * 3e-9);
        // A layout transform preserves row order and count, so any
        // existing delete vector (row-id-addressed) stays valid as-is.
        b.write(&encode_batch(&batch, target))?;
        Ok(vec![target as u8])
    });

    // skyhook.delete_rows — merge row ids into the object's `dv1/`
    // delete vector. Input: u32 count + count little-endian u32 row ids.
    // Output: the object's total tombstone count (u64 LE) after the
    // merge — authoritative, so re-deleting a row cannot double-count in
    // dataset metadata. Out-of-range rows are hard errors before any
    // state changes.
    r.register("skyhook", "delete_rows", |b, input| {
        let mut r = ByteReader::new(input);
        let n = r.u32()? as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(r.u32()?);
        }
        // Row count: the stamped zone map knows it without a data read;
        // an unstamped object pays one decode.
        let nrows = match zone_map_of(b) {
            Some(zm) => zm.rows as usize,
            None => decode_batch(&b.read()?)?.0.nrows(),
        };
        let mut deleted = match b.omap_get(DV_KEY) {
            Some(raw) => decode_dv(&raw)?,
            None => vec![false; nrows],
        };
        if deleted.len() != nrows {
            return Err(Error::Corrupt(
                "delete vector does not cover the object's rows".into(),
            ));
        }
        for &row in &rows {
            match deleted.get_mut(row as usize) {
                Some(d) => *d = true,
                None => {
                    return Err(Error::Invalid(format!(
                        "row {row} out of range (object has {nrows} rows)"
                    )))
                }
            }
        }
        let prof = b.exec_profile();
        b.charge_cpu(rows.len() as f64 * prof.index_posting_cost_s);
        let total = deleted.iter().filter(|&&d| d).count() as u64;
        b.omap_set(DV_KEY, &encode_dv(&deleted));
        Ok(total.to_le_bytes().to_vec())
    });

    // skyhook.read_dv — fetch the raw `dv1/` delete vector (empty when
    // the object has none). The client-side worker merges it into its
    // own kernel pre-mask, mirroring what `skyhook.exec` does on the
    // server — both sides of the boundary read the same bytes.
    r.register("skyhook", "read_dv", |b, _input| {
        Ok(b.omap_get(DV_KEY).unwrap_or_default())
    });

    // skyhook.dump_index — debug re-scan support: every posting of one
    // column's `ix1/` scheme, as (key suffix after the prefix, row id)
    // pairs. `metadata::verify_index` recomputes the expected set from
    // the object's decoded rows and compares. Not a query path.
    r.register("skyhook", "dump_index", |b, input| {
        let mut r = ByteReader::new(input);
        let col_name = r.str()?.to_string();
        let prefix = index_prefix(&col_name);
        let hits = b.omap_scan_prefix(&prefix);
        let mut w = ByteWriter::new();
        w.u32(hits.len() as u32);
        for (k, v) in hits {
            let suffix = &k[prefix.len()..];
            w.u32(suffix.len() as u32);
            w.raw(suffix);
            w.u32(u32::from_le_bytes(
                v.as_slice()
                    .try_into()
                    .map_err(|_| Error::Corrupt("bad index entry".into()))?,
            ));
        }
        Ok(w.finish())
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::skyhook::query::{AggFunc, CmpOp};
    use crate::store::objclass::MemBackend;

    fn registry() -> ClassRegistry {
        let mut r = ClassRegistry::with_builtins();
        register_skyhook_class(&mut r, None);
        r
    }

    fn table_object() -> Vec<u8> {
        encode_batch(&gen::sensor_table(200, 7), Layout::Col)
    }

    #[test]
    fn scan_filters_and_projects() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let pred = Predicate::cmp("flag", CmpOp::Eq, 1.0);
        let out = r.get("skyhook", "scan").unwrap()(
            &mut b,
            &encode_scan_arg(&pred, Some(&["val".to_string(), "ts".to_string()]), true),
        )
        .unwrap();
        let (batch, layout) = decode_batch(&out).unwrap();
        assert_eq!(layout, Layout::Col);
        assert_eq!(batch.ncols(), 2);
        assert!(batch.nrows() > 0 && batch.nrows() < 200);
        assert!(b.cpu > 0.0);

        // Verify against direct evaluation.
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let mask = pred.eval(&orig).unwrap();
        let want = mask.iter().filter(|&&m| m).count();
        assert_eq!(batch.nrows(), want);
    }

    #[test]
    fn scan_without_projection_keeps_all_columns() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out =
            r.get("skyhook", "scan").unwrap()(&mut b, &encode_scan_arg(&Predicate::True, None, true))
                .unwrap();
        let (batch, _) = decode_batch(&out).unwrap();
        assert_eq!(batch.ncols(), 4);
        assert_eq!(batch.nrows(), 200);
    }

    #[test]
    fn agg_partials_match_direct() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let pred = Predicate::cmp("val", CmpOp::Gt, 50.0);
        let aggs = vec![
            Aggregate::new(AggFunc::Count, "val"),
            Aggregate::new(AggFunc::Sum, "val"),
        ];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&pred, &aggs, false, true),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states.len(), 2);

        let (orig, _) = decode_batch(&table_object()).unwrap();
        let mask = pred.eval(&orig).unwrap();
        let mut direct = AggState::new(false);
        direct
            .update_column(orig.col("val").unwrap(), &mask)
            .unwrap();
        assert_eq!(states[0].count, direct.count);
        assert!((states[1].sum - direct.sum).abs() < 1e-6);
        // Partials are constant-size (no raw values).
        assert!(states[0].values.is_none());
        assert!(out.len() < 200);
    }

    #[test]
    fn agg_with_values_for_median() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let aggs = vec![Aggregate::new(AggFunc::Median, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&Predicate::True, &aggs, true, true),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states[0].values.as_ref().unwrap().len(), 200);
        states[0].finalize(AggFunc::Median).unwrap();
    }

    #[test]
    fn group_agg_partitions_by_key() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out = r.get("skyhook", "group_agg").unwrap()(
            &mut b,
            &encode_group_arg(&Predicate::True, "sensor", "val", true),
        )
        .unwrap();
        let groups = decode_group_out(&out).unwrap();
        assert!(!groups.is_empty());
        let total: u64 = groups.iter().map(|(_, s)| s.count).sum();
        assert_eq!(total, 200);
        // Keys sorted and unique.
        for w in groups.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn group_agg_rejects_non_i64_key() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        assert!(r.get("skyhook", "group_agg").unwrap()(
            &mut b,
            &encode_group_arg(&Predicate::True, "val", "val", true),
        )
        .is_err());
    }

    #[test]
    fn zone_map_short_circuits_without_reading_data() {
        let r = registry();
        let batch = gen::sensor_table(200, 7);
        let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
        b.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        // Destroy the object data: a short-circuited handler never
        // notices, while any handler that reads must fail.
        b.data = vec![0xff; 16];
        // val ~ N(50, 15) never reaches 10_000 → provably zero matches.
        let pred = Predicate::cmp("val", CmpOp::Gt, 10_000.0);
        let out = r.get("skyhook", "scan").unwrap()(
            &mut b,
            &encode_scan_arg(&pred, Some(&["ts".to_string()]), true),
        )
        .unwrap();
        let (empty, layout) = decode_batch(&out).unwrap();
        assert_eq!(layout, Layout::Col);
        assert_eq!(empty.nrows(), 0);
        assert_eq!(empty.ncols(), 1);
        assert_eq!(empty.schema.columns[0].name, "ts");

        let aggs = vec![Aggregate::new(AggFunc::Sum, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&pred, &aggs, false, true),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states.len(), 1);
        assert_eq!(states[0].count, 0);

        let out = r.get("skyhook", "group_agg").unwrap()(
            &mut b,
            &encode_group_arg(&pred, "sensor", "val", true),
        )
        .unwrap();
        assert!(decode_group_out(&out).unwrap().is_empty());

        // A satisfiable predicate must NOT short-circuit: with the data
        // destroyed the handler now fails, proving it went to the object.
        let alive = Predicate::cmp("val", CmpOp::Gt, 0.0);
        assert!(
            r.get("skyhook", "scan").unwrap()(&mut b, &encode_scan_arg(&alive, None, true)).is_err()
        );
        // With zone maps disabled in the request (the unpruned baseline),
        // even a provably dead predicate must go to the data.
        assert!(
            r.get("skyhook", "scan").unwrap()(&mut b, &encode_scan_arg(&pred, None, false)).is_err()
        );
        assert!(r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&pred, &aggs, false, false),
        )
        .is_err());
    }

    #[test]
    fn zone_map_pruned_agg_matches_unpruned() {
        let r = registry();
        let batch = gen::sensor_table(300, 9);
        let enc = encode_batch(&batch, Layout::Col);
        let pred = Predicate::cmp("val", CmpOp::Lt, -10_000.0);
        let aggs = vec![Aggregate::new(AggFunc::Count, "val")];
        // Without a zone map: normal path, zero matches.
        let mut plain = MemBackend::new(&enc);
        let a = decode_agg_out(&r.get("skyhook", "agg").unwrap()(
            &mut plain,
            &encode_agg_arg(&pred, &aggs, false, true),
        )
        .unwrap())
        .unwrap();
        // With a zone map: short-circuit, identical partials.
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        let b2 = decode_agg_out(&r.get("skyhook", "agg").unwrap()(
            &mut stamped,
            &encode_agg_arg(&pred, &aggs, false, true),
        )
        .unwrap())
        .unwrap();
        assert_eq!(a, b2);
        // A ghost aggregate column errors even on the pruned path.
        let ghost = vec![Aggregate::new(AggFunc::Sum, "nope")];
        assert!(r.get("skyhook", "agg").unwrap()(
            &mut stamped,
            &encode_agg_arg(&pred, &ghost, false, true),
        )
        .is_err());
    }

    #[test]
    fn index_build_and_lookup() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let mut w = ByteWriter::new();
        w.str("sensor");
        let out = r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 200);

        // Look up rows where sensor == most common value.
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let sensors = match orig.col("sensor").unwrap() {
            Column::I64(v) => v.clone(),
            _ => unreachable!(),
        };
        let target = sensors[0];
        let want: Vec<u32> = sensors
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == target)
            .map(|(i, _)| i as u32)
            .collect();

        let mut w = ByteWriter::new();
        w.str("sensor");
        w.i64(target);
        let out = r.get("skyhook", "index_lookup").unwrap()(&mut b, &w.finish()).unwrap();
        let mut rr = ByteReader::new(&out);
        let n = rr.u32().unwrap() as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(rr.u32().unwrap());
        }
        rows.sort_unstable();
        assert_eq!(rows, want);
    }

    #[test]
    fn index_lookup_without_index_fails() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let mut w = ByteWriter::new();
        w.str("sensor");
        w.i64(1);
        assert!(r.get("skyhook", "index_lookup").unwrap()(&mut b, &w.finish()).is_err());
    }

    #[test]
    fn index_key_order_preserving() {
        let mut keys: Vec<i64> = vec![-5, 3, 0, i64::MIN, i64::MAX, -1, 7];
        keys.sort_unstable();
        let encoded: Vec<[u8; 8]> = keys.iter().map(|&k| index_key_i64(k)).collect();
        let mut sorted = encoded.clone();
        sorted.sort();
        assert_eq!(encoded, sorted);
        // f32: byte order must equal total_cmp order, NaN and zeros
        // included.
        let mut vals: Vec<f32> = vec![
            f32::NEG_INFINITY,
            -1.5e30,
            -2.0,
            -f32::MIN_POSITIVE,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            3.25,
            1.5e30,
            f32::INFINITY,
            f32::NAN,
            -f32::NAN,
        ];
        vals.sort_by(f32::total_cmp);
        let encoded: Vec<[u8; 4]> = vals.iter().map(|&x| index_key_f32(x)).collect();
        let mut sorted = encoded.clone();
        sorted.sort();
        assert_eq!(encoded, sorted);
        // Distinct values get distinct keys (the zeros differ in key
        // space on purpose — probes widen below -0.0).
        let mut dedup = encoded.clone();
        dedup.dedup();
        assert_eq!(dedup.len(), encoded.len());
    }

    #[test]
    fn build_index_accepts_f32_and_step_widening_is_safe() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let mut w = ByteWriter::new();
        w.str("val");
        let out = r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 200);
        assert_eq!(b.getxattr("index.val").unwrap(), b"f32".to_vec());
        // Strings stay unindexable (a real Str column, not a missing one).
        let strs = Batch::new(
            TableSchema::new(&[("tag", DType::Str)]),
            vec![Column::Str(vec!["a".into(), "b".into()])],
        )
        .unwrap();
        let mut b2 = MemBackend::new(&encode_batch(&strs, Layout::Row));
        let mut w = ByteWriter::new();
        w.str("tag");
        assert!(r.get("skyhook", "build_index").unwrap()(&mut b2, &w.finish()).is_err());
        // Step widening brackets every value, zeros included.
        for x in [0.0f32, -0.0, 1.0, -1.0, f32::MAX, f32::MIN_POSITIVE] {
            assert!(f32_step_down(x).total_cmp(&x).is_lt() || x == f32::NEG_INFINITY);
            assert!(f32_step_up(x).total_cmp(&x).is_gt() || x == f32::INFINITY);
        }
        // i64 probe bounds: exact in the exact range.
        assert_eq!(i64_probe_lo(5.0, true), 5);
        assert_eq!(i64_probe_lo(5.0, false), 6);
        assert_eq!(i64_probe_lo(5.5, true), 6);
        assert_eq!(i64_probe_hi(5.0, true), 5);
        assert_eq!(i64_probe_hi(5.0, false), 4);
        assert_eq!(i64_probe_hi(5.5, false), 5);
        // Beyond 2^53 the margin only widens.
        let v = 1.0e17;
        assert!(i64_probe_lo(v, true) <= 100_000_000_000_000_000);
        assert!(i64_probe_hi(v, true) >= 100_000_000_000_000_000);
    }

    #[test]
    fn exec_index_probe_matches_scan_and_reports_counters() {
        use crate::skyhook::query::SortKey;
        let r = registry();
        let batch = gen::sensor_table(500, 7);
        let enc = encode_batch(&batch, Layout::Col);
        let build = |b: &mut MemBackend, col: &str| {
            let mut w = ByteWriter::new();
            w.str(col);
            r.get("skyhook", "build_index").unwrap()(b, &w.finish()).unwrap();
        };
        // Range over the indexed f32 column + an unindexed conjunct: the
        // probe pre-masks, the full predicate still filters.
        let pred = Predicate::cmp("val", CmpOp::Ge, 45.0)
            .and(Predicate::cmp("val", CmpOp::Lt, 55.0))
            .and(Predicate::cmp("sensor", CmpOp::Eq, 3.0));
        for spec in [
            PipelineSpec {
                predicate: pred.clone(),
                aggs: vec![
                    Aggregate::new(AggFunc::Count, "val"),
                    Aggregate::new(AggFunc::Sum, "ts"),
                ],
                ..exec_spec()
            },
            PipelineSpec {
                predicate: pred.clone(),
                projection: Some(vec!["ts".to_string(), "val".to_string()]),
                sort: vec![SortKey::desc("val")],
                limit: Some(5),
                ..exec_spec()
            },
        ] {
            let mut plain = MemBackend::new(&enc);
            let want = r.get("skyhook", "exec").unwrap()(&mut plain, &spec.encode()).unwrap();
            let (_, cw) = decode_exec_out_full(&want, 0, spec.aggs.len()).unwrap();
            assert_eq!((cw.index_probes, cw.index_postings), (0, 0));
            let mut ixd = MemBackend::new(&enc);
            build(&mut ixd, "val");
            let ispec = PipelineSpec {
                index: Some("val".to_string()),
                ..spec.clone()
            };
            let got = r.get("skyhook", "exec").unwrap()(&mut ixd, &ispec.encode()).unwrap();
            let (gout, c) = decode_exec_out_full(&got, 0, spec.aggs.len()).unwrap();
            let (wout, _) = decode_exec_out_full(&want, 0, spec.aggs.len()).unwrap();
            assert_eq!(c.index_probes, 1);
            assert!(c.index_postings > 0);
            match (gout, wout) {
                (ExecOut::Aggs(g), ExecOut::Aggs(w)) => assert_eq!(g, w),
                (ExecOut::Rows(g), ExecOut::Rows(w)) => assert_eq!(g, w),
                _ => panic!("probe changed the output shape"),
            }
        }
        // An i64-indexed equality probe narrows to exactly the eq run.
        let mut ixd = MemBackend::new(&enc);
        build(&mut ixd, "sensor");
        let eq = PipelineSpec {
            predicate: Predicate::cmp("sensor", CmpOp::Eq, 3.0),
            aggs: vec![Aggregate::new(AggFunc::Count, "sensor")],
            index: Some("sensor".to_string()),
            ..exec_spec()
        };
        let got = r.get("skyhook", "exec").unwrap()(&mut ixd, &eq.encode()).unwrap();
        let (out, c) = decode_exec_out_full(&got, 0, 1).unwrap();
        let ExecOut::Aggs(states) = out else {
            panic!("expected aggs");
        };
        assert_eq!(c.index_probes, 1);
        assert_eq!(c.index_postings, states[0].count);
        // Missing index or no probe-able window: silent scan fallback.
        let no_ix = PipelineSpec {
            index: Some("ts".to_string()),
            ..eq.clone()
        };
        let got = r.get("skyhook", "exec").unwrap()(&mut ixd, &no_ix.encode()).unwrap();
        let (_, c) = decode_exec_out_full(&got, 0, 1).unwrap();
        assert_eq!((c.index_probes, c.index_postings), (0, 0));
        let no_window = PipelineSpec {
            predicate: Predicate::cmp("sensor", CmpOp::Ne, 3.0),
            ..eq.clone()
        };
        let got = r.get("skyhook", "exec").unwrap()(&mut ixd, &no_window.encode()).unwrap();
        let (_, c) = decode_exec_out_full(&got, 0, 1).unwrap();
        assert_eq!(c.index_probes, 0);
        // The unpruned baseline never probes.
        let baseline = PipelineSpec {
            zone_maps: false,
            ..eq.clone()
        };
        let got = r.get("skyhook", "exec").unwrap()(&mut ixd, &baseline.encode()).unwrap();
        let (_, c) = decode_exec_out_full(&got, 0, 1).unwrap();
        assert_eq!(c.index_probes, 0);
    }

    #[test]
    fn exec_index_empty_probe_prunes_without_reading() {
        let r = registry();
        let batch = gen::sensor_table(200, 7);
        let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
        let mut w = ByteWriter::new();
        w.str("sensor");
        r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        b.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        // Destroy the data: only a pruned answer can survive. The zone
        // map cannot prune `sensor == 3 AND sensor == 4` (each value is
        // in range); the probe window's contradiction can.
        b.data = vec![0xff; 16];
        let spec = PipelineSpec {
            predicate: Predicate::cmp("sensor", CmpOp::Eq, 3.0)
                .and(Predicate::cmp("sensor", CmpOp::Eq, 4.0)),
            aggs: vec![Aggregate::new(AggFunc::Count, "sensor")],
            index: Some("sensor".to_string()),
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let (ExecOut::Aggs(states), c) = decode_exec_out_full(&out, 0, 1).unwrap() else {
            panic!("expected aggs");
        };
        assert_eq!(states[0].count, 0);
        assert_eq!(c.index_probes, 1);
        assert_eq!(c.index_postings, 0);
        // Without the index hint the same spec must hit the (destroyed)
        // data and fail — proving the probe is what pruned.
        let unhinted = PipelineSpec {
            index: None,
            ..spec
        };
        assert!(r.get("skyhook", "exec").unwrap()(&mut b, &unhinted.encode()).is_err());
    }

    #[test]
    fn transform_rewrites_layout() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out = r.get("skyhook", "transform").unwrap()(&mut b, &[0u8]).unwrap();
        assert_eq!(out, vec![0u8]);
        let (_, layout) = decode_batch(&b.data).unwrap();
        assert_eq!(layout, Layout::Row);
        // Idempotent no-op when already in target layout.
        let before = b.data.clone();
        r.get("skyhook", "transform").unwrap()(&mut b, &[0u8]).unwrap();
        assert_eq!(b.data, before);
    }

    fn exec_spec() -> PipelineSpec {
        PipelineSpec {
            predicate: Predicate::True,
            projection: None,
            aggs: vec![],
            keys: vec![],
            sort: vec![],
            limit: None,
            zone_maps: true,
            index: None,
        }
    }

    #[test]
    fn exec_runs_chained_row_pipeline_in_one_pass() {
        use crate::skyhook::query::SortKey;
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let spec = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 40.0),
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            sort: vec![SortKey::desc("val")],
            limit: Some(5),
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Rows(rows) = decode_exec_out(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert_eq!(rows.ncols(), 2);
        assert_eq!(rows.nrows(), 5);
        // The per-object partial is the top 5 by val, descending.
        let Column::F32(v) = rows.col("val").unwrap() else {
            unreachable!()
        };
        assert!(v.windows(2).all(|w| w[0] >= w[1]));
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let Column::F32(all) = orig.col("val").unwrap() else {
            unreachable!()
        };
        let mut best: Vec<f32> = all.iter().copied().filter(|&x| x > 40.0).collect();
        best.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(v[0], best[0]);
        assert_eq!(*v.last().unwrap(), best[4]);
        // Head without sort keys: first n matching rows in row order.
        let spec = PipelineSpec {
            predicate: Predicate::True,
            limit: Some(7),
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Rows(rows) = decode_exec_out(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert_eq!(rows.nrows(), 7);
        assert_eq!(rows, orig.slice(0, 7).unwrap());
    }

    #[test]
    fn exec_multi_aggregate_partials_match_single_op_handlers() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let pred = Predicate::cmp("val", CmpOp::Gt, 50.0);
        let spec = PipelineSpec {
            predicate: pred.clone(),
            aggs: vec![
                Aggregate::new(AggFunc::Count, "val"),
                Aggregate::new(AggFunc::Sum, "val"),
                Aggregate::new(AggFunc::Median, "ts"),
            ],
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Aggs(states) = decode_exec_out(&out, 0, 3).unwrap() else {
            panic!("expected aggs");
        };
        assert_eq!(states.len(), 3);
        // Algebraic partials stay constant-size; the holistic median
        // ships its values.
        assert!(states[0].values.is_none());
        assert!(states[2].values.is_some());
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let mask = pred.eval(&orig).unwrap();
        let mut direct = AggState::new(false);
        direct.update_column(orig.col("val").unwrap(), &mask).unwrap();
        assert_eq!(states[0].count, direct.count);
        assert!((states[1].sum - direct.sum).abs() < 1e-6);
        assert_eq!(
            states[2].values.as_ref().unwrap().len(),
            direct.count as usize
        );
    }

    #[test]
    fn exec_multi_key_group_partials() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let spec = PipelineSpec {
            predicate: Predicate::True,
            aggs: vec![
                Aggregate::new(AggFunc::Count, "val"),
                Aggregate::new(AggFunc::Sum, "val"),
            ],
            keys: vec!["sensor".to_string(), "flag".to_string()],
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Groups(groups) = decode_exec_out(&out, 2, 2).unwrap() else {
            panic!("expected groups");
        };
        assert!(!groups.is_empty());
        let total: u64 = groups.iter().map(|(_, s)| s[0].count).sum();
        assert_eq!(total, 200);
        // Keys are 2-wide, sorted, unique; both aggregates agree on count.
        for w in groups.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        for (key, states) in &groups {
            assert_eq!(key.len(), 2);
            assert_eq!(states[0].count, states[1].count);
        }
        // Non-i64 key errors.
        let bad = PipelineSpec {
            keys: vec!["val".to_string()],
            aggs: vec![Aggregate::new(AggFunc::Count, "val")],
            ..exec_spec()
        };
        assert!(r.get("skyhook", "exec").unwrap()(&mut b, &bad.encode()).is_err());
    }

    #[test]
    fn exec_zone_map_short_circuits_like_single_ops() {
        use crate::skyhook::query::SortKey;
        let r = registry();
        let batch = gen::sensor_table(200, 7);
        let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
        b.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        b.data = vec![0xff; 16]; // destroy data: only short-circuits survive
        let dead = Predicate::cmp("val", CmpOp::Gt, 10_000.0);
        // Dead row pipeline: empty batch with the carried schema.
        let spec = PipelineSpec {
            predicate: dead.clone(),
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            sort: vec![SortKey::desc("val")],
            limit: Some(3),
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Rows(rows) = decode_exec_out(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert_eq!(rows.nrows(), 0);
        assert_eq!(rows.ncols(), 2);
        // Dead aggregates: empty states / zero groups, same arity.
        let spec = PipelineSpec {
            predicate: dead.clone(),
            aggs: vec![
                Aggregate::new(AggFunc::Sum, "val"),
                Aggregate::new(AggFunc::Median, "val"),
            ],
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Aggs(states) = decode_exec_out(&out, 0, 2).unwrap() else {
            panic!("expected aggs");
        };
        assert_eq!(states[0].count, 0);
        assert!(states[1].values.is_some(), "holistic keeps (empty) values");
        let spec = PipelineSpec {
            predicate: dead.clone(),
            aggs: vec![Aggregate::new(AggFunc::Count, "val")],
            keys: vec!["sensor".to_string(), "flag".to_string()],
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Groups(groups) = decode_exec_out(&out, 2, 1).unwrap() else {
            panic!("expected groups");
        };
        assert!(groups.is_empty());
        // Error parity on the pruned path: ghost columns still fail.
        let bad = PipelineSpec {
            predicate: dead.clone(),
            aggs: vec![Aggregate::new(AggFunc::Sum, "nope")],
            ..exec_spec()
        };
        assert!(r.get("skyhook", "exec").unwrap()(&mut b, &bad.encode()).is_err());
        // A live predicate must go to the (destroyed) data and fail.
        let live = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 0.0),
            ..exec_spec()
        };
        assert!(r.get("skyhook", "exec").unwrap()(&mut b, &live.encode()).is_err());
        // Zone maps disabled in the spec: even a dead predicate reads.
        let unpruned = PipelineSpec {
            predicate: dead,
            zone_maps: false,
            ..exec_spec()
        };
        assert!(r.get("skyhook", "exec").unwrap()(&mut b, &unpruned.encode()).is_err());
    }

    #[test]
    fn exec_serves_sorted_topk_as_bounded_prefix_read() {
        use crate::skyhook::query::SortKey;
        let r = registry();
        // A clustered-style object: rows sorted by val.
        let batch = gen::sensor_table(2000, 7).sort_by_column("val").unwrap();
        let enc = encode_batch(&batch, Layout::Col);
        let spec = PipelineSpec {
            predicate: Predicate::True,
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            aggs: vec![],
            keys: vec![],
            sort: vec![SortKey::asc("val")],
            limit: Some(5),
            zone_maps: true,
            index: None,
        };
        // Without the stamped marker: full read, no prefix flag.
        let mut plain = MemBackend::new(&enc);
        let out = r.get("skyhook", "exec").unwrap()(&mut plain, &spec.encode()).unwrap();
        let (ExecOut::Rows(want), c0) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert!(!c0.prefix_read);
        // With it: the handler reads only a 5-row prefix of the needed
        // columns, reports it, and returns the identical partial.
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        let out = r.get("skyhook", "exec").unwrap()(&mut stamped, &spec.encode()).unwrap();
        let (ExecOut::Rows(got), c1) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert!(c1.prefix_read);
        assert_eq!(got, want);
        assert_eq!(got.nrows(), 5);
        // A range filter over the sorted column reports short-circuited
        // rows (and still matches the unmarked execution exactly).
        let fspec = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Lt, 30.0),
            limit: None,
            sort: vec![],
            ..spec
        };
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        let out = r.get("skyhook", "exec").unwrap()(&mut stamped, &fspec.encode()).unwrap();
        let (ExecOut::Rows(got), cf) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert!(cf.rows_short_circuited > 0, "sorted range filter must early-stop");
        let mut plain = MemBackend::new(&enc);
        let out = r.get("skyhook", "exec").unwrap()(&mut plain, &fspec.encode()).unwrap();
        let (ExecOut::Rows(want), cp) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert_eq!(cp.rows_short_circuited, 0);
        assert_eq!(got, want);
    }

    #[test]
    fn legacy_handlers_exploit_sortedness_markers() {
        // A clustered-style object: rows sorted by val, marker stamped.
        let batch = gen::sensor_table(2000, 7).sort_by_column("val").unwrap();
        let enc = encode_batch(&batch, Layout::Col);
        let zm = ZoneMap::from_batch(&batch);
        let sorted_cols = zm.sorted_columns();
        assert!(sorted_cols.contains(&"val".to_string()));
        // The windowed read bounds the non-predicate columns to the
        // binary-searched window's row prefix.
        let pred = Predicate::cmp("val", CmpOp::Lt, 30.0);
        let needed = vec!["ts".to_string(), "val".to_string()];
        let mut b = MemBackend::new(&enc);
        let (win, (wlo, whi), bounded) =
            read_windowed(&mut b, &pred, Some(&needed), &sorted_cols).unwrap();
        assert!(bounded, "value columns must be prefix-bounded");
        assert_eq!(wlo, 0);
        assert!(whi < 2000, "val < 30 is a selective prefix");
        assert_eq!(win.nrows(), whi);
        assert_eq!(win.ncols(), 2);
        // skyhook.scan: identical result with and without the marker,
        // strictly cheaper charged CPU with it.
        let r = registry();
        let arg = encode_scan_arg(&pred, Some(&["ts".to_string()]), true);
        let mut plain = MemBackend::new(&enc);
        let want = r.get("skyhook", "scan").unwrap()(&mut plain, &arg).unwrap();
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &zm.encode());
        let got = r.get("skyhook", "scan").unwrap()(&mut stamped, &arg).unwrap();
        assert_eq!(got, want, "sortedness must never change scan results");
        assert!(
            stamped.cpu < plain.cpu,
            "windowed scan must charge less: {} vs {}",
            stamped.cpu,
            plain.cpu
        );
        // skyhook.agg too — bit-identical partials, cheaper charge.
        let aggs = vec![Aggregate::new(AggFunc::Sum, "ts")];
        let arg = encode_agg_arg(&pred, &aggs, false, true);
        let mut plain = MemBackend::new(&enc);
        let want = r.get("skyhook", "agg").unwrap()(&mut plain, &arg).unwrap();
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &zm.encode());
        let got = r.get("skyhook", "agg").unwrap()(&mut stamped, &arg).unwrap();
        assert_eq!(got, want, "sortedness must never change agg partials");
        assert!(stamped.cpu < plain.cpu);
        // Ghost columns keep failing on the windowed path.
        let ghost = encode_scan_arg(&pred, Some(&["nope".to_string()]), true);
        let mut stamped = MemBackend::new(&enc);
        stamped.setxattr(ZONE_MAP_XATTR, &zm.encode());
        assert!(r.get("skyhook", "scan").unwrap()(&mut stamped, &ghost).is_err());
    }

    #[test]
    fn exec_reports_compiled_tier_counters() {
        use crate::simnet::ExecProfile;
        let r = registry();
        let big = gen::sensor_table(20_000, 5);
        let enc = encode_batch(&big, Layout::Col);
        let eligible = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 40.0),
            aggs: vec![Aggregate::new(AggFunc::Mean, "val")],
            ..exec_spec()
        };
        // Profile with the tier disabled (the default): scalar runs and
        // the response reports zero compiled work.
        let mut b = MemBackend::new(&enc);
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &eligible.encode()).unwrap();
        let (_, c) = decode_exec_out_full(&out, 0, 1).unwrap();
        assert_eq!((c.compiled_chunks, c.compiled_rows), (0, 0));
        if exec_kernel::scalar_forced() {
            eprintln!("skipping compiled-tier counter asserts: SKYHOOK_FORCE_SCALAR set");
            return;
        }
        // Tier enabled on the backend's profile: the handler reports the
        // chunks it launched, and the partial matches the scalar run
        // bit-for-bit.
        let scalar = decode_exec_out(&out, 0, 1).unwrap();
        let mut b = MemBackend::new(&enc);
        b.exec = ExecProfile::default().with_compiled_tier();
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &eligible.encode()).unwrap();
        let (compiled, c) = decode_exec_out_full(&out, 0, 1).unwrap();
        assert_eq!(c.compiled_chunks, 2);
        assert_eq!(c.compiled_rows, 20_000);
        let (ExecOut::Aggs(a), ExecOut::Aggs(s)) = (compiled, scalar) else {
            panic!("expected aggs");
        };
        assert_eq!(a, s, "tiers must agree bit-for-bit across the wire");
        // A holistic pipeline stays scalar even with the tier enabled.
        let holistic = PipelineSpec {
            aggs: vec![Aggregate::new(AggFunc::Median, "val")],
            ..exec_spec()
        };
        let mut b = MemBackend::new(&enc);
        b.exec = ExecProfile::default().with_compiled_tier();
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &holistic.encode()).unwrap();
        let (_, c) = decode_exec_out_full(&out, 0, 1).unwrap();
        assert_eq!((c.compiled_chunks, c.compiled_rows), (0, 0));
    }

    #[test]
    fn handler_charges_flow_from_the_backend_profile() {
        use crate::simnet::ExecProfile;
        use crate::skyhook::query::SortKey;
        // The same call against a backend with doubled execution rates
        // charges exactly twice the CPU — no local constants survive.
        let r = registry();
        let spec = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 40.0),
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            sort: vec![SortKey::desc("val")],
            limit: Some(5),
            ..exec_spec()
        };
        let run = |exec: ExecProfile| {
            let mut b = MemBackend::new(&table_object());
            b.exec = exec;
            r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
            b.cpu
        };
        let base = run(ExecProfile::default());
        assert!(base > 0.0);
        let d = ExecProfile::default();
        let doubled = ExecProfile {
            row_pred_cost_s: 2.0 * d.row_pred_cost_s,
            val_agg_cost_s: 2.0 * d.val_agg_cost_s,
            sort_row_cost_s: 2.0 * d.sort_row_cost_s,
            result_enc_cost_s: 2.0 * d.result_enc_cost_s,
            ..d
        };
        let twice = run(doubled);
        assert!(
            (twice - 2.0 * base).abs() < 1e-12 * (1.0 + base),
            "doubled profile must double the charge: {base} vs {twice}"
        );
    }

    #[test]
    fn pjrt_hook_is_used_when_present() {
        struct FakeEngine(std::sync::atomic::AtomicU64);
        impl ChunkCompute for FakeEngine {
            fn masked_moments(&self, values: &[f32], mask: &[bool]) -> Result<[f64; 5]> {
                self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                let mut st = AggState::new(false);
                for (i, &m) in mask.iter().enumerate() {
                    if m {
                        st.update(values[i] as f64);
                    }
                }
                Ok([st.count as f64, st.sum, st.sumsq, st.min, st.max])
            }
        }
        let engine = Arc::new(FakeEngine(Default::default()));
        let mut r = ClassRegistry::with_builtins();
        register_skyhook_class(&mut r, Some(engine.clone() as Arc<dyn ChunkCompute>));
        let mut b = MemBackend::new(&table_object());
        let aggs = vec![Aggregate::new(AggFunc::Mean, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&Predicate::True, &aggs, false, true),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states[0].count, 200);
        assert_eq!(engine.0.load(std::sync::atomic::Ordering::SeqCst), 1);
        // The chained-pipeline handler shares the same kernel hot path.
        let spec = PipelineSpec {
            predicate: Predicate::True,
            projection: None,
            aggs: vec![Aggregate::new(AggFunc::Mean, "val")],
            keys: vec![],
            sort: vec![],
            limit: None,
            zone_maps: true,
            index: None,
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let ExecOut::Aggs(states) = decode_exec_out(&out, 0, 1).unwrap() else {
            panic!("expected aggs");
        };
        assert_eq!(states[0].count, 200);
        assert_eq!(engine.0.load(std::sync::atomic::Ordering::SeqCst), 2);
    }

    #[test]
    fn delete_vector_roundtrip_and_rejects_garbage() {
        for n in [0usize, 1, 7, 8, 9, 200] {
            let deleted: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            assert_eq!(decode_dv(&encode_dv(&deleted)).unwrap(), deleted);
        }
        assert!(decode_dv(b"").is_err());
        assert!(decode_dv(b"XXXX\x01\x00\x00\x00\x00").is_err());
        // Wrong version, truncated bitmap.
        let mut enc = encode_dv(&[true; 9]);
        enc[4] = 9;
        assert!(decode_dv(&enc).is_err());
        let enc = encode_dv(&[true; 9]);
        assert!(decode_dv(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn delete_rows_masks_every_handler_path() {
        let r = registry();
        let batch = gen::sensor_table(200, 7);
        let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
        b.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
        // Tombstone rows 0..50, twice — the returned total must not
        // double-count.
        let del = |rows: &[u32]| {
            let mut w = ByteWriter::new();
            w.u32(rows.len() as u32);
            for &x in rows {
                w.u32(x);
            }
            w.finish()
        };
        let rows: Vec<u32> = (0..50).collect();
        let out = r.get("skyhook", "delete_rows").unwrap()(&mut b, &del(&rows)).unwrap();
        assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 50);
        let out = r.get("skyhook", "delete_rows").unwrap()(&mut b, &del(&rows)).unwrap();
        assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 50);
        // Out-of-range row is a hard error.
        assert!(r.get("skyhook", "delete_rows").unwrap()(&mut b, &del(&[200])).is_err());
        // read_dv returns the stored vector.
        let raw = r.get("skyhook", "read_dv").unwrap()(&mut b, &[]).unwrap();
        let deleted = decode_dv(&raw).unwrap();
        assert_eq!(deleted.iter().filter(|&&d| d).count(), 50);
        // exec: a full scan must return exactly the live rows.
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &exec_spec().encode()).unwrap();
        let (ExecOut::Rows(live), _) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert_eq!(live.nrows(), 150);
        assert_eq!(live, batch.slice(50, 150).unwrap());
        // scan handler honors the dv too.
        let out = r.get("skyhook", "scan").unwrap()(
            &mut b,
            &encode_scan_arg(&Predicate::True, None, true),
        )
        .unwrap();
        assert_eq!(decode_batch(&out).unwrap().0.nrows(), 150);
        // agg: count over the live rows only.
        let aggs = vec![Aggregate::new(AggFunc::Count, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&Predicate::True, &aggs, false, true),
        )
        .unwrap();
        assert_eq!(decode_agg_out(&out).unwrap()[0].count, 150);
        // Head limit must deliver the first live rows, not the first
        // stored rows (prefix_limit is disabled under a dv).
        let spec = PipelineSpec {
            limit: Some(7),
            ..exec_spec()
        };
        let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
        let (ExecOut::Rows(head), c) = decode_exec_out_full(&out, 0, 0).unwrap() else {
            panic!("expected rows");
        };
        assert!(!c.prefix_read);
        assert_eq!(head, batch.slice(50, 7).unwrap());
        // index_lookup drops tombstoned rows.
        let mut w = ByteWriter::new();
        w.str("sensor");
        r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        let Column::I64(sensors) = batch.col("sensor").unwrap() else {
            unreachable!()
        };
        let want = sensors
            .iter()
            .enumerate()
            .filter(|&(i, &s)| i >= 50 && s == 3)
            .count();
        let mut w = ByteWriter::new();
        w.str("sensor");
        w.i64(3);
        let out = r.get("skyhook", "index_lookup").unwrap()(&mut b, &w.finish()).unwrap();
        let mut rr = ByteReader::new(&out);
        assert_eq!(rr.u32().unwrap() as usize, want);
    }

    #[test]
    fn inverted_probe_windows_prune_instead_of_panicking() {
        // f64-level contradiction (`x > 5 AND x < 3`) over both index
        // encodings, plus the encoded-domain inversion that survives the
        // f64 check (`x > 5 AND x < 6` over i64 tightens to [6, 5]): all
        // must answer a counted empty probe without touching data.
        let r = registry();
        let cases: [(&str, Predicate); 3] = [
            (
                "sensor", // i64
                Predicate::cmp("sensor", CmpOp::Gt, 5.0)
                    .and(Predicate::cmp("sensor", CmpOp::Lt, 3.0)),
            ),
            (
                "val", // f32
                Predicate::cmp("val", CmpOp::Gt, 5.0).and(Predicate::cmp("val", CmpOp::Lt, 3.0)),
            ),
            (
                "sensor", // i64, non-empty over f64, empty over i64
                Predicate::cmp("sensor", CmpOp::Gt, 5.0)
                    .and(Predicate::cmp("sensor", CmpOp::Lt, 6.0)),
            ),
        ];
        for (col, pred) in cases {
            let batch = gen::sensor_table(200, 7);
            let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
            let mut w = ByteWriter::new();
            w.str(col);
            r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
            b.setxattr(ZONE_MAP_XATTR, &ZoneMap::from_batch(&batch).encode());
            // Destroy the data: only a probe-pruned answer survives.
            b.data = vec![0xff; 16];
            let spec = PipelineSpec {
                predicate: pred,
                aggs: vec![Aggregate::new(AggFunc::Count, col)],
                index: Some(col.to_string()),
                ..exec_spec()
            };
            let out = r.get("skyhook", "exec").unwrap()(&mut b, &spec.encode()).unwrap();
            let (ExecOut::Aggs(states), c) = decode_exec_out_full(&out, 0, 1).unwrap() else {
                panic!("expected aggs");
            };
            assert_eq!(states[0].count, 0, "{col}: inverted window must prune");
            assert_eq!((c.index_probes, c.index_postings), (1, 0));
        }
        // The second i64 case goes through `probe_key_range` itself —
        // assert the encoded inversion is detected at that level too.
        let probe = index_probe_window(
            &Predicate::cmp("x", CmpOp::Gt, 5.0).and(Predicate::cmp("x", CmpOp::Lt, 6.0)),
            "x",
        )
        .unwrap();
        assert!(!probe.empty, "f64 window [5,6] is non-empty");
        assert!(matches!(
            probe_key_range("x", b"i64", &probe),
            Some(ProbeKeys::Empty)
        ));
        // A sane window still yields a scannable range.
        let probe = index_probe_window(&Predicate::cmp("x", CmpOp::Ge, 3.0), "x").unwrap();
        assert!(matches!(
            probe_key_range("x", b"i64", &probe),
            Some(ProbeKeys::Range(..))
        ));
    }

    #[test]
    fn dump_index_lists_all_postings() {
        let r = registry();
        let batch = gen::sensor_table(50, 7);
        let mut b = MemBackend::new(&encode_batch(&batch, Layout::Col));
        let mut w = ByteWriter::new();
        w.str("sensor");
        r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        let mut w = ByteWriter::new();
        w.str("sensor");
        let out = r.get("skyhook", "dump_index").unwrap()(&mut b, &w.finish()).unwrap();
        let mut rr = ByteReader::new(&out);
        let n = rr.u32().unwrap() as usize;
        assert_eq!(n, 50);
        let Column::I64(sensors) = batch.col("sensor").unwrap() else {
            unreachable!()
        };
        let mut seen = vec![false; 50];
        for _ in 0..n {
            let klen = rr.u32().unwrap() as usize;
            let suffix = rr.raw(klen).unwrap().to_vec();
            let row = rr.u32().unwrap() as usize;
            // Suffix = order-preserving value encoding + BE row id.
            let mut want = index_key_i64(sensors[row]).to_vec();
            want.extend_from_slice(&(row as u32).to_be_bytes());
            assert_eq!(suffix, want);
            seen[row] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
