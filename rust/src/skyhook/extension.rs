//! The Skyhook-Extension: object-class handlers that process table
//! objects *inside* the storage servers (§4.2) — remote select / project /
//! filter / aggregate, group-by partials, and an omap-backed secondary
//! index (the RocksDB-based "remote indexing system").
//!
//! When a PJRT engine is supplied (the AOT-compiled JAX/Pallas chunk
//! kernel, see `runtime::`), the masked f32 aggregation inside
//! `skyhook.agg` executes on it — the paper's storage-side compute
//! offload running the very kernel the L1/L2 layers compiled.

use super::query::{AggState, Aggregate, Predicate};
use crate::dataset::layout::{decode_batch, encode_batch, Layout};
use crate::dataset::table::Column;
use crate::error::{Error, Result};
use crate::store::objclass::{ClassRegistry, ClsBackend};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::sync::Arc;

/// Per-row CPU cost of predicate evaluation in the extension (seconds).
const ROW_PRED_COST: f64 = 10e-9;
/// Per-value CPU cost of aggregation in the extension (seconds).
const VAL_AGG_COST: f64 = 4e-9;

/// Storage-side compute engine for the masked filter+aggregate hot spot.
/// Implemented by `runtime::PjrtEngine` (the AOT JAX/Pallas kernel); the
/// extension falls back to the native Rust loop when absent.
pub trait ChunkCompute: Send + Sync {
    /// Masked moments of `values`: returns `[count, sum, sumsq, min, max]`
    /// over elements where `mask` is true.
    fn masked_moments(&self, values: &[f32], mask: &[bool]) -> Result<[f64; 5]>;
}

/// Encode the input of `skyhook.scan`: predicate + projection.
pub fn encode_scan_arg(pred: &Predicate, projection: Option<&[String]>) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    match projection {
        Some(cols) => {
            w.u8(1);
            w.u32(cols.len() as u32);
            for c in cols {
                w.str(c);
            }
        }
        None => {
            w.u8(0);
        }
    }
    w.finish()
}

fn decode_scan_arg(input: &[u8]) -> Result<(Predicate, Option<Vec<String>>)> {
    let mut r = ByteReader::new(input);
    let pred = Predicate::decode_from(&mut r)?;
    let projection = match r.u8()? {
        0 => None,
        1 => {
            let n = r.u32()? as usize;
            let mut cols = Vec::with_capacity(n);
            for _ in 0..n {
                cols.push(r.str()?.to_string());
            }
            Some(cols)
        }
        o => return Err(Error::Corrupt(format!("bad projection tag {o}"))),
    };
    Ok((pred, projection))
}

/// Encode the input of `skyhook.agg`: predicate + aggregate list +
/// whether raw values must be returned (holistic finalization).
pub fn encode_agg_arg(pred: &Predicate, aggs: &[Aggregate], keep_values: bool) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    w.u8(keep_values as u8);
    w.u32(aggs.len() as u32);
    for a in aggs {
        w.str(&a.col);
        w.u8(a.func.code());
    }
    w.finish()
}

fn decode_agg_arg(input: &[u8]) -> Result<(Predicate, bool, Vec<String>)> {
    let mut r = ByteReader::new(input);
    let pred = Predicate::decode_from(&mut r)?;
    let keep_values = r.u8()? != 0;
    let n = r.u32()? as usize;
    let mut cols = Vec::with_capacity(n);
    for _ in 0..n {
        cols.push(r.str()?.to_string());
        let _func = r.u8()?; // per-agg func is only needed at finalize time
    }
    Ok((pred, keep_values, cols))
}

/// Encode the input of `skyhook.group_agg`.
pub fn encode_group_arg(pred: &Predicate, group_col: &str, agg_col: &str) -> Vec<u8> {
    let mut w = ByteWriter::new();
    pred.encode_into(&mut w);
    w.str(group_col);
    w.str(agg_col);
    w.finish()
}

/// Decode the output of `skyhook.agg`: one state per requested aggregate.
pub fn decode_agg_out(out: &[u8]) -> Result<Vec<AggState>> {
    let mut r = ByteReader::new(out);
    let n = r.u32()? as usize;
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        states.push(AggState::decode_from(&mut r)?);
    }
    Ok(states)
}

/// Decode the output of `skyhook.group_agg`: (group key, state) pairs.
pub fn decode_group_out(out: &[u8]) -> Result<Vec<(i64, AggState)>> {
    let mut r = ByteReader::new(out);
    let n = r.u32()? as usize;
    let mut groups = Vec::with_capacity(n);
    for _ in 0..n {
        let key = r.i64()?;
        groups.push((key, AggState::decode_from(&mut r)?));
    }
    Ok(groups)
}

/// Order-preserving big-endian encoding of i64 (for omap index keys).
pub fn index_key_i64(x: i64) -> [u8; 8] {
    ((x as u64) ^ (1u64 << 63)).to_be_bytes()
}

/// Largest header prefix we read before falling back to a full read.
const HEADER_PREFIX: usize = 64 * 1024;

/// Read only the columns a handler needs.
///
/// For columnar objects this issues *ranged device reads* via the header
/// directory — the physical advantage of the Col layout (§5 physical
/// design): untouched columns never leave the device, and bytes-read
/// metering (hence simulated device time) reflects that. Row objects are
/// read whole. `needed = None` reads everything.
///
/// Returns a batch containing exactly the needed columns (schema order).
fn read_needed(
    b: &mut dyn ClsBackend,
    needed: Option<&[String]>,
) -> Result<crate::dataset::table::Batch> {
    use crate::dataset::layout::{decode_one_col, parse_header};
    use crate::dataset::table::Batch;

    let Some(needed) = needed else {
        let raw = b.read()?;
        return Ok(decode_batch(&raw)?.0);
    };
    let size = b.size()?;
    let prefix = b.read_range(0, size.min(HEADER_PREFIX))?;
    let header = match parse_header(&prefix) {
        Ok(h) if h.layout == Layout::Col => h,
        // Row layout, oversized header, or parse trouble: full read.
        _ => {
            let raw = b.read()?;
            let (batch, _) = decode_batch(&raw)?;
            let refs: Vec<&str> = needed.iter().map(String::as_str).collect();
            return batch.project(&refs);
        }
    };
    // Validate names early.
    for n in needed {
        header.schema.col_index(n)?;
    }
    let mut schema_cols = Vec::new();
    let mut columns = Vec::new();
    for (ci, col_schema) in header.schema.columns.iter().enumerate() {
        if !needed.contains(&col_schema.name) {
            continue;
        }
        let (off, len, crc) = header.directory[ci];
        let start = header.payload_start + off as usize;
        let bytes = if start + len as usize <= prefix.len() {
            prefix[start..start + len as usize].to_vec()
        } else {
            b.read_range(start, len as usize)?
        };
        if crc32fast::hash(&bytes) != crc {
            return Err(Error::Corrupt(format!(
                "column {:?} checksum mismatch",
                col_schema.name
            )));
        }
        let mut col = crate::dataset::table::Column::empty(col_schema.dtype);
        decode_one_col(&mut col, header.nrows, &bytes)?;
        schema_cols.push((col_schema.name.as_str(), col_schema.dtype));
        columns.push(col);
    }
    Batch::new(
        crate::dataset::TableSchema::new(&schema_cols),
        columns,
    )
}

/// Union of column names used by a predicate and an extra set.
fn needed_union(pred: &Predicate, extra: &[String]) -> Vec<String> {
    let mut v = pred.columns();
    v.extend(extra.iter().cloned());
    v.sort();
    v.dedup();
    v
}

/// Register the `skyhook` class with an optional PJRT compute engine.
pub fn register_skyhook_class(r: &mut ClassRegistry, engine: Option<Arc<dyn ChunkCompute>>) {
    // skyhook.scan — filter+project on the server, return a Col batch.
    r.register("skyhook", "scan", |b, input| {
        let (pred, projection) = decode_scan_arg(input)?;
        // Read only predicate + projection columns (ranged reads on Col).
        let batch = match &projection {
            Some(cols) => read_needed(b, Some(&needed_union(&pred, cols)))?,
            None => read_needed(b, None)?,
        };
        b.charge_cpu(batch.nrows() as f64 * ROW_PRED_COST);
        let mask = pred.eval(&batch)?;
        let filtered = batch.filter(&mask)?;
        let result = match projection {
            Some(cols) => {
                let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                filtered.project(&refs)?
            }
            None => filtered,
        };
        Ok(encode_batch(&result, Layout::Col))
    });

    // skyhook.agg — filter+aggregate on the server, return partials.
    let eng = engine.clone();
    r.register("skyhook", "agg", move |b, input| {
        let (pred, keep_values, cols) = decode_agg_arg(input)?;
        let batch = read_needed(b, Some(&needed_union(&pred, &cols)))?;
        b.charge_cpu(batch.nrows() as f64 * ROW_PRED_COST);
        let mask = pred.eval(&batch)?;
        let mut w = ByteWriter::new();
        w.u32(cols.len() as u32);
        for col_name in &cols {
            let col = batch.col(col_name)?;
            let mut st = AggState::new(keep_values);
            // Hot path: masked moments of an f32 column → PJRT kernel.
            match (col, &eng, keep_values) {
                (Column::F32(v), Some(engine), false) => {
                    let m = engine.masked_moments(v, &mask)?;
                    st.count = m[0] as u64;
                    st.sum = m[1];
                    st.sumsq = m[2];
                    if st.count > 0 {
                        st.min = m[3];
                        st.max = m[4];
                    }
                }
                _ => {
                    b.charge_cpu(batch.nrows() as f64 * VAL_AGG_COST);
                    st.update_column(col, &mask)?;
                }
            }
            st.encode_into(&mut w);
        }
        Ok(w.finish())
    });

    // skyhook.group_agg — grouped partials keyed by an i64 column.
    r.register("skyhook", "group_agg", |b, input| {
        let mut r = ByteReader::new(input);
        let pred = Predicate::decode_from(&mut r)?;
        let group_col = r.str()?.to_string();
        let agg_col = r.str()?.to_string();
        let batch = read_needed(
            b,
            Some(&needed_union(&pred, &[group_col.clone(), agg_col.clone()])),
        )?;
        b.charge_cpu(batch.nrows() as f64 * (ROW_PRED_COST + VAL_AGG_COST));
        let mask = pred.eval(&batch)?;
        let keys = match batch.col(&group_col)? {
            Column::I64(v) => v,
            _ => return Err(Error::Query("group_by needs an i64 column".into())),
        };
        let vals = batch.col(&agg_col)?;
        let mut groups: std::collections::BTreeMap<i64, AggState> = Default::default();
        for (i, &keep) in mask.iter().enumerate() {
            if keep {
                groups
                    .entry(keys[i])
                    .or_insert_with(|| AggState::new(false))
                    .update(vals.get_f64(i)?);
            }
        }
        let mut w = ByteWriter::new();
        w.u32(groups.len() as u32);
        for (k, st) in groups {
            w.i64(k);
            st.encode_into(&mut w);
        }
        Ok(w.finish())
    });

    // skyhook.build_index — omap index over an i64 column: key =
    // `i/<col>/<be-value>/<row>` → row id. The paper's RocksDB indexing.
    r.register("skyhook", "build_index", |b, input| {
        let mut r = ByteReader::new(input);
        let col_name = r.str()?.to_string();
        let raw = b.read()?;
        let (batch, _) = decode_batch(&raw)?;
        let keys = match batch.col(&col_name)? {
            Column::I64(v) => v,
            _ => return Err(Error::Query("index needs an i64 column".into())),
        };
        b.charge_cpu(keys.len() as f64 * 50e-9); // kv insert cost
        for (row, &k) in keys.iter().enumerate() {
            let mut key = Vec::with_capacity(col_name.len() + 16);
            key.extend_from_slice(b"i/");
            key.extend_from_slice(col_name.as_bytes());
            key.push(b'/');
            key.extend_from_slice(&index_key_i64(k));
            key.extend_from_slice(&(row as u32).to_be_bytes());
            b.omap_set(&key, &(row as u32).to_le_bytes());
        }
        b.setxattr(&format!("index.{col_name}"), b"1");
        Ok((keys.len() as u64).to_le_bytes().to_vec())
    });

    // skyhook.index_lookup — equality lookup: rows where col == value.
    r.register("skyhook", "index_lookup", |b, input| {
        let mut r = ByteReader::new(input);
        let col_name = r.str()?.to_string();
        let value = r.i64()?;
        if b.getxattr(&format!("index.{col_name}")).is_none() {
            return Err(Error::Query(format!("no index on {col_name:?}")));
        }
        let mut prefix = Vec::with_capacity(col_name.len() + 12);
        prefix.extend_from_slice(b"i/");
        prefix.extend_from_slice(col_name.as_bytes());
        prefix.push(b'/');
        prefix.extend_from_slice(&index_key_i64(value));
        let hits = b.omap_scan_prefix(&prefix);
        let mut w = ByteWriter::new();
        w.u32(hits.len() as u32);
        for (_, v) in hits {
            w.u32(u32::from_le_bytes(v.as_slice().try_into().map_err(|_| {
                Error::Corrupt("bad index entry".into())
            })?));
        }
        Ok(w.finish())
    });

    // skyhook.quantile_sketch — the §3.2 de-composable approximation:
    // build a constant-size mergeable quantile sketch over the filtered
    // column, instead of shipping raw values for holistic functions.
    // Input: predicate + column name. Output: encoded QuantileSketch.
    r.register("skyhook", "quantile_sketch", |b, input| {
        let mut r = ByteReader::new(input);
        let pred = Predicate::decode_from(&mut r)?;
        let col_name = r.str()?.to_string();
        let batch = read_needed(b, Some(&needed_union(&pred, &[col_name.clone()])))?;
        b.charge_cpu(batch.nrows() as f64 * (ROW_PRED_COST + VAL_AGG_COST));
        let mask = pred.eval(&batch)?;
        let col = batch.col(&col_name)?;
        let mut values = Vec::with_capacity(mask.iter().filter(|&&m| m).count());
        for (i, &m) in mask.iter().enumerate() {
            if m {
                values.push(col.get_f64(i)?);
            }
        }
        let sketch = super::sketch::QuantileSketch::build(&values);
        let mut w = ByteWriter::new();
        sketch.encode_into(&mut w);
        Ok(w.finish())
    });

    // skyhook.transform — rewrite the object in the other layout
    // (physical design management, §5 bullet 2).
    r.register("skyhook", "transform", |b, input| {
        let target = match input.first() {
            Some(0) => Layout::Row,
            Some(1) => Layout::Col,
            _ => return Err(Error::Invalid("transform wants layout byte".into())),
        };
        let raw = b.read()?;
        let (batch, current) = decode_batch(&raw)?;
        if current == target {
            return Ok(vec![current as u8]);
        }
        b.charge_cpu(batch.nrows() as f64 * batch.ncols() as f64 * 3e-9);
        b.write(&encode_batch(&batch, target))?;
        Ok(vec![target as u8])
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::skyhook::query::{AggFunc, CmpOp};
    use crate::store::objclass::MemBackend;

    fn registry() -> ClassRegistry {
        let mut r = ClassRegistry::with_builtins();
        register_skyhook_class(&mut r, None);
        r
    }

    fn table_object() -> Vec<u8> {
        encode_batch(&gen::sensor_table(200, 7), Layout::Col)
    }

    #[test]
    fn scan_filters_and_projects() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let pred = Predicate::cmp("flag", CmpOp::Eq, 1.0);
        let out = r.get("skyhook", "scan").unwrap()(
            &mut b,
            &encode_scan_arg(&pred, Some(&["val".to_string(), "ts".to_string()])),
        )
        .unwrap();
        let (batch, layout) = decode_batch(&out).unwrap();
        assert_eq!(layout, Layout::Col);
        assert_eq!(batch.ncols(), 2);
        assert!(batch.nrows() > 0 && batch.nrows() < 200);
        assert!(b.cpu > 0.0);

        // Verify against direct evaluation.
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let mask = pred.eval(&orig).unwrap();
        let want = mask.iter().filter(|&&m| m).count();
        assert_eq!(batch.nrows(), want);
    }

    #[test]
    fn scan_without_projection_keeps_all_columns() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out =
            r.get("skyhook", "scan").unwrap()(&mut b, &encode_scan_arg(&Predicate::True, None))
                .unwrap();
        let (batch, _) = decode_batch(&out).unwrap();
        assert_eq!(batch.ncols(), 4);
        assert_eq!(batch.nrows(), 200);
    }

    #[test]
    fn agg_partials_match_direct() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let pred = Predicate::cmp("val", CmpOp::Gt, 50.0);
        let aggs = vec![
            Aggregate::new(AggFunc::Count, "val"),
            Aggregate::new(AggFunc::Sum, "val"),
        ];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&pred, &aggs, false),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states.len(), 2);

        let (orig, _) = decode_batch(&table_object()).unwrap();
        let mask = pred.eval(&orig).unwrap();
        let mut direct = AggState::new(false);
        direct
            .update_column(orig.col("val").unwrap(), &mask)
            .unwrap();
        assert_eq!(states[0].count, direct.count);
        assert!((states[1].sum - direct.sum).abs() < 1e-6);
        // Partials are constant-size (no raw values).
        assert!(states[0].values.is_none());
        assert!(out.len() < 200);
    }

    #[test]
    fn agg_with_values_for_median() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let aggs = vec![Aggregate::new(AggFunc::Median, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&Predicate::True, &aggs, true),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states[0].values.as_ref().unwrap().len(), 200);
        states[0].finalize(AggFunc::Median).unwrap();
    }

    #[test]
    fn group_agg_partitions_by_key() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out = r.get("skyhook", "group_agg").unwrap()(
            &mut b,
            &encode_group_arg(&Predicate::True, "sensor", "val"),
        )
        .unwrap();
        let groups = decode_group_out(&out).unwrap();
        assert!(!groups.is_empty());
        let total: u64 = groups.iter().map(|(_, s)| s.count).sum();
        assert_eq!(total, 200);
        // Keys sorted and unique.
        for w in groups.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn group_agg_rejects_non_i64_key() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        assert!(r.get("skyhook", "group_agg").unwrap()(
            &mut b,
            &encode_group_arg(&Predicate::True, "val", "val"),
        )
        .is_err());
    }

    #[test]
    fn index_build_and_lookup() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let mut w = ByteWriter::new();
        w.str("sensor");
        let out = r.get("skyhook", "build_index").unwrap()(&mut b, &w.finish()).unwrap();
        assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 200);

        // Look up rows where sensor == most common value.
        let (orig, _) = decode_batch(&table_object()).unwrap();
        let sensors = match orig.col("sensor").unwrap() {
            Column::I64(v) => v.clone(),
            _ => unreachable!(),
        };
        let target = sensors[0];
        let want: Vec<u32> = sensors
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == target)
            .map(|(i, _)| i as u32)
            .collect();

        let mut w = ByteWriter::new();
        w.str("sensor");
        w.i64(target);
        let out = r.get("skyhook", "index_lookup").unwrap()(&mut b, &w.finish()).unwrap();
        let mut rr = ByteReader::new(&out);
        let n = rr.u32().unwrap() as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(rr.u32().unwrap());
        }
        rows.sort_unstable();
        assert_eq!(rows, want);
    }

    #[test]
    fn index_lookup_without_index_fails() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let mut w = ByteWriter::new();
        w.str("sensor");
        w.i64(1);
        assert!(r.get("skyhook", "index_lookup").unwrap()(&mut b, &w.finish()).is_err());
    }

    #[test]
    fn index_key_order_preserving() {
        let mut keys: Vec<i64> = vec![-5, 3, 0, i64::MIN, i64::MAX, -1, 7];
        keys.sort_unstable();
        let encoded: Vec<[u8; 8]> = keys.iter().map(|&k| index_key_i64(k)).collect();
        let mut sorted = encoded.clone();
        sorted.sort();
        assert_eq!(encoded, sorted);
    }

    #[test]
    fn transform_rewrites_layout() {
        let r = registry();
        let mut b = MemBackend::new(&table_object());
        let out = r.get("skyhook", "transform").unwrap()(&mut b, &[0u8]).unwrap();
        assert_eq!(out, vec![0u8]);
        let (_, layout) = decode_batch(&b.data).unwrap();
        assert_eq!(layout, Layout::Row);
        // Idempotent no-op when already in target layout.
        let before = b.data.clone();
        r.get("skyhook", "transform").unwrap()(&mut b, &[0u8]).unwrap();
        assert_eq!(b.data, before);
    }

    #[test]
    fn pjrt_hook_is_used_when_present() {
        struct FakeEngine(std::sync::atomic::AtomicU64);
        impl ChunkCompute for FakeEngine {
            fn masked_moments(&self, values: &[f32], mask: &[bool]) -> Result<[f64; 5]> {
                self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                let mut st = AggState::new(false);
                for (i, &m) in mask.iter().enumerate() {
                    if m {
                        st.update(values[i] as f64);
                    }
                }
                Ok([st.count as f64, st.sum, st.sumsq, st.min, st.max])
            }
        }
        let engine = Arc::new(FakeEngine(Default::default()));
        let mut r = ClassRegistry::with_builtins();
        register_skyhook_class(&mut r, Some(engine.clone() as Arc<dyn ChunkCompute>));
        let mut b = MemBackend::new(&table_object());
        let aggs = vec![Aggregate::new(AggFunc::Mean, "val")];
        let out = r.get("skyhook", "agg").unwrap()(
            &mut b,
            &encode_agg_arg(&Predicate::True, &aggs, false),
        )
        .unwrap();
        let states = decode_agg_out(&out).unwrap();
        assert_eq!(states[0].count, 200);
        assert_eq!(engine.0.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
