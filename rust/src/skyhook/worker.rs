//! Skyhook-Worker (§4.2): executes one sub-query — either by invoking the
//! Skyhook-Extension on the object's OSD (pushdown) or by fetching the
//! object and computing client-side — and, on the write path, partitions
//! data, adds the format wrapper, computes per-column zone maps, and
//! writes objects (data + `skyhook.zonemap` xattr).
//!
//! Pushdown encodes the planner's server-side stage block (one
//! [`super::logical::PipelineSpec`]) and executes the whole chained
//! pipeline in a single
//! `skyhook.exec` call per object. Client-side execution fetches only
//! the columns the query touches when the object is columnar (projected
//! partial reads via [`layout::read_projected_stats`] over ranged,
//! extent-coalescing cluster reads) and then runs the *identical*
//! pipeline through the shared [`super::exec_kernel`] — the same
//! `run_pipeline` the storage-side extension executes, including the
//! per-object sort/top-k stages of chained plans. There is no separate
//! client evaluator to drift.
//!
//! All client-side CPU is priced by the cluster-owned
//! [`crate::simnet::ExecProfile`] (decode bandwidth + per-row cost,
//! plus the kernel's movable aggregation/sort work) — charged to the
//! worker's timeline so client-side execution pays the CPU the paper
//! wants to offload.

use super::exec_kernel::{self, run_pipeline, ExecOut};
use super::extension::decode_exec_out_full;
use super::logical::PipelineSpec;
use super::plan::{ExecMode, SubQuery};
use super::query::AggState;
use crate::dataset::layout::{self, encode_batch, Layout};
use crate::dataset::metadata::{ColumnStats, ZoneMap, ZONE_MAP_XATTR};
use crate::dataset::table::Batch;
use crate::error::Result;
use crate::simnet::Timeline;
use crate::store::Cluster;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Duration;

/// What one sub-query produced.
#[derive(Debug)]
pub enum SubOutput {
    Rows(Batch),
    Aggs(Vec<AggState>),
    /// Grouped partials: multi-column i64 key → one state per aggregate.
    Groups(Vec<(Vec<i64>, Vec<AggState>)>),
}

/// Result of one sub-query execution.
#[derive(Debug)]
pub struct SubResult {
    pub output: SubOutput,
    /// Bytes that crossed the client↔storage network for this sub-query.
    pub bytes_moved: u64,
    /// Ranged reads saved by column-extent coalescing (client-side
    /// partial reads only; pushdown coalesces on the device instead).
    pub reads_coalesced: u64,
    /// Row partials only: the storage server already sorted this partial
    /// by the query's sort keys (pushed-down top-k), so the driver can
    /// k-way merge it without re-sorting.
    pub presorted: bool,
    /// Did this sub-query degenerate into a bounded prefix read (the
    /// sort-aware clustered layout's payoff: head/ascending-top-k served
    /// from the object's first k rows)?
    pub prefix_reads: u64,
    /// Rows the kernel's sorted-run binary search spared the filter
    /// (counted wherever the kernel ran; pushdown ships it back in the
    /// response frame).
    pub rows_short_circuited: u64,
    /// Chunks the storage server's compiled execution tier launched for
    /// this sub-query (from the response frame). Always 0 client-side:
    /// the compiled tier is a storage-server capability, the client runs
    /// the scalar kernel.
    pub compiled_chunks: u64,
    /// Rows the storage server's compiled tier covered.
    pub compiled_rows: u64,
    /// Secondary-index probes the storage server issued for this
    /// sub-query (the IndexScan access path; from the response frame).
    /// Always 0 client-side — the worker has no omap to probe.
    pub index_probes: u64,
    /// Postings those probes returned (the pre-mask population).
    pub index_postings: u64,
    /// Did this client-side sub-query reuse a batch another in-flight
    /// query fetched and decoded (the shared-scan cache)? `1` on a hit —
    /// `bytes_moved` is then 0 because nothing crossed the network.
    pub shared_scan_hits: u64,
    /// Virtual completion time.
    pub finish: f64,
}

// ---- shared-scan batching -------------------------------------------------

/// Cache key: the exact inputs that determine the fetched batch bytes —
/// object name, projected column set (`*` = all), and the bounded prefix
/// limit (`u64::MAX` = unbounded). Same key ⇒ bit-identical batch, so a
/// hit can never change results, only skip a fetch+decode.
type ScanKey = (String, String, u64);

enum SlotState {
    /// A leader is fetching; followers wait on the condvar.
    Pending,
    /// The decoded batch, shareable, available from virtual time
    /// `ready_at` (the leader's read frontier).
    Ready { batch: Arc<Batch>, ready_at: f64 },
    /// The leader errored or panicked; followers fall back to their own
    /// direct fetch.
    Failed,
}

struct ScanSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Request-merging cache for client-side scans: when N in-flight queries
/// need the same `(object, columns, prefix)` batch, one **leader**
/// fetches and decodes it and every **follower** reuses the decoded
/// batch — the shared-scan batching of the serving layer. The driver
/// owns one of these and scopes its lifetime to overlapping queries
/// (cleared when the last in-flight query finishes and on any write).
///
/// Driver-level clears alone are not enough: mutations can reach the
/// cluster without going through `Driver::write` (direct
/// `Cluster::write_object`/`delete_object`, delete-vector stamps,
/// appends, compaction). Every slot lookup therefore also checks the
/// cluster's [`Cluster::mutation_epoch`] — a counter every OSD bumps on
/// any state change — and flushes the whole cache when it moved, so no
/// mutation path can leave a stale decoded batch servable to followers.
pub struct ScanCache {
    slots: Mutex<HashMap<ScanKey, Arc<ScanSlot>>>,
    hits: AtomicU64,
    /// Cluster mutation epoch the current slot population was built
    /// under; a lookup under a different epoch flushes first.
    epoch: AtomicU64,
}

impl Default for ScanCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ScanCache {
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
        }
    }

    /// Lifetime shared-scan hits served.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Drop every entry (the driver calls this when the in-flight query
    /// count reaches zero and after any write/transform/index build).
    pub fn clear(&self) {
        plock(&self.slots).clear();
    }

    /// Look up `key`, creating a `Pending` slot if absent. Returns the
    /// slot and whether this caller is the leader (it created the slot
    /// and owes it a fill or a fail). `epoch` is the cluster's current
    /// mutation epoch: if any mutation landed since the slots were
    /// populated, the stale population is flushed before the lookup —
    /// the single invalidation choke point no mutation path can bypass.
    fn slot(&self, key: &ScanKey, epoch: u64) -> (Arc<ScanSlot>, bool) {
        let mut slots = plock(&self.slots);
        if self.epoch.swap(epoch, Ordering::AcqRel) != epoch {
            slots.clear();
        }
        if let Some(s) = slots.get(key) {
            return (Arc::clone(s), false);
        }
        let s = Arc::new(ScanSlot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        });
        slots.insert(key.clone(), Arc::clone(&s));
        (s, true)
    }
}

/// Poison-tolerant lock (same rationale as the backpressure gate: the
/// protected state is always valid, a stranger's panic must not cascade).
fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl ScanSlot {
    fn fill(&self, batch: Arc<Batch>, ready_at: f64) {
        *plock(&self.state) = SlotState::Ready { batch, ready_at };
        self.cv.notify_all();
    }

    fn fail(&self) {
        *plock(&self.state) = SlotState::Failed;
        self.cv.notify_all();
    }

    /// Wait for the leader's outcome: `Some` = the shared batch, `None` =
    /// the leader failed (or the bounded wait elapsed — the leader runs
    /// to completion on a pool thread, so this is a liveness backstop,
    /// not an expected path); the follower then fetches directly, which
    /// yields the identical batch.
    fn wait_ready(&self) -> Option<(Arc<Batch>, f64)> {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut st = plock(&self.state);
        loop {
            match &*st {
                SlotState::Ready { batch, ready_at } => {
                    return Some((Arc::clone(batch), *ready_at))
                }
                SlotState::Failed => return None,
                SlotState::Pending => {
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (g, _) = self
                        .cv
                        .wait_timeout(st, deadline - now)
                        .unwrap_or_else(PoisonError::into_inner);
                    st = g;
                }
            }
        }
    }
}

/// Marks the slot `Failed` unless the leader disarms it by filling —
/// covering both error returns and panics mid-fetch, so followers can
/// never wait forever on a leader that died.
struct LeaderGuard {
    slot: Arc<ScanSlot>,
    armed: bool,
}

impl Drop for LeaderGuard {
    fn drop(&mut self) {
        if self.armed {
            self.slot.fail();
        }
    }
}

/// Execute one sub-query against the cluster, charging worker-side work
/// to `worker_cpu`. `spec` is the plan's server-side stage block
/// (`QueryPlan::pipeline` / `plan::server_pipeline`), built once per
/// plan and shared across every sub-query — the same chain runs on
/// whichever side `sub.mode` chose. `shared` is the driver's shared-scan
/// cache (client path only; pushdown decodes on the OSD): `None` runs
/// every fetch directly.
pub fn execute_subquery(
    cluster: &Arc<Cluster>,
    spec: &PipelineSpec,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
    shared: Option<&ScanCache>,
) -> Result<SubResult> {
    match sub.mode {
        ExecMode::Pushdown => execute_pushdown(cluster, spec, sub, at, worker_cpu),
        ExecMode::ClientSide => execute_client_side(cluster, spec, sub, at, worker_cpu, shared),
    }
}

fn execute_pushdown(
    cluster: &Arc<Cluster>,
    spec: &PipelineSpec,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<SubResult> {
    // The planner's server-side stage block, encoded and executed in a
    // single pass on the OSD. The probe column is a per-object planner
    // choice, so it is stamped here rather than in the shared spec.
    let input = if sub.index_col.is_some() {
        let mut probed = spec.clone();
        probed.index = sub.index_col.clone();
        probed.encode()
    } else {
        spec.encode()
    };
    let t = cluster.call(at, &sub.object, "skyhook", "exec", &input)?;
    let bytes = (input.len() + t.value.len()) as u64;
    let (out, counters) = decode_exec_out_full(&t.value, spec.keys.len(), spec.aggs.len())?;
    let finish = worker_cpu.submit(
        t.finish,
        cluster.cost().exec.decode_time(t.value.len() as u64),
    );
    let output = match out {
        ExecOut::Rows(b) => SubOutput::Rows(b),
        ExecOut::Aggs(states) => SubOutput::Aggs(states),
        ExecOut::Groups(gs) => SubOutput::Groups(gs),
    };
    Ok(SubResult {
        output,
        bytes_moved: bytes,
        reads_coalesced: 0,
        // A pushed-down partial top-k arrives sorted by the spec's keys.
        presorted: !spec.sort.is_empty(),
        prefix_reads: counters.prefix_read as u64,
        rows_short_circuited: counters.rows_short_circuited,
        compiled_chunks: counters.compiled_chunks,
        compiled_rows: counters.compiled_rows,
        index_probes: counters.index_probes,
        index_postings: counters.index_postings,
        shared_scan_hits: 0,
        finish,
    })
}

/// [`layout::RangeSource`] over cluster reads of one object: tracks the
/// virtual-time frontier across sequential ranged reads and meters the
/// bytes that actually crossed the network.
struct ClusterRange<'a> {
    cluster: &'a Cluster,
    object: &'a str,
    at: f64,
    fetched: u64,
}

impl layout::RangeSource for ClusterRange<'_> {
    fn size(&mut self) -> Result<usize> {
        let t = self.cluster.stat_object(self.at, self.object)?;
        self.at = t.finish;
        Ok(t.value.size as usize)
    }
    fn read_range(&mut self, offset: usize, len: usize) -> Result<Vec<u8>> {
        let t = self
            .cluster
            .read_object_range(self.at, self.object, offset, len)?;
        self.at = t.finish;
        self.fetched += t.value.len() as u64;
        Ok(t.value)
    }
    fn read_all(&mut self) -> Result<Vec<u8>> {
        let t = self.cluster.read_object(self.at, self.object)?;
        self.at = t.finish;
        self.fetched += t.value.len() as u64;
        Ok(t.value)
    }
}

/// What one client-side fetch produced: the decoded (projected, possibly
/// prefix-bounded) batch plus the metering the leader observed.
struct FetchOut {
    batch: Batch,
    bytes: u64,
    coalesced: u64,
    prefix_reads: u64,
    /// Virtual time at which the last read completed.
    frontier: f64,
}

/// The client-side fetch: only the columns the pipeline touches
/// (coalesced ranged reads on Col objects); Row objects must be read
/// whole anyway, so skip the stat/prefix probing and issue the one full
/// read directly (the pre-zone-map cost profile).
fn fetch_client_batch(
    cluster: &Cluster,
    sub: &SubQuery,
    needed: Option<&[String]>,
    plim: Option<u64>,
    at: f64,
) -> Result<FetchOut> {
    let mut src = ClusterRange {
        cluster,
        object: &sub.object,
        at,
        fetched: 0,
    };
    let mut coalesced = 0u64;
    let mut prefix_reads = 0u64;
    let needed_refs: Option<Vec<&str>> =
        needed.map(|cols| cols.iter().map(String::as_str).collect());
    let batch = if sub.layout == Layout::Col {
        match plim {
            // Bounded prefix fetch: when the planner's sortedness markers
            // prove the pipeline needs only the object's first k rows
            // (head, or ascending top-k over the clustered column), fetch
            // exactly that row prefix of the needed columns instead of
            // whole extents — the clustered layout's bytes-moved payoff
            // on the client path.
            Some(k) => {
                let (batch, rstats, bounded) = layout::read_projected_rows(
                    &mut src,
                    needed_refs.as_deref(),
                    sub.header_prefix,
                    k,
                )?;
                coalesced = rstats.reads_coalesced as u64;
                prefix_reads = bounded as u64;
                batch
            }
            None => {
                let (batch, rstats) = layout::read_projected_stats(
                    &mut src,
                    needed_refs.as_deref(),
                    sub.header_prefix,
                )?;
                coalesced = rstats.reads_coalesced as u64;
                batch
            }
        }
    } else {
        // Row objects decode whole; trim to the pipeline's column set
        // up front so the kernel's filter doesn't copy unneeded columns
        // per matching row (the same batch shape the server-side
        // read_needed produces).
        let full = layout::read_projected(&mut src, None, sub.header_prefix)?;
        match &needed_refs {
            Some(refs) => full.project(refs)?,
            None => full,
        }
    };
    Ok(FetchOut {
        batch,
        bytes: src.fetched,
        coalesced,
        prefix_reads,
        frontier: src.at,
    })
}

fn execute_client_side(
    cluster: &Arc<Cluster>,
    spec: &PipelineSpec,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
    shared: Option<&ScanCache>,
) -> Result<SubResult> {
    // The client runs the *same* server-side stage block, through the
    // same kernel: encode nothing, but evaluate the identical
    // PipelineSpec locally.
    let needed = super::exec_kernel::needed_columns(spec);
    let sorted = |c: &str| sub.sorted_cols.iter().any(|s| s == c);

    // Tombstoned object: fetch its delete vector first and run the
    // kernel pre-masked, exactly as the storage-side extension does, so
    // deleted rows can never surface from the client path either. The
    // planner stamps `sub.tombstones` from dataset metadata, so
    // never-mutated datasets pay no extra round trip here.
    let (dv_live, dv_bytes, at) = if sub.tombstones > 0 {
        let t = cluster.call(at, &sub.object, "skyhook", "read_dv", &[])?;
        let dv_bytes = t.value.len() as u64;
        let live: Option<Vec<bool>> = if t.value.is_empty() {
            None
        } else {
            let deleted = super::extension::decode_dv(&t.value)?;
            Some(deleted.iter().map(|&d| !d).collect())
        };
        (live, dv_bytes, t.finish)
    } else {
        (None, 0u64, at)
    };

    // A delete vector voids the bounded-prefix shortcut: the first k
    // stored rows are no longer the first k *live* rows.
    let plim = if dv_live.is_some() {
        None
    } else {
        exec_kernel::prefix_limit(spec, &sorted)
    };

    // Shared-scan batching: concurrent queries needing the same batch
    // elect a leader per cache key; followers reuse its decode. The key
    // pins everything that shapes the fetched bytes, so a hit is
    // bit-identical to fetching — results can never differ, only the
    // bytes-moved/CPU accounting improves.
    let mut hit: Option<(Arc<Batch>, f64)> = None;
    let mut leader: Option<LeaderGuard> = None;
    if let Some(cache) = shared {
        let cols_key = match &needed {
            Some(cols) => cols.join(","),
            None => "*".into(),
        };
        let key: ScanKey = (sub.object.clone(), cols_key, plim.unwrap_or(u64::MAX));
        let (slot, is_leader) = cache.slot(&key, cluster.mutation_epoch());
        if is_leader {
            leader = Some(LeaderGuard { slot, armed: true });
        } else {
            hit = slot.wait_ready();
            if hit.is_some() {
                cache.hits.fetch_add(1, Ordering::Relaxed);
            }
            // A failed leader leaves `hit` None: fall through to a
            // direct fetch of our own (same bytes, same batch).
        }
    }

    let prof = &cluster.cost().exec;
    let (batch, bytes, coalesced, prefix_reads, start, cpu_fetch, shared_scan_hits) = match hit {
        Some((batch, ready_at)) => {
            // The shared batch exists from the leader's read frontier;
            // this sub-query pays no fetch and no decode, only its own
            // kernel work below.
            (batch, 0u64, 0u64, 0u64, at.max(ready_at), 0.0, 1u64)
        }
        None => {
            let fetched = fetch_client_batch(cluster, sub, needed.as_deref(), plim, at);
            let out = match fetched {
                Ok(f) => f,
                Err(e) => {
                    // LeaderGuard's Drop marks the slot Failed so
                    // followers fall back instead of waiting forever.
                    return Err(e);
                }
            };
            let batch = Arc::new(out.batch);
            if let Some(mut g) = leader.take() {
                g.armed = false;
                g.slot.fill(Arc::clone(&batch), out.frontier);
            }
            let decode = prof.client_cpu(out.bytes, 0);
            (
                batch,
                out.bytes,
                out.coalesced,
                out.prefix_reads,
                out.frontier,
                decode,
                0u64,
            )
        }
    };

    // One shared evaluator for both sides of the boundary: chained
    // plans (sort/limit/top-k, grouped multi-aggregates) execute here
    // exactly as they do in the storage servers, so partials are
    // bit-identical and — like pushdown — already sorted/truncated. A
    // delete vector enters as a pre-mask, the same way the extension
    // merges it server-side.
    let (out, work) = match &dv_live {
        Some(live) => exec_kernel::run_pipeline_premasked(
            &batch,
            spec,
            None,
            &sub.sorted_cols,
            exec_kernel::ExecTier::Scalar,
            Some(live.as_slice()),
        )?,
        None => run_pipeline(&batch, spec, None, &sub.sorted_cols)?,
    };
    // Client pays decode + per-row scan CPU for what it fetched (a
    // shared hit pays only the per-row part), plus the movable kernel
    // work (aggregation, per-object sort) it just performed instead of
    // the storage server — all priced by the cluster's single-sourced
    // execution profile.
    let cpu = cpu_fetch + prof.client_cpu(0, batch.nrows() as u64) + work.movable_seconds(prof);
    let finish = worker_cpu.submit(start, cpu);
    let output = match out {
        ExecOut::Rows(b) => SubOutput::Rows(b),
        ExecOut::Aggs(states) => SubOutput::Aggs(states),
        ExecOut::Groups(gs) => SubOutput::Groups(gs),
    };
    Ok(SubResult {
        output,
        bytes_moved: bytes + dv_bytes,
        reads_coalesced: coalesced,
        // The kernel pre-sorts the partial whenever the spec carries
        // sort keys, on either side of the boundary.
        presorted: !spec.sort.is_empty(),
        prefix_reads,
        rows_short_circuited: work.rows_short_circuited,
        compiled_chunks: 0,
        compiled_rows: 0,
        index_probes: 0,
        index_postings: 0,
        shared_scan_hits,
        finish,
    })
}

/// Write-path worker: wrap a row group in the object format, compute its
/// per-column zone map, and store both (data + xattr). Returns (object
/// bytes written, virtual finish, column stats for the dataset metadata).
pub fn write_row_group(
    cluster: &Arc<Cluster>,
    object: &str,
    group: &Batch,
    layout: Layout,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<(u64, f64, Vec<ColumnStats>)> {
    let bytes = encode_batch(group, layout);
    let zone = ZoneMap::from_batch(group);
    // Serialization + stats cost on the worker.
    let depart = worker_cpu.submit(at, cluster.cost().exec.decode_time(bytes.len() as u64));
    let t = cluster.write_object(depart, object, &bytes)?;
    // Stamp the zone map so storage-side handlers can short-circuit
    // without reading object data.
    let tx = cluster.setxattr(t.finish, object, ZONE_MAP_XATTR, &zone.encode())?;
    Ok((bytes.len() as u64, tx.finish, zone.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::dataset::layout::decode_batch;
    use crate::dataset::table::{gen, Column};
    use crate::skyhook::extension::register_skyhook_class;
    use crate::skyhook::plan::server_pipeline;
    use crate::skyhook::query::{AggFunc, CmpOp, Predicate, Query};
    use crate::store::ClassRegistry;

    /// Build the plan's stage block for `q` and run one sub-query with
    /// it — what `Driver::execute_plan` does once per plan.
    fn exec(c: &Arc<Cluster>, q: &Query, sub: &SubQuery, cpu: &Timeline) -> Result<SubResult> {
        execute_subquery(c, &server_pipeline(q, sub.zone_maps), sub, 0.0, cpu, None)
    }

    #[test]
    fn shared_scan_cache_serves_identical_batch_without_refetch() {
        let c = cluster();
        seed_object(&c, "t9", 300);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 40.0))
            .select(&["ts", "val"]);
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "t9".into(),
            mode: ExecMode::ClientSide,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let spec = server_pipeline(&q, sub.zone_maps);
        let cache = ScanCache::new();
        // Leader: populates the slot, meters a real fetch.
        let r1 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r1.shared_scan_hits, 0);
        assert!(r1.bytes_moved > 0);
        // Follower (the slot is Ready): identical rows, zero bytes moved.
        let r2 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r2.shared_scan_hits, 1);
        assert_eq!(r2.bytes_moved, 0);
        assert_eq!(cache.hits(), 1);
        let (SubOutput::Rows(a), SubOutput::Rows(b)) = (r1.output, r2.output) else {
            panic!("expected rows")
        };
        assert_eq!(a, b, "shared hit must be bit-identical to the fetch");
        // Cleared cache: back to a real fetch.
        cache.clear();
        let r3 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r3.shared_scan_hits, 0);
        assert!(r3.bytes_moved > 0);
    }

    #[test]
    fn shared_scan_cache_drops_entries_when_cluster_mutates_underneath() {
        // Regression: mutations that bypass the Driver (direct
        // Cluster::write_object, delete-vector stamps, appends,
        // compaction) used to leave stale decoded batches servable to
        // followers, because only Driver-level writes called clear().
        // The mutation-epoch check must flush the cache by itself.
        let c = cluster();
        seed_object(&c, "t9e", 300);
        let q = Query::scan("ds").select(&["ts", "val"]);
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "t9e".into(),
            mode: ExecMode::ClientSide,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let spec = server_pipeline(&q, sub.zone_maps);
        let cache = ScanCache::new();
        let r1 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        let SubOutput::Rows(before) = r1.output else {
            panic!("expected rows")
        };
        assert_eq!(before.nrows(), 300);
        // Overwrite the object directly on the cluster — no Driver, no
        // cache.clear(). Only the epoch check can save the next reader.
        let replacement = gen::sensor_table(120, 7);
        c.write_object(0.0, "t9e", &encode_batch(&replacement, Layout::Col))
            .unwrap();
        let r2 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(
            r2.shared_scan_hits, 0,
            "a mutation must invalidate the slot, not serve it"
        );
        assert!(r2.bytes_moved > 0, "the follower must re-fetch fresh bytes");
        let SubOutput::Rows(after) = r2.output else {
            panic!("expected rows")
        };
        assert_eq!(after.nrows(), 120, "stale pre-mutation batch was served");
        // Steady state (no further mutations): hits work again.
        let r3 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r3.shared_scan_hits, 0, "leader after flush");
        let r4 = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r4.shared_scan_hits, 1, "unchanged epoch keeps serving hits");
    }

    #[test]
    fn client_side_delete_vector_masks_rows_and_voids_prefix_reads() {
        // A SubQuery stamped with tombstones>0 must fetch dv1/ and
        // pre-mask the kernel — and must NOT take the bounded-prefix
        // shortcut, because the first k stored rows are no longer the
        // first k live rows.
        use crate::skyhook::extension::{encode_dv, DV_KEY};
        let c = cluster();
        let b = gen::sensor_table(10_000, 42).sort_by_column("val").unwrap();
        c.write_object(0.0, "tdv", &encode_batch(&b, Layout::Col))
            .unwrap();
        // Tombstone the first 5 rows of the val-ascending order via the
        // storage-side handler (stamps dv1/ in the object's omap).
        let mut deleted = vec![false; 10_000];
        for d in deleted.iter_mut().take(5) {
            *d = true;
        }
        let mut arg = Vec::new();
        arg.extend_from_slice(&5u32.to_le_bytes());
        for row in 0u32..5 {
            arg.extend_from_slice(&row.to_le_bytes());
        }
        let popcount = c
            .call(0.0, "tdv", "skyhook", "delete_rows", &arg)
            .unwrap()
            .value;
        assert_eq!(u64::from_le_bytes(popcount.try_into().unwrap()), 5);
        let raw_dv = c.call(0.0, "tdv", "skyhook", "read_dv", &[]).unwrap().value;
        assert_eq!(raw_dv, encode_dv(&deleted));
        assert_eq!(DV_KEY, b"dv1/bitmap");

        let q = Query::scan("ds").select(&["ts"]).top_k("val", false, 8);
        let cpu = Timeline::new();
        let mk = |tombstones: u64| SubQuery {
            object: "tdv".into(),
            mode: ExecMode::ClientSide,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec!["val".into()],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones,
        };
        let spec = server_pipeline(&q, true);
        let masked = execute_subquery(&c, &spec, &mk(5), 0.0, &cpu, None).unwrap();
        assert_eq!(masked.prefix_reads, 0, "dv must void the prefix shortcut");
        let SubOutput::Rows(rows) = masked.output else {
            panic!("expected rows")
        };
        assert_eq!(rows.nrows(), 8);
        // The bottom-8 sort keys must be those of rows 5..13 of the
        // val-sorted table — the first five live rows — and none of the
        // five deleted rows may surface.
        let Column::F32(got_val) = rows.col("val").unwrap() else {
            panic!("expected f32 val")
        };
        let Column::F32(all_val) = b.col("val").unwrap() else {
            panic!("expected f32 val")
        };
        assert_eq!(&got_val[..], &all_val[5..13]);
        let Column::I64(got_ts) = rows.col("ts").unwrap() else {
            panic!("expected i64 ts")
        };
        let Column::I64(all_ts) = b.col("ts").unwrap() else {
            panic!("expected i64 ts")
        };
        assert!(
            all_ts[..5].iter().all(|t| !got_ts.contains(t)),
            "a tombstoned row surfaced client-side"
        );
        // Pushdown over the same object agrees bit-for-bit (the
        // extension consults dv1/ unconditionally).
        let push = execute_subquery(
            &c,
            &spec,
            &SubQuery {
                mode: ExecMode::Pushdown,
                ..mk(5)
            },
            0.0,
            &cpu,
            None,
        )
        .unwrap();
        let SubOutput::Rows(prows) = push.output else {
            panic!("expected rows")
        };
        let Column::I64(push_ts) = prows.col("ts").unwrap() else {
            panic!("expected i64 ts")
        };
        assert_eq!(&push_ts[..], &got_ts[..]);
    }

    #[test]
    fn shared_scan_failed_leader_falls_back_to_direct_fetch() {
        let c = cluster();
        let q = Query::scan("ds").aggregate(AggFunc::Count, "val");
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "missing".into(),
            mode: ExecMode::ClientSide,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let spec = server_pipeline(&q, sub.zone_maps);
        let cache = ScanCache::new();
        // Leader errors (object absent): the guard marks the slot Failed.
        assert!(execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).is_err());
        // Now the object exists; the follower must not trust the Failed
        // slot — it fetches directly and succeeds.
        seed_object(&c, "missing", 100);
        let r = execute_subquery(&c, &spec, &sub, 0.0, &cpu, Some(&cache)).unwrap();
        assert_eq!(r.shared_scan_hits, 0);
        assert!(r.bytes_moved > 0);
        assert_eq!(cache.hits(), 0);
    }

    fn cluster() -> Arc<Cluster> {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            reg,
        )
    }

    fn seed_object(c: &Arc<Cluster>, name: &str, rows: usize) -> Batch {
        let b = gen::sensor_table(rows, 42);
        c.write_object(0.0, name, &encode_batch(&b, Layout::Col))
            .unwrap();
        b
    }

    #[test]
    fn pushdown_and_client_agree_on_rows() {
        let c = cluster();
        let b = seed_object(&c, "t0", 300);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 55.0))
            .select(&["ts", "val"]);
        let cpu = Timeline::new();
        let sub_p = SubQuery {
            object: "t0".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let sub_c = SubQuery {
            mode: ExecMode::ClientSide,
            ..sub_p.clone()
        };
        let rp = exec(&c, &q, &sub_p, &cpu).unwrap();
        let rc = exec(&c, &q, &sub_c, &cpu).unwrap();
        let (SubOutput::Rows(bp), SubOutput::Rows(bc)) = (rp.output, rc.output) else {
            panic!("expected rows")
        };
        assert_eq!(bp, bc);
        // Verify against direct computation.
        let mask = q.predicate.eval(&b).unwrap();
        assert_eq!(bp.nrows(), mask.iter().filter(|&&m| m).count());
        // Selective pushdown moves fewer bytes.
        assert!(
            rp.bytes_moved < rc.bytes_moved,
            "pushdown {} vs client {}",
            rp.bytes_moved,
            rc.bytes_moved
        );
    }

    #[test]
    fn pushdown_and_client_agree_on_aggregates() {
        let c = cluster();
        let b = seed_object(&c, "t1", 500);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("sensor", CmpOp::Lt, 10.0))
            .aggregate(AggFunc::Sum, "val")
            .aggregate(AggFunc::Count, "val");
        let cpu = Timeline::new();
        let mk = |mode| SubQuery {
            object: "t1".into(),
            mode,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let rp = exec(&c, &q, &mk(ExecMode::Pushdown), &cpu).unwrap();
        let rc = exec(&c, &q, &mk(ExecMode::ClientSide), &cpu).unwrap();
        let (SubOutput::Aggs(sp), SubOutput::Aggs(sc)) = (rp.output, rc.output) else {
            panic!("expected aggs")
        };
        assert_eq!(sp[0].count, sc[0].count);
        assert!((sp[0].sum - sc[0].sum).abs() < 1e-3);
        // Direct check.
        let mask = q.predicate.eval(&b).unwrap();
        let mut direct = AggState::new(false);
        direct.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert_eq!(sp[0].count, direct.count);
        // Aggregate pushdown moves far fewer bytes than the object.
        assert!(rp.bytes_moved * 10 < rc.bytes_moved);
    }

    #[test]
    fn group_agg_modes_agree() {
        let c = cluster();
        seed_object(&c, "t2", 400);
        let q = Query::scan("ds")
            .group("sensor")
            .aggregate(AggFunc::Mean, "val");
        let cpu = Timeline::new();
        let mk = |mode| SubQuery {
            object: "t2".into(),
            mode,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let rp = exec(&c, &q, &mk(ExecMode::Pushdown), &cpu).unwrap();
        let rc = exec(&c, &q, &mk(ExecMode::ClientSide), &cpu).unwrap();
        let (SubOutput::Groups(gp), SubOutput::Groups(gc)) = (rp.output, rc.output) else {
            panic!("expected groups")
        };
        assert_eq!(gp.len(), gc.len());
        for ((ka, sa), (kb, sb)) in gp.iter().zip(&gc) {
            assert_eq!(ka, kb);
            assert_eq!(sa[0].count, sb[0].count);
            assert!((sa[0].sum - sb[0].sum).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_key_multi_agg_groups_agree() {
        let c = cluster();
        let b = seed_object(&c, "t2b", 600);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 30.0))
            .group("sensor")
            .group("flag")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Sum, "val");
        let cpu = Timeline::new();
        let mk = |mode| SubQuery {
            object: "t2b".into(),
            mode,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let rp = exec(&c, &q, &mk(ExecMode::Pushdown), &cpu).unwrap();
        let rc = exec(&c, &q, &mk(ExecMode::ClientSide), &cpu).unwrap();
        let (SubOutput::Groups(gp), SubOutput::Groups(gc)) = (rp.output, rc.output) else {
            panic!("expected groups")
        };
        assert_eq!(gp, gc);
        // 2-wide keys, counts match direct evaluation in total.
        let mask = q.predicate.eval(&b).unwrap();
        let want = mask.iter().filter(|&&m| m).count() as u64;
        let total: u64 = gp.iter().map(|(_, s)| s[0].count).sum();
        assert_eq!(total, want);
        assert!(gp.iter().all(|(k, s)| k.len() == 2 && s.len() == 2));
    }

    #[test]
    fn topk_pushdown_truncates_per_object() {
        let c = cluster();
        let b = seed_object(&c, "t5", 2000);
        let q = Query::scan("ds")
            .select(&["ts"])
            .top_k("val", true, 10);
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "t5".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let r = exec(&c, &q, &sub, &cpu).unwrap();
        let SubOutput::Rows(rows) = r.output else {
            panic!("expected rows");
        };
        // The partial carries the sort key alongside the projection and
        // holds only k rows.
        assert_eq!(rows.nrows(), 10);
        assert_eq!(rows.ncols(), 2);
        let Column::F32(v) = rows.col("val").unwrap() else {
            unreachable!()
        };
        let Column::F32(all) = b.col("val").unwrap() else {
            unreachable!()
        };
        let mut best: Vec<f32> = all.clone();
        best.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(v[0], best[0]);
        // Client-side runs the identical pipeline through the shared
        // kernel: same truncated, pre-sorted partial, bit for bit.
        let sub_c = SubQuery {
            mode: ExecMode::ClientSide,
            ..sub
        };
        let rc = exec(&c, &q, &sub_c, &cpu).unwrap();
        assert!(r.presorted && rc.presorted);
        let SubOutput::Rows(rows_c) = rc.output else {
            panic!("expected rows");
        };
        assert_eq!(rows_c, rows);
        // Bytes asymmetry survives: the client still fetched the
        // columns, only pushdown ships just the k-row partial.
        assert!(r.bytes_moved * 10 < rc.bytes_moved);
    }

    #[test]
    fn holistic_pushdown_ships_values() {
        let c = cluster();
        seed_object(&c, "t3", 200);
        let q = Query::scan("ds").aggregate(AggFunc::Median, "val");
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "t3".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: true,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let r = exec(&c, &q, &sub, &cpu).unwrap();
        let SubOutput::Aggs(states) = r.output else {
            panic!()
        };
        assert_eq!(states[0].values.as_ref().unwrap().len(), 200);
        // Values dominate the wire bytes.
        assert!(r.bytes_moved > 200 * 8);
    }

    #[test]
    fn write_row_group_roundtrip() {
        let c = cluster();
        let b = gen::sensor_table(100, 3);
        let cpu = Timeline::new();
        let (bytes, finish, stats) =
            write_row_group(&c, "w0", &b, Layout::Row, 0.0, &cpu).unwrap();
        assert!(bytes > 0);
        assert!(finish > 0.0);
        assert_eq!(stats.len(), b.ncols());
        // ts is 0..100, so its zone map is exact.
        assert_eq!(stats[0].range(), Some((0.0, 99.0)));
        assert_eq!(stats[0].nan_count, 0);
        let raw = c.read_object(0.0, "w0").unwrap().value;
        let (dec, layout) = decode_batch(&raw).unwrap();
        assert_eq!(layout, Layout::Row);
        assert_eq!(dec, b);
        // The zone map xattr was stamped alongside the data.
        let x = c.getxattr(0.0, "w0", ZONE_MAP_XATTR).unwrap().value.unwrap();
        let zm = ZoneMap::decode(&x).unwrap();
        assert_eq!(zm.rows, 100);
        assert_eq!(zm.stats, stats);
    }

    #[test]
    fn client_side_projected_read_fetches_less() {
        // Large enough that the object exceeds the 64 KiB header prefix —
        // otherwise the prefix read covers everything and there is no
        // ranged-read advantage to observe.
        let c = cluster();
        seed_object(&c, "t4", 10_000);
        let cpu = Timeline::new();
        let mk = |q: Query| {
            let sub = SubQuery {
                object: "t4".into(),
                mode: ExecMode::ClientSide,
                layout: Layout::Col,
                keep_values: false,
                zone_maps: true,
                sorted_cols: vec![],
                header_prefix: layout::HEADER_PREFIX,
                index_col: None,
                tombstones: 0,
            };
            exec(&c, &q, &sub, &cpu).unwrap()
        };
        // Full scan moves the whole object.
        let full = mk(Query::scan("ds"));
        // A projected scan over a Col object moves only ts+val columns
        // (plus the header prefix) — strictly less than the full object.
        let narrow = mk(Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .select(&["ts"]));
        assert!(
            narrow.bytes_moved < full.bytes_moved,
            "narrow {} vs full {}",
            narrow.bytes_moved,
            full.bytes_moved
        );
        // Adjacent needed columns (ts, sensor, val are contiguous in the
        // schema) coalesce into fewer ranged reads.
        let adjacent = mk(Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .select(&["ts", "sensor"]));
        assert!(
            adjacent.reads_coalesced > 0,
            "adjacent column extents should coalesce"
        );
        // And both agree with direct evaluation row-count-wise.
        let (SubOutput::Rows(f), SubOutput::Rows(n)) = (full.output, narrow.output) else {
            panic!("expected rows");
        };
        assert_eq!(f.nrows(), 10_000);
        assert_eq!(n.ncols(), 1);
        assert!(n.nrows() > 0 && n.nrows() < 10_000);
    }

    #[test]
    fn client_side_prefix_fetch_bounds_the_read() {
        // A clustered-style object (rows sorted by val) large enough to
        // outgrow the header prefix: with the planner-stamped marker the
        // ascending top-k fetches only a k-row prefix of the needed
        // columns; without it the same sub-query fetches whole extents.
        // Results are bit-identical either way.
        let c = cluster();
        let b = gen::sensor_table(10_000, 42).sort_by_column("val").unwrap();
        c.write_object(0.0, "ts0", &encode_batch(&b, Layout::Col))
            .unwrap();
        let q = Query::scan("ds").select(&["ts"]).top_k("val", false, 8);
        let cpu = Timeline::new();
        let mk = |sorted_cols: Vec<String>| SubQuery {
            object: "ts0".into(),
            mode: ExecMode::ClientSide,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols,
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        let bounded = exec(&c, &q, &mk(vec!["val".into()]), &cpu).unwrap();
        let full = exec(&c, &q, &mk(vec![]), &cpu).unwrap();
        assert_eq!(bounded.prefix_reads, 1);
        assert_eq!(full.prefix_reads, 0);
        assert!(
            bounded.bytes_moved < full.bytes_moved,
            "prefix {} vs full {}",
            bounded.bytes_moved,
            full.bytes_moved
        );
        let (SubOutput::Rows(a), SubOutput::Rows(c2)) = (bounded.output, full.output) else {
            panic!("expected rows");
        };
        assert_eq!(a, c2);
        assert_eq!(a.nrows(), 8);
    }

    #[test]
    fn missing_object_errors() {
        let c = cluster();
        let q = Query::scan("ds");
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "ghost".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
            sorted_cols: vec![],
            header_prefix: layout::HEADER_PREFIX,
            index_col: None,
            tombstones: 0,
        };
        assert!(exec(&c, &q, &sub, &cpu).is_err());
    }
}
