//! Skyhook-Worker (§4.2): executes one sub-query — either by invoking the
//! Skyhook-Extension on the object's OSD (pushdown) or by fetching the
//! object and computing client-side — and, on the write path, partitions
//! data, adds the format wrapper, computes per-column zone maps, and
//! writes objects (data + `skyhook.zonemap` xattr).
//!
//! Client-side execution fetches only the columns the query touches when
//! the object is columnar (projected partial reads via
//! [`layout::read_projected`] over ranged cluster reads) — the whole
//! object crosses the network only for row-layout objects or full scans.

use super::extension::{
    decode_agg_out, decode_group_out, encode_agg_arg, encode_group_arg, encode_scan_arg,
};
use super::plan::{ExecMode, SubQuery};
use super::query::{AggState, Query};
use crate::dataset::layout::{self, decode_batch, encode_batch, Layout};
use crate::dataset::metadata::{ColumnStats, ZoneMap, ZONE_MAP_XATTR};
use crate::dataset::table::Batch;
use crate::error::Result;
use crate::simnet::Timeline;
use crate::store::Cluster;
use std::sync::Arc;

/// Client-side CPU rate for decoding + predicate evaluation (bytes/s and
/// rows/s respectively) — charged to the worker's timeline so client-side
/// execution pays the CPU the paper wants to offload.
const CLIENT_DECODE_BW: f64 = 2.0e9;
const CLIENT_ROW_COST: f64 = 12e-9;

/// What one sub-query produced.
#[derive(Debug)]
pub enum SubOutput {
    Rows(Batch),
    Aggs(Vec<AggState>),
    Groups(Vec<(i64, AggState)>),
}

/// Result of one sub-query execution.
#[derive(Debug)]
pub struct SubResult {
    pub output: SubOutput,
    /// Bytes that crossed the client↔storage network for this sub-query.
    pub bytes_moved: u64,
    /// Virtual completion time.
    pub finish: f64,
}

/// Execute one sub-query against the cluster, charging worker-side work
/// to `worker_cpu`.
pub fn execute_subquery(
    cluster: &Arc<Cluster>,
    query: &Query,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<SubResult> {
    match sub.mode {
        ExecMode::Pushdown => execute_pushdown(cluster, query, sub, at, worker_cpu),
        ExecMode::ClientSide => execute_client_side(cluster, query, sub, at, worker_cpu),
    }
}

fn execute_pushdown(
    cluster: &Arc<Cluster>,
    query: &Query,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<SubResult> {
    if let Some(group_col) = &query.group_by {
        let input = encode_group_arg(
            &query.predicate,
            group_col,
            &query.aggregates[0].col,
            sub.zone_maps,
        );
        let t = cluster.call(at, &sub.object, "skyhook", "group_agg", &input)?;
        let bytes = (input.len() + t.value.len()) as u64;
        let groups = decode_group_out(&t.value)?;
        let finish = worker_cpu.submit(t.finish, t.value.len() as f64 / CLIENT_DECODE_BW);
        return Ok(SubResult {
            output: SubOutput::Groups(groups),
            bytes_moved: bytes,
            finish,
        });
    }
    if query.is_aggregate() {
        let input =
            encode_agg_arg(&query.predicate, &query.aggregates, sub.keep_values, sub.zone_maps);
        let t = cluster.call(at, &sub.object, "skyhook", "agg", &input)?;
        let bytes = (input.len() + t.value.len()) as u64;
        let states = decode_agg_out(&t.value)?;
        let finish = worker_cpu.submit(t.finish, t.value.len() as f64 / CLIENT_DECODE_BW);
        return Ok(SubResult {
            output: SubOutput::Aggs(states),
            bytes_moved: bytes,
            finish,
        });
    }
    let input = encode_scan_arg(&query.predicate, query.projection.as_deref(), sub.zone_maps);
    let t = cluster.call(at, &sub.object, "skyhook", "scan", &input)?;
    let bytes = (input.len() + t.value.len()) as u64;
    let (batch, _) = decode_batch(&t.value)?;
    let finish = worker_cpu.submit(t.finish, t.value.len() as f64 / CLIENT_DECODE_BW);
    Ok(SubResult {
        output: SubOutput::Rows(batch),
        bytes_moved: bytes,
        finish,
    })
}

/// [`layout::RangeSource`] over cluster reads of one object: tracks the
/// virtual-time frontier across sequential ranged reads and meters the
/// bytes that actually crossed the network.
struct ClusterRange<'a> {
    cluster: &'a Cluster,
    object: &'a str,
    at: f64,
    fetched: u64,
}

impl layout::RangeSource for ClusterRange<'_> {
    fn size(&mut self) -> Result<usize> {
        let t = self.cluster.stat_object(self.at, self.object)?;
        self.at = t.finish;
        Ok(t.value.size as usize)
    }
    fn read_range(&mut self, offset: usize, len: usize) -> Result<Vec<u8>> {
        let t = self
            .cluster
            .read_object_range(self.at, self.object, offset, len)?;
        self.at = t.finish;
        self.fetched += t.value.len() as u64;
        Ok(t.value)
    }
    fn read_all(&mut self) -> Result<Vec<u8>> {
        let t = self.cluster.read_object(self.at, self.object)?;
        self.at = t.finish;
        self.fetched += t.value.len() as u64;
        Ok(t.value)
    }
}

/// Columns a client-side execution must fetch; `None` = all (a row query
/// without projection needs every column, so one full read wins).
fn client_needed_columns(query: &Query) -> Option<Vec<String>> {
    if !query.is_aggregate() && query.projection.is_none() {
        return None;
    }
    // Neither remaining shape expands to "all columns", so the full-list
    // argument is never consulted.
    Some(query.needed_columns(&[]))
}

fn execute_client_side(
    cluster: &Arc<Cluster>,
    query: &Query,
    sub: &SubQuery,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<SubResult> {
    // Fetch only the columns the query touches (ranged reads on Col
    // objects) — the filter/aggregate CPU still runs on the client,
    // which is what makes this the baseline. Row objects must be read
    // whole anyway, so skip the stat/prefix probing and issue the one
    // full read directly (the pre-zone-map cost profile).
    let needed = client_needed_columns(query);
    let mut src = ClusterRange {
        cluster: cluster.as_ref(),
        object: &sub.object,
        at,
        fetched: 0,
    };
    let batch = if sub.layout == Layout::Col {
        layout::read_projected(&mut src, needed.as_deref())?
    } else {
        let full = layout::read_projected(&mut src, None)?;
        match &needed {
            Some(cols) => {
                let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                full.project(&refs)?
            }
            None => full,
        }
    };
    let bytes = src.fetched;
    // Client pays decode + scan CPU for what it fetched.
    let cpu = bytes as f64 / CLIENT_DECODE_BW + batch.nrows() as f64 * CLIENT_ROW_COST;
    let finish = worker_cpu.submit(src.at, cpu);
    let mut mask = Vec::new();
    query.predicate.eval_into(&batch, &mut mask)?;

    if let Some(group_col) = &query.group_by {
        let keys = match batch.col(group_col)? {
            crate::dataset::table::Column::I64(v) => v,
            _ => return Err(crate::error::Error::Query("group_by needs i64".into())),
        };
        let vals = batch.col(&query.aggregates[0].col)?;
        let mut groups: std::collections::BTreeMap<i64, AggState> = Default::default();
        for (i, &keep) in mask.iter().enumerate() {
            if keep {
                groups
                    .entry(keys[i])
                    .or_insert_with(|| AggState::new(false))
                    .update(vals.get_f64(i)?);
            }
        }
        return Ok(SubResult {
            output: SubOutput::Groups(groups.into_iter().collect()),
            bytes_moved: bytes,
            finish,
        });
    }
    if query.is_aggregate() {
        let mut states = Vec::with_capacity(query.aggregates.len());
        for agg in &query.aggregates {
            let mut st = AggState::new(!agg.func.is_algebraic());
            st.update_column(batch.col(&agg.col)?, &mask)?;
            states.push(st);
        }
        return Ok(SubResult {
            output: SubOutput::Aggs(states),
            bytes_moved: bytes,
            finish,
        });
    }
    let filtered = batch.filter(&mask)?;
    let rows = match &query.projection {
        Some(cols) => {
            let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
            filtered.project(&refs)?
        }
        None => filtered,
    };
    Ok(SubResult {
        output: SubOutput::Rows(rows),
        bytes_moved: bytes,
        finish,
    })
}

/// Write-path worker: wrap a row group in the object format, compute its
/// per-column zone map, and store both (data + xattr). Returns (object
/// bytes written, virtual finish, column stats for the dataset metadata).
pub fn write_row_group(
    cluster: &Arc<Cluster>,
    object: &str,
    group: &Batch,
    layout: Layout,
    at: f64,
    worker_cpu: &Timeline,
) -> Result<(u64, f64, Vec<ColumnStats>)> {
    let bytes = encode_batch(group, layout);
    let zone = ZoneMap::from_batch(group);
    // Serialization + stats cost on the worker.
    let depart = worker_cpu.submit(at, bytes.len() as f64 / CLIENT_DECODE_BW);
    let t = cluster.write_object(depart, object, &bytes)?;
    // Stamp the zone map so storage-side handlers can short-circuit
    // without reading object data.
    let tx = cluster.setxattr(t.finish, object, ZONE_MAP_XATTR, &zone.encode())?;
    Ok((bytes.len() as u64, tx.finish, zone.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::dataset::table::gen;
    use crate::skyhook::extension::register_skyhook_class;
    use crate::skyhook::query::{AggFunc, CmpOp, Predicate};
    use crate::store::ClassRegistry;

    fn cluster() -> Arc<Cluster> {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        Cluster::new(
            &ClusterConfig {
                osds: 4,
                replicas: 1,
                ..Default::default()
            },
            reg,
        )
    }

    fn seed_object(c: &Arc<Cluster>, name: &str, rows: usize) -> Batch {
        let b = gen::sensor_table(rows, 42);
        c.write_object(0.0, name, &encode_batch(&b, Layout::Col))
            .unwrap();
        b
    }

    #[test]
    fn pushdown_and_client_agree_on_rows() {
        let c = cluster();
        let b = seed_object(&c, "t0", 300);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 55.0))
            .select(&["ts", "val"]);
        let cpu = Timeline::new();
        let sub_p = SubQuery {
            object: "t0".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
        };
        let sub_c = SubQuery {
            mode: ExecMode::ClientSide,
            ..sub_p.clone()
        };
        let rp = execute_subquery(&c, &q, &sub_p, 0.0, &cpu).unwrap();
        let rc = execute_subquery(&c, &q, &sub_c, 0.0, &cpu).unwrap();
        let (SubOutput::Rows(bp), SubOutput::Rows(bc)) = (rp.output, rc.output) else {
            panic!("expected rows")
        };
        assert_eq!(bp, bc);
        // Verify against direct computation.
        let mask = q.predicate.eval(&b).unwrap();
        assert_eq!(bp.nrows(), mask.iter().filter(|&&m| m).count());
        // Selective pushdown moves fewer bytes.
        assert!(
            rp.bytes_moved < rc.bytes_moved,
            "pushdown {} vs client {}",
            rp.bytes_moved,
            rc.bytes_moved
        );
    }

    #[test]
    fn pushdown_and_client_agree_on_aggregates() {
        let c = cluster();
        let b = seed_object(&c, "t1", 500);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("sensor", CmpOp::Lt, 10.0))
            .aggregate(AggFunc::Sum, "val")
            .aggregate(AggFunc::Count, "val");
        let cpu = Timeline::new();
        let mk = |mode| SubQuery {
            object: "t1".into(),
            mode,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
        };
        let rp = execute_subquery(&c, &q, &mk(ExecMode::Pushdown), 0.0, &cpu).unwrap();
        let rc = execute_subquery(&c, &q, &mk(ExecMode::ClientSide), 0.0, &cpu).unwrap();
        let (SubOutput::Aggs(sp), SubOutput::Aggs(sc)) = (rp.output, rc.output) else {
            panic!("expected aggs")
        };
        assert_eq!(sp[0].count, sc[0].count);
        assert!((sp[0].sum - sc[0].sum).abs() < 1e-3);
        // Direct check.
        let mask = q.predicate.eval(&b).unwrap();
        let mut direct = AggState::new(false);
        direct.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert_eq!(sp[0].count, direct.count);
        // Aggregate pushdown moves far fewer bytes than the object.
        assert!(rp.bytes_moved * 10 < rc.bytes_moved);
    }

    #[test]
    fn group_agg_modes_agree() {
        let c = cluster();
        seed_object(&c, "t2", 400);
        let q = Query::scan("ds")
            .group("sensor")
            .aggregate(AggFunc::Mean, "val");
        let cpu = Timeline::new();
        let mk = |mode| SubQuery {
            object: "t2".into(),
            mode,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
        };
        let rp = execute_subquery(&c, &q, &mk(ExecMode::Pushdown), 0.0, &cpu).unwrap();
        let rc = execute_subquery(&c, &q, &mk(ExecMode::ClientSide), 0.0, &cpu).unwrap();
        let (SubOutput::Groups(gp), SubOutput::Groups(gc)) = (rp.output, rc.output) else {
            panic!("expected groups")
        };
        assert_eq!(gp.len(), gc.len());
        for ((ka, sa), (kb, sb)) in gp.iter().zip(&gc) {
            assert_eq!(ka, kb);
            assert_eq!(sa.count, sb.count);
            assert!((sa.sum - sb.sum).abs() < 1e-6);
        }
    }

    #[test]
    fn holistic_pushdown_ships_values() {
        let c = cluster();
        seed_object(&c, "t3", 200);
        let q = Query::scan("ds").aggregate(AggFunc::Median, "val");
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "t3".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: true,
            zone_maps: true,
        };
        let r = execute_subquery(&c, &q, &sub, 0.0, &cpu).unwrap();
        let SubOutput::Aggs(states) = r.output else {
            panic!()
        };
        assert_eq!(states[0].values.as_ref().unwrap().len(), 200);
        // Values dominate the wire bytes.
        assert!(r.bytes_moved > 200 * 8);
    }

    #[test]
    fn write_row_group_roundtrip() {
        let c = cluster();
        let b = gen::sensor_table(100, 3);
        let cpu = Timeline::new();
        let (bytes, finish, stats) =
            write_row_group(&c, "w0", &b, Layout::Row, 0.0, &cpu).unwrap();
        assert!(bytes > 0);
        assert!(finish > 0.0);
        assert_eq!(stats.len(), b.ncols());
        // ts is 0..100, so its zone map is exact.
        assert_eq!(stats[0].range(), Some((0.0, 99.0)));
        let raw = c.read_object(0.0, "w0").unwrap().value;
        let (dec, layout) = decode_batch(&raw).unwrap();
        assert_eq!(layout, Layout::Row);
        assert_eq!(dec, b);
        // The zone map xattr was stamped alongside the data.
        let x = c.getxattr(0.0, "w0", ZONE_MAP_XATTR).unwrap().value.unwrap();
        let zm = ZoneMap::decode(&x).unwrap();
        assert_eq!(zm.rows, 100);
        assert_eq!(zm.stats, stats);
    }

    #[test]
    fn client_side_projected_read_fetches_less() {
        // Large enough that the object exceeds the 64 KiB header prefix —
        // otherwise the prefix read covers everything and there is no
        // ranged-read advantage to observe.
        let c = cluster();
        seed_object(&c, "t4", 10_000);
        let cpu = Timeline::new();
        let mk = |q: Query| {
            let sub = SubQuery {
                object: "t4".into(),
                mode: ExecMode::ClientSide,
                layout: Layout::Col,
                keep_values: false,
                zone_maps: true,
            };
            execute_subquery(&c, &q, &sub, 0.0, &cpu).unwrap()
        };
        // Full scan moves the whole object.
        let full = mk(Query::scan("ds"));
        // A projected scan over a Col object moves only ts+val columns
        // (plus the header prefix) — strictly less than the full object.
        let narrow = mk(Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .select(&["ts"]));
        assert!(
            narrow.bytes_moved < full.bytes_moved,
            "narrow {} vs full {}",
            narrow.bytes_moved,
            full.bytes_moved
        );
        // And both agree with direct evaluation row-count-wise.
        let (SubOutput::Rows(f), SubOutput::Rows(n)) = (full.output, narrow.output) else {
            panic!("expected rows");
        };
        assert_eq!(f.nrows(), 10_000);
        assert_eq!(n.ncols(), 1);
        assert!(n.nrows() > 0 && n.nrows() < 10_000);
    }

    #[test]
    fn missing_object_errors() {
        let c = cluster();
        let q = Query::scan("ds");
        let cpu = Timeline::new();
        let sub = SubQuery {
            object: "ghost".into(),
            mode: ExecMode::Pushdown,
            layout: Layout::Col,
            keep_values: false,
            zone_maps: true,
        };
        assert!(execute_subquery(&c, &q, &sub, 0.0, &cpu).is_err());
    }
}
