//! The logical-plan IR: dataset access operations as a composable
//! operator tree (§3.2 "Composability of Access Operations").
//!
//! A [`LogicalPlan`] is a chain of operators over a `Scan` leaf —
//! `Filter`, `Project`, `Aggregate` (any number of aggregate expressions
//! over any number of i64 group keys; a `Filter` *above* an `Aggregate`
//! is the HAVING operator over its finalized group rows), `Sort`,
//! `Limit`, and the fused `TopK`. The fluent [`Query`] builder
//! constructs the same shape directly; [`LogicalPlan::to_query`]
//! validates an arbitrary tree into that flat form (rejecting shapes
//! the engine cannot run, e.g. a projection over aggregate output), and
//! [`Query::logical`] lifts a query back into the tree.
//!
//! The planner (`skyhook::plan`) compiles the IR into a staged
//! `QueryPlan`: the operators up to and including the per-object
//! partials ([`PipelineSpec`]) are encoded once onto the wire and
//! executed server-side in a single pass by the `skyhook.exec` object
//! class; the merge-side operators (partial merge, final sort, limit,
//! finalization) run at the driver. The offload boundary is chosen per
//! operator, not per query.

use super::query::{AggFunc, AggState, Aggregate, CmpOp, Predicate, Query, SortKey};
use crate::dataset::array::Hyperslab;
use crate::dataset::metadata::ValueRange;
use crate::dataset::table::{Batch, Column};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A logical operator tree over one dataset.
#[derive(Clone, Debug, PartialEq)]
pub enum LogicalPlan {
    /// Leaf: read a dataset. `slab` selects a hyperslab of an *array*
    /// dataset (the VOL read path compiled into the IR); `None` is the
    /// ordinary whole-table scan.
    Scan {
        dataset: String,
        slab: Option<Hyperslab>,
    },
    /// Keep rows matching a predicate.
    Filter {
        input: Box<LogicalPlan>,
        predicate: Predicate,
    },
    /// Keep only the named columns.
    Project {
        input: Box<LogicalPlan>,
        columns: Vec<String>,
    },
    /// Aggregate expressions over optional group keys (empty = scalar).
    Aggregate {
        input: Box<LogicalPlan>,
        aggs: Vec<Aggregate>,
        keys: Vec<String>,
    },
    /// Total order over the rows.
    Sort {
        input: Box<LogicalPlan>,
        keys: Vec<SortKey>,
    },
    /// Keep the first `n` rows (or group rows, over aggregate output).
    Limit { input: Box<LogicalPlan>, n: usize },
    /// Fused Sort+Limit: the best `n` rows under `keys` — the operator
    /// the planner offloads as per-object partial top-k.
    TopK {
        input: Box<LogicalPlan>,
        keys: Vec<SortKey>,
        n: usize,
    },
}

impl LogicalPlan {
    /// Leaf constructor.
    pub fn scan(dataset: &str) -> LogicalPlan {
        LogicalPlan::Scan {
            dataset: dataset.to_string(),
            slab: None,
        }
    }

    /// Leaf constructor for a hyperslab selection over an array dataset —
    /// what `read_slab`/`read_slab_where` compile to. The VOL planner
    /// (`plan_vol_read`) is the only consumer; `to_query` rejects it.
    pub fn scan_slab(dataset: &str, slab: Hyperslab) -> LogicalPlan {
        LogicalPlan::Scan {
            dataset: dataset.to_string(),
            slab: Some(slab),
        }
    }

    /// Wrap this plan in a row filter. Below an `Aggregate` it is the
    /// WHERE clause; *above* one it is the HAVING operator (a filter over
    /// the finalized group rows, validated by [`LogicalPlan::to_query`]).
    pub fn filter(self, predicate: Predicate) -> LogicalPlan {
        LogicalPlan::Filter {
            input: Box::new(self),
            predicate,
        }
    }

    /// Keep only the named columns (row pipelines only).
    pub fn project(self, columns: &[&str]) -> LogicalPlan {
        LogicalPlan::Project {
            input: Box::new(self),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Aggregate expressions over `keys` (empty keys = scalar output).
    pub fn aggregate(self, aggs: Vec<Aggregate>, keys: &[&str]) -> LogicalPlan {
        LogicalPlan::Aggregate {
            input: Box::new(self),
            aggs,
            keys: keys.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Total order over the rows (merge-side; reduces nothing per object).
    pub fn sort(self, keys: Vec<SortKey>) -> LogicalPlan {
        LogicalPlan::Sort {
            input: Box::new(self),
            keys,
        }
    }

    /// Keep the first `n` rows (or group rows, over aggregate output).
    pub fn limit(self, n: usize) -> LogicalPlan {
        LogicalPlan::Limit {
            input: Box::new(self),
            n,
        }
    }

    /// Fused Sort+Limit: the best `n` rows under `keys`, offloadable as
    /// per-object partial top-k.
    pub fn top_k(self, keys: Vec<SortKey>, n: usize) -> LogicalPlan {
        LogicalPlan::TopK {
            input: Box::new(self),
            keys,
            n,
        }
    }

    /// The operator below this one (`None` for the scan leaf).
    fn input(&self) -> Option<&LogicalPlan> {
        match self {
            LogicalPlan::Scan { .. } => None,
            LogicalPlan::Filter { input, .. }
            | LogicalPlan::Project { input, .. }
            | LogicalPlan::Aggregate { input, .. }
            | LogicalPlan::Sort { input, .. }
            | LogicalPlan::Limit { input, .. }
            | LogicalPlan::TopK { input, .. } => Some(input),
        }
    }

    /// One-line description of this node (no inputs).
    fn describe(&self) -> String {
        match self {
            LogicalPlan::Scan { dataset, slab } => match slab {
                None => format!("Scan {dataset}"),
                Some(s) => format!(
                    "Scan {dataset} slab start={:?} count={:?}",
                    s.start, s.count
                ),
            },
            LogicalPlan::Filter { predicate, .. } => format!("Filter {predicate}"),
            LogicalPlan::Project { columns, .. } => {
                format!("Project [{}]", columns.join(", "))
            }
            LogicalPlan::Aggregate { aggs, keys, .. } => {
                let a: Vec<String> = aggs.iter().map(|x| x.to_string()).collect();
                if keys.is_empty() {
                    format!("Aggregate [{}]", a.join(", "))
                } else {
                    format!("Aggregate [{}] by [{}]", a.join(", "), keys.join(", "))
                }
            }
            LogicalPlan::Sort { keys, .. } => {
                let k: Vec<String> = keys.iter().map(|x| x.to_string()).collect();
                format!("Sort [{}]", k.join(", "))
            }
            LogicalPlan::Limit { n, .. } => format!("Limit {n}"),
            LogicalPlan::TopK { keys, n, .. } => {
                let k: Vec<String> = keys.iter().map(|x| x.to_string()).collect();
                format!("TopK {n} by [{}]", k.join(", "))
            }
        }
    }

    /// Render the operator tree top-down with indentation — the logical
    /// half of `QueryPlan::explain`.
    pub fn explain_tree(&self) -> String {
        let mut nodes = Vec::new();
        let mut cur = Some(self);
        while let Some(op) = cur {
            nodes.push(op.describe());
            cur = op.input();
        }
        let mut out = String::new();
        for (depth, line) in nodes.iter().enumerate() {
            for _ in 0..depth {
                out.push_str("  ");
            }
            let _ = writeln!(out, "{line}");
        }
        out
    }

    /// Validate and flatten the tree into a [`Query`].
    ///
    /// Accepted shape (bottom-up): one `Scan`, any number of `Filter`s
    /// (AND-merged) below the first non-filter operator, at most one
    /// `Project`, at most one `Aggregate` — optionally topped by
    /// `Filter`s over its *grouped* output, which flatten into the
    /// HAVING clause (`Query::having`; their columns must name group
    /// keys or aggregates by display form) — then `Sort`/`Limit` (or
    /// the fused `TopK`) on top. Anything else — a projection over
    /// aggregate output, a filter over a scalar aggregate, a sort above
    /// a limit, duplicated operators — is rejected with a query error
    /// rather than silently reordered.
    pub fn to_query(&self) -> Result<Query> {
        // Walk down to the leaf collecting the chain, then fold bottom-up.
        let mut chain = Vec::new();
        let mut cur = self;
        loop {
            chain.push(cur);
            match cur.input() {
                Some(next) => cur = next,
                None => break,
            }
        }
        let Some(LogicalPlan::Scan { dataset, slab }) = chain.pop() else {
            return Err(Error::Query("plan must bottom out in a Scan".into()));
        };
        if slab.is_some() {
            return Err(Error::Query(
                "hyperslab scans compile via the VOL planner, not to_query".into(),
            ));
        }
        let mut q = Query::scan(dataset);
        let mut has_filter = false;
        let mut has_agg = false;
        let mut has_sort = false;
        let mut has_limit = false;
        for op in chain.into_iter().rev() {
            match op {
                LogicalPlan::Scan { .. } => {
                    return Err(Error::Query("Scan above the leaf".into()));
                }
                LogicalPlan::Filter { predicate, .. } => {
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Filter must precede Sort/Limit".into(),
                        ));
                    }
                    if has_agg {
                        // Filter above Aggregate is the HAVING operator:
                        // it runs at the driver over the finalized group
                        // rows. Its columns must name group keys or
                        // aggregate expressions ("sum(val)") — anything
                        // else cannot exist above the aggregate.
                        q.having = if q.having == Predicate::True {
                            predicate.clone()
                        } else {
                            std::mem::replace(&mut q.having, Predicate::True)
                                .and(predicate.clone())
                        };
                        q.validate_having()?;
                        continue;
                    }
                    q.predicate = if has_filter {
                        std::mem::replace(&mut q.predicate, Predicate::True)
                            .and(predicate.clone())
                    } else {
                        predicate.clone()
                    };
                    has_filter = true;
                }
                LogicalPlan::Project { columns, .. } => {
                    if has_agg {
                        return Err(Error::Query(
                            "Project over aggregate output is not supported".into(),
                        ));
                    }
                    if q.projection.is_some() {
                        return Err(Error::Query("multiple Project operators".into()));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Project must precede Sort/Limit".into(),
                        ));
                    }
                    q.projection = Some(columns.clone());
                }
                LogicalPlan::Aggregate { aggs, keys, .. } => {
                    if has_agg {
                        return Err(Error::Query("multiple Aggregate operators".into()));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Aggregate must precede Sort/Limit".into(),
                        ));
                    }
                    if q.projection.is_some() {
                        return Err(Error::Query(
                            "Project below Aggregate is redundant; aggregate columns name their inputs"
                                .into(),
                        ));
                    }
                    if aggs.is_empty() {
                        return Err(Error::Query("Aggregate with no expressions".into()));
                    }
                    q.aggregates = aggs.clone();
                    q.group_by = keys.clone();
                    has_agg = true;
                }
                LogicalPlan::Sort { keys, .. } => {
                    if has_agg {
                        // Group output is already key-ordered; arbitrary
                        // sorts over aggregate rows are not supported.
                        return Err(Error::Query(
                            "Sort over aggregate output is not supported".into(),
                        ));
                    }
                    if has_sort {
                        return Err(Error::Query("multiple Sort operators".into()));
                    }
                    if has_limit {
                        // limit-then-sort has different semantics than the
                        // sort-then-limit the engine runs.
                        return Err(Error::Query("Sort above Limit is not supported".into()));
                    }
                    if keys.is_empty() {
                        return Err(Error::Query("Sort with no keys".into()));
                    }
                    q.sort_keys = keys.clone();
                    has_sort = true;
                }
                LogicalPlan::Limit { n, .. } => {
                    if has_limit {
                        return Err(Error::Query("multiple Limit operators".into()));
                    }
                    q.limit = Some(*n);
                    has_limit = true;
                }
                LogicalPlan::TopK { keys, n, .. } => {
                    if has_agg {
                        return Err(Error::Query(
                            "TopK over aggregate output is not supported".into(),
                        ));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "TopK combined with Sort/Limit is not supported".into(),
                        ));
                    }
                    if keys.is_empty() {
                        return Err(Error::Query("TopK with no keys".into()));
                    }
                    q.sort_keys = keys.clone();
                    q.limit = Some(*n);
                    has_sort = true;
                    has_limit = true;
                }
            }
        }
        Ok(q)
    }
}

impl Query {
    /// Lift the flat query into the operator-tree IR (inverse of
    /// [`LogicalPlan::to_query`] on accepted shapes).
    pub fn logical(&self) -> LogicalPlan {
        let mut plan = LogicalPlan::scan(&self.dataset);
        if self.predicate != Predicate::True {
            plan = plan.filter(self.predicate.clone());
        }
        if self.is_aggregate() {
            let keys: Vec<&str> = self.group_by.iter().map(String::as_str).collect();
            plan = plan.aggregate(self.aggregates.clone(), &keys);
            if self.having != Predicate::True {
                // Filter above Aggregate is the HAVING operator.
                plan = plan.filter(self.having.clone());
            }
        } else if let Some(p) = &self.projection {
            let cols: Vec<&str> = p.iter().map(String::as_str).collect();
            plan = plan.project(&cols);
        }
        match (&self.sort_keys[..], self.limit) {
            ([], None) => {}
            ([], Some(n)) => plan = plan.limit(n),
            (keys, None) => plan = plan.sort(keys.to_vec()),
            (keys, Some(n)) => plan = plan.top_k(keys.to_vec(), n),
        }
        plan
    }
}

// ---- the wire form of the server-side stage --------------------------------

/// The chained operator pipeline one storage server executes in a single
/// pass over one object (`skyhook.exec`): filter → project/carry →
/// partial aggregate (scalar or grouped) or partial top-k/head. Encoded
/// once per sub-query; every field after the predicate describes work
/// the server does *so the client does not have to move the bytes*.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineSpec {
    /// Row filter evaluated first, against the decoded column set.
    pub predicate: Predicate,
    /// Columns row-query partials must carry (projection ∪ sort keys);
    /// `None` = all columns.
    pub projection: Option<Vec<String>>,
    /// Aggregate expressions (empty = row query). Holistic functions
    /// make the server ship raw values back for exact finalization.
    pub aggs: Vec<Aggregate>,
    /// Group-by key columns (i64); meaningful only with `aggs`.
    pub keys: Vec<String>,
    /// Per-object pre-sort for partial top-k (row queries with a limit).
    pub sort: Vec<SortKey>,
    /// Per-object row cap (head(n) without `sort`, top-k with it).
    pub limit: Option<u64>,
    /// May the handler consult the object's zone-map xattr?
    pub zone_maps: bool,
    /// Column whose server-local secondary index the handler should
    /// probe (`ix1/` omap postings) to pre-mask the scan. `None` = plain
    /// scan. The handler falls back to a scan when the object carries no
    /// index for the column or the predicate has no probe-able window.
    pub index: Option<String>,
}

impl PipelineSpec {
    /// Wire encoding (the `skyhook.exec` call input).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.predicate.encode_into(&mut w);
        match &self.projection {
            Some(cols) => {
                w.u8(1);
                w.u32(cols.len() as u32);
                for c in cols {
                    w.str(c);
                }
            }
            None => {
                w.u8(0);
            }
        }
        w.u32(self.aggs.len() as u32);
        for a in &self.aggs {
            w.str(&a.col);
            w.u8(a.func.code());
        }
        w.u32(self.keys.len() as u32);
        for k in &self.keys {
            w.str(k);
        }
        w.u32(self.sort.len() as u32);
        for s in &self.sort {
            s.encode_into(&mut w);
        }
        match self.limit {
            Some(n) => {
                w.u8(1);
                w.u64(n);
            }
            None => {
                w.u8(0);
            }
        }
        w.u8(self.zone_maps as u8);
        match &self.index {
            Some(col) => {
                w.u8(1);
                w.str(col);
            }
            None => {
                w.u8(0);
            }
        }
        w.finish()
    }

    /// Wire decoding (inverse of [`PipelineSpec::encode`]).
    pub fn decode(buf: &[u8]) -> Result<PipelineSpec> {
        let mut r = ByteReader::new(buf);
        let predicate = Predicate::decode_from(&mut r)?;
        let projection = match r.u8()? {
            0 => None,
            1 => {
                let n = r.u32()? as usize;
                let mut cols = Vec::with_capacity(n);
                for _ in 0..n {
                    cols.push(r.str()?.to_string());
                }
                Some(cols)
            }
            o => return Err(Error::Corrupt(format!("bad projection tag {o}"))),
        };
        let n = r.u32()? as usize;
        let mut aggs = Vec::with_capacity(n);
        for _ in 0..n {
            let col = r.str()?.to_string();
            let func = AggFunc::from_code(r.u8()?)?;
            aggs.push(Aggregate { func, col });
        }
        let n = r.u32()? as usize;
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            keys.push(r.str()?.to_string());
        }
        let n = r.u32()? as usize;
        let mut sort = Vec::with_capacity(n);
        for _ in 0..n {
            sort.push(SortKey::decode_from(&mut r)?);
        }
        let limit = match r.u8()? {
            0 => None,
            1 => Some(r.u64()?),
            o => return Err(Error::Corrupt(format!("bad limit tag {o}"))),
        };
        let zone_maps = r.u8()? != 0;
        let index = match r.u8()? {
            0 => None,
            1 => Some(r.str()?.to_string()),
            o => return Err(Error::Corrupt(format!("bad index tag {o}"))),
        };
        Ok(PipelineSpec {
            predicate,
            projection,
            aggs,
            keys,
            sort,
            limit,
            zone_maps,
            index,
        })
    }

    /// Does any aggregate need raw values shipped back (holistic
    /// finalization at the driver)?
    pub fn any_holistic(&self) -> bool {
        self.aggs.iter().any(|a| !a.func.is_algebraic())
    }
}

// ---- index probe windows ---------------------------------------------------

/// The value window a secondary index on one column can serve for a
/// predicate, extracted from the conjunctive (AND) spine. Bounds live in
/// the query's f64 comparison domain; `None` means unbounded on that side.
///
/// The window is an *over*-approximation by construction: every row the
/// full predicate accepts satisfies each AND-spine conjunct, so its column
/// value falls inside the intersection window. Probing the index over the
/// window therefore yields a superset of the matching rows, and the full
/// predicate is still evaluated over the survivors — results are
/// bit-identical to an unindexed scan no matter how loose the window is.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IndexProbe {
    /// Lower bound as `(value, inclusive)`; `None` = unbounded below.
    pub lo: Option<(f64, bool)>,
    /// Upper bound as `(value, inclusive)`; `None` = unbounded above.
    pub hi: Option<(f64, bool)>,
    /// Contradictory conjuncts (or a NaN literal): no row can satisfy
    /// the indexed conjuncts, so the whole object produces zero rows.
    pub empty: bool,
}

/// Extract the probe-able window for `col`, or `None` when the predicate
/// carries no eq/range conjunct on `col` (an index probe would degenerate
/// to a full scan). Only the AND spine tightens the window: conjuncts
/// under `Or`/`Not` (and `Ne`, which excludes a point) could shrink the
/// row set below the true match set and are ignored.
pub fn index_probe_window(pred: &Predicate, col: &str) -> Option<IndexProbe> {
    let mut probe = IndexProbe {
        lo: None,
        hi: None,
        empty: false,
    };
    collect_probe_bounds(pred, col, &mut probe);
    if probe.lo.is_none() && probe.hi.is_none() && !probe.empty {
        return None;
    }
    if let (Some((lo, lo_inc)), Some((hi, hi_inc))) = (probe.lo, probe.hi) {
        if lo > hi || (lo == hi && !(lo_inc && hi_inc)) {
            probe.empty = true;
        }
    }
    Some(probe)
}

fn collect_probe_bounds(pred: &Predicate, col: &str, probe: &mut IndexProbe) {
    match pred {
        Predicate::And(a, b) => {
            collect_probe_bounds(a, col, probe);
            collect_probe_bounds(b, col, probe);
        }
        Predicate::Cmp { col: c, op, value } if c == col => {
            if value.is_nan() {
                // `x <op> NaN` is false for every ordering op, so the
                // conjunct — and hence the predicate — matches nothing.
                if !matches!(op, CmpOp::Ne) {
                    probe.empty = true;
                }
                return;
            }
            match op {
                CmpOp::Eq => {
                    tighten_lo(probe, *value, true);
                    tighten_hi(probe, *value, true);
                }
                CmpOp::Gt => tighten_lo(probe, *value, false),
                CmpOp::Ge => tighten_lo(probe, *value, true),
                CmpOp::Lt => tighten_hi(probe, *value, false),
                CmpOp::Le => tighten_hi(probe, *value, true),
                CmpOp::Ne => {}
            }
        }
        _ => {}
    }
}

fn tighten_lo(p: &mut IndexProbe, v: f64, inclusive: bool) {
    p.lo = Some(match p.lo {
        // Keep the existing bound when it is higher, or equal and at
        // least as tight (exclusive beats inclusive at the same value).
        Some((cur, ci)) if cur > v || (cur == v && (!ci || inclusive)) => (cur, ci),
        _ => (v, inclusive),
    });
}

fn tighten_hi(p: &mut IndexProbe, v: f64, inclusive: bool) {
    p.hi = Some(match p.hi {
        Some((cur, ci)) if cur < v || (cur == v && (!ci || inclusive)) => (cur, ci),
        _ => (v, inclusive),
    });
}

// ---- cardinality / selectivity estimation ----------------------------------

/// Estimate the fraction of `rows` rows a predicate matches, from the
/// per-column zone-map [`ValueRange`]s of one row group (`None` =
/// unknown column → assume everything matches).
///
/// Assumptions: values are uniform over `[lo, hi]`, conjuncts are
/// independent (`sel(a && b) = sel(a)·sel(b)`), equality on a non-point
/// range matches a handful of rows. NaN rows satisfy only `Ne`, exactly
/// like evaluation and pruning. The estimate feeds the planner's
/// per-stage offload choice ([`crate::simnet::AccessProfile`]); it
/// biases byte counts, never results.
pub fn estimate_selectivity(
    pred: &Predicate,
    rows: u64,
    range: &dyn Fn(&str) -> Option<ValueRange>,
) -> f64 {
    let s = match pred {
        Predicate::True => 1.0,
        Predicate::Cmp { col, op, value } => match range(col) {
            None => 1.0,
            Some(r) => {
                let nan_frac = if rows > 0 {
                    (r.nans as f64 / rows as f64).min(1.0)
                } else {
                    0.0
                };
                let non_nan = if !r.has_values() {
                    0.0
                } else if r.hi > r.lo {
                    let frac = ((*value - r.lo) / (r.hi - r.lo)).clamp(0.0, 1.0);
                    let point = (1.0 / rows.max(1) as f64).max(0.01);
                    match op {
                        CmpOp::Lt | CmpOp::Le => frac,
                        CmpOp::Gt | CmpOp::Ge => 1.0 - frac,
                        CmpOp::Eq => {
                            if *value >= r.lo && *value <= r.hi {
                                point
                            } else {
                                0.0
                            }
                        }
                        CmpOp::Ne => {
                            if *value >= r.lo && *value <= r.hi {
                                1.0 - point
                            } else {
                                1.0
                            }
                        }
                    }
                } else {
                    // Point range: the comparison is decided outright.
                    if op.eval(r.lo, *value) {
                        1.0
                    } else {
                        0.0
                    }
                };
                non_nan * (1.0 - nan_frac)
                    + if *op == CmpOp::Ne { nan_frac } else { 0.0 }
            }
        },
        Predicate::And(a, b) => {
            estimate_selectivity(a, rows, range) * estimate_selectivity(b, rows, range)
        }
        Predicate::Or(a, b) => {
            let x = estimate_selectivity(a, rows, range);
            let y = estimate_selectivity(b, rows, range);
            x + y - x * y
        }
        Predicate::Not(p) => 1.0 - estimate_selectivity(p, rows, range),
    };
    s.clamp(0.0, 1.0)
}

/// Estimate the distinct group count a grouped aggregate produces over
/// `matching_rows` rows: the product of per-key distinct estimates
/// (integral span of the zone-map range when known, `√rows` otherwise),
/// capped at the matching row count. Sizes the grouped-partial bytes in
/// the planner's cost model.
pub fn estimate_groups(
    keys: &[String],
    matching_rows: u64,
    range: &dyn Fn(&str) -> Option<ValueRange>,
) -> u64 {
    let cap = matching_rows.max(1) as f64;
    let mut product = 1.0f64;
    for k in keys {
        let distinct = match range(k) {
            Some(r) if r.has_values() => (r.hi - r.lo + 1.0).max(1.0),
            _ => cap.sqrt().max(1.0),
        };
        product = (product * distinct).min(cap);
    }
    product.min(cap) as u64
}

// ---- shared row ordering ---------------------------------------------------

/// One extracted sort-key column: floats compared with `total_cmp` (NaN
/// sorts after +inf, deterministically in every execution mode), i64
/// natively (no f64 widening — values beyond 2^53 must keep their
/// order), strings lexicographically.
enum KeyVals<'a> {
    Num(Vec<f64>),
    Int(&'a [i64]),
    Str(&'a [String]),
}

/// Extract the sort-key columns of one batch — the single definition of
/// how key values are read (F32 widened to f64, i64 native, strings
/// borrowed), shared by [`sort_rows`] and [`merge_sorted`] so their
/// comparators can never drift apart.
fn key_vals<'a>(batch: &'a Batch, keys: &[SortKey]) -> Result<Vec<(KeyVals<'a>, bool)>> {
    let mut cols = Vec::with_capacity(keys.len());
    for k in keys {
        let kv = match batch.col(&k.col)? {
            Column::Str(v) => KeyVals::Str(v),
            Column::F32(v) => KeyVals::Num(v.iter().map(|&x| x as f64).collect()),
            Column::F64(v) => KeyVals::Num(v.clone()),
            Column::I64(v) => KeyVals::Int(v),
        };
        cols.push((kv, k.desc));
    }
    Ok(cols)
}

/// Stable sort of a batch's rows by `keys`. Shared by the storage-side
/// partial top-k (`skyhook.exec`) and the driver's merge-side sort, so
/// pushed-down and client-side executions order rows identically.
pub fn sort_rows(batch: &Batch, keys: &[SortKey]) -> Result<Batch> {
    // Resolve keys first: a missing sort column errors regardless of row
    // count, so error behavior never depends on how many rows matched.
    let cols = key_vals(batch, keys)?;
    if cols.is_empty() || batch.nrows() <= 1 {
        return Ok(batch.clone());
    }
    let mut idx: Vec<usize> = (0..batch.nrows()).collect();
    idx.sort_by(|&a, &b| {
        for (kv, desc) in &cols {
            let o = match kv {
                KeyVals::Num(v) => v[a].total_cmp(&v[b]),
                KeyVals::Int(v) => v[a].cmp(&v[b]),
                KeyVals::Str(v) => v[a].cmp(&v[b]),
            };
            let o = if *desc { o.reverse() } else { o };
            if o != std::cmp::Ordering::Equal {
                return o;
            }
        }
        std::cmp::Ordering::Equal
    });
    batch.take(&idx)
}

/// Sort by `keys` and keep the first `n` rows — the per-object partial
/// of the TopK operator (with empty `keys`: plain head(n)).
pub fn top_k_rows(batch: &Batch, keys: &[SortKey], n: usize) -> Result<Batch> {
    let sorted = sort_rows(batch, keys)?;
    if sorted.nrows() > n {
        sorted.slice(0, n)
    } else {
        Ok(sorted)
    }
}

/// K-way partial-order merge of per-object row partials, each already
/// sorted by `keys`, truncated to `limit` rows when given — the
/// merge-side half of distributed top-k and of the final sort. Replaces
/// concatenate-then-resort: pre-sorted partials are consumed in order,
/// so a top-k merge touches at most `limit × parts` rows instead of
/// sorting everything again.
///
/// Ordering is identical to a *stable* sort of the concatenation: keys
/// compare exactly like [`sort_rows`] (floats via `total_cmp`, i64
/// natively, strings lexicographically), and ties keep (part order, row
/// order). All parts must share one schema.
pub fn merge_sorted(parts: &[Batch], keys: &[SortKey], limit: Option<usize>) -> Result<Batch> {
    let Some(first) = parts.first() else {
        return Err(Error::Query("merge_sorted needs at least one batch".into()));
    };
    // Resolve key columns per part up front (errors never depend on row
    // counts), and reject schema drift outright.
    let mut part_keys: Vec<Vec<(KeyVals, bool)>> = Vec::with_capacity(parts.len());
    for part in parts {
        if part.schema != first.schema {
            return Err(Error::Query("merge_sorted parts disagree on schema".into()));
        }
        part_keys.push(key_vals(part, keys)?);
    }
    let total: usize = parts.iter().map(|b| b.nrows()).sum();
    let want = limit.map_or(total, |n| n.min(total));
    // Compare the head rows of two parts under the sort keys.
    let row_cmp = |a: (usize, usize), b: (usize, usize)| -> std::cmp::Ordering {
        for ((ka, desc), (kb, _)) in part_keys[a.0].iter().zip(&part_keys[b.0]) {
            let o = match (ka, kb) {
                (KeyVals::Num(x), KeyVals::Num(y)) => x[a.1].total_cmp(&y[b.1]),
                (KeyVals::Int(x), KeyVals::Int(y)) => x[a.1].cmp(&y[b.1]),
                (KeyVals::Str(x), KeyVals::Str(y)) => x[a.1].cmp(&y[b.1]),
                // Same schema ⇒ same column type per key.
                _ => unreachable!("schema equality checked above"),
            };
            let o = if *desc { o.reverse() } else { o };
            if o != std::cmp::Ordering::Equal {
                return o;
            }
        }
        std::cmp::Ordering::Equal
    };
    let mut out: Vec<Column> = first
        .schema
        .columns
        .iter()
        .map(|c| Column::empty(c.dtype))
        .collect();
    let mut cursors = vec![0usize; parts.len()];
    for _ in 0..want {
        // Linear scan over the (few, ≤ #objects) cursors; strict `Less`
        // keeps the earliest part on ties — the stable order.
        let mut best: Option<usize> = None;
        for (pi, part) in parts.iter().enumerate() {
            if cursors[pi] >= part.nrows() {
                continue;
            }
            best = match best {
                None => Some(pi),
                Some(bi) => {
                    if row_cmp((pi, cursors[pi]), (bi, cursors[bi]))
                        == std::cmp::Ordering::Less
                    {
                        Some(pi)
                    } else {
                        Some(bi)
                    }
                }
            };
        }
        let bi = best.expect("want is bounded by the total row count");
        for (oc, c) in out.iter_mut().zip(&parts[bi].columns) {
            oc.push_from(c, cursors[bi])?;
        }
        cursors[bi] += 1;
    }
    Batch::new(first.schema.clone(), out)
}

/// Grouped multi-aggregate partials over a masked batch: multi-column
/// i64 key → one [`AggState`] per aggregate, sorted by key. Shared by
/// the storage-side `skyhook.exec` handler and the client-side worker,
/// so both execution modes fold the exact same arithmetic sequence and
/// produce bit-identical partials. The per-row key is probed through a
/// reused scratch buffer; an owned key is allocated only on the first
/// row of a new group.
pub fn grouped_partials(
    batch: &Batch,
    mask: &[bool],
    keys: &[String],
    aggs: &[Aggregate],
) -> Result<Vec<(Vec<i64>, Vec<AggState>)>> {
    let mut keycols: Vec<&[i64]> = Vec::with_capacity(keys.len());
    for k in keys {
        match batch.col(k)? {
            Column::I64(v) => keycols.push(v),
            _ => return Err(Error::Query("group_by needs an i64 column".into())),
        }
    }
    let valcols: Vec<&Column> = aggs
        .iter()
        .map(|a| batch.col(&a.col))
        .collect::<Result<_>>()?;
    let mut groups: BTreeMap<Vec<i64>, Vec<AggState>> = BTreeMap::new();
    let mut scratch: Vec<i64> = Vec::with_capacity(keys.len());
    for (i, &keep) in mask.iter().enumerate() {
        if !keep {
            continue;
        }
        scratch.clear();
        scratch.extend(keycols.iter().map(|k| k[i]));
        if !groups.contains_key(scratch.as_slice()) {
            groups.insert(
                scratch.clone(),
                aggs.iter()
                    .map(|a| AggState::new(!a.func.is_algebraic()))
                    .collect(),
            );
        }
        let states = groups
            .get_mut(scratch.as_slice())
            .expect("group inserted above");
        for (st, col) in states.iter_mut().zip(&valcols) {
            st.update(col.get_f64(i)?);
        }
    }
    Ok(groups.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::dataset::{DType, TableSchema};
    use crate::skyhook::query::CmpOp;

    #[test]
    fn builder_chain_flattens_to_query() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 10.0))
            .filter(Predicate::cmp("ts", CmpOp::Lt, 100.0))
            .project(&["ts", "val"])
            .sort(vec![SortKey::desc("val")])
            .limit(5);
        let q = lp.to_query().unwrap();
        assert_eq!(q.dataset, "t");
        // Two filters AND-merge in order.
        assert_eq!(
            q.predicate,
            Predicate::cmp("val", CmpOp::Gt, 10.0).and(Predicate::cmp("ts", CmpOp::Lt, 100.0))
        );
        assert_eq!(
            q.projection,
            Some(vec!["ts".to_string(), "val".to_string()])
        );
        assert_eq!(q.sort_keys, vec![SortKey::desc("val")]);
        assert_eq!(q.limit, Some(5));
        // And the round trip through Query::logical is the identity on
        // the flat form (filters already merged → single Filter node).
        assert_eq!(q.logical().to_query().unwrap(), q);
    }

    #[test]
    fn aggregate_chain_and_top_k() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(
                vec![
                    Aggregate::new(AggFunc::Sum, "val"),
                    Aggregate::new(AggFunc::Count, "val"),
                ],
                &["sensor", "flag"],
            );
        let q = lp.to_query().unwrap();
        assert_eq!(q.aggregates.len(), 2);
        assert_eq!(q.group_by, vec!["sensor", "flag"]);
        assert_eq!(q.logical().to_query().unwrap(), q);

        let topk = LogicalPlan::scan("t").top_k(vec![SortKey::desc("val")], 3);
        let q = topk.to_query().unwrap();
        assert_eq!(q.sort_keys, vec![SortKey::desc("val")]);
        assert_eq!(q.limit, Some(3));
        assert_eq!(q.logical().to_query().unwrap(), q);
    }

    #[test]
    fn slab_scans_describe_and_reject_to_query() {
        let slab = Hyperslab::new(&[16, 0], &[32, 4096]).unwrap();
        let lp = LogicalPlan::scan_slab("arr", slab)
            .filter(Predicate::cmp("v", CmpOp::Gt, 0.5));
        let tree = lp.explain_tree();
        assert!(tree.contains("Scan arr slab"), "{tree}");
        assert!(tree.contains("start=[16, 0]"), "{tree}");
        // Hyperslab scans are the VOL planner's input, not a Query shape.
        let err = lp.to_query().unwrap_err();
        assert!(err.to_string().contains("VOL planner"), "{err}");
        // The plain scan is unchanged.
        assert!(LogicalPlan::scan("t").to_query().is_ok());
    }

    #[test]
    fn illegal_shapes_are_rejected() {
        let agg = LogicalPlan::scan("t").aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &[]);
        assert!(agg
            .clone()
            .filter(Predicate::cmp("v", CmpOp::Gt, 0.0))
            .to_query()
            .is_err());
        assert!(agg.clone().project(&["v"]).to_query().is_err());
        assert!(agg
            .clone()
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &[])
            .to_query()
            .is_err());
        assert!(agg.clone().sort(vec![SortKey::asc("v")]).to_query().is_err());
        // Limit over aggregate output is shape-valid in the IR (it
        // truncates group rows; the planner rejects it for scalar
        // aggregates, where there is nothing to truncate).
        assert!(agg.limit(3).to_query().is_ok());
        let grouped = LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &["k"])
            .limit(3)
            .to_query()
            .unwrap();
        assert_eq!(grouped.limit, Some(3));
        // Sort above limit flips semantics → rejected.
        assert!(LogicalPlan::scan("t")
            .limit(3)
            .sort(vec![SortKey::asc("v")])
            .to_query()
            .is_err());
        // Empty sorts/aggregates and duplicate projections.
        assert!(LogicalPlan::scan("t").sort(vec![]).to_query().is_err());
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![], &[])
            .to_query()
            .is_err());
        assert!(LogicalPlan::scan("t")
            .project(&["a"])
            .project(&["a"])
            .to_query()
            .is_err());
    }

    #[test]
    fn having_is_filter_above_aggregate() {
        // Filter above a *grouped* Aggregate flattens into Query::having.
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(
                vec![
                    Aggregate::new(AggFunc::Count, "val"),
                    Aggregate::new(AggFunc::Sum, "val"),
                ],
                &["sensor"],
            )
            .filter(Predicate::cmp("count(val)", CmpOp::Gt, 10.0));
        let q = lp.to_query().unwrap();
        assert_eq!(q.predicate, Predicate::cmp("flag", CmpOp::Eq, 0.0));
        assert_eq!(q.having, Predicate::cmp("count(val)", CmpOp::Gt, 10.0));
        // Round trip through the IR is the identity.
        assert_eq!(q.logical().to_query().unwrap(), q);
        // Two HAVING filters AND-merge; group keys are valid columns.
        let q = LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "val")], &["sensor"])
            .filter(Predicate::cmp("sum(val)", CmpOp::Gt, 1.0))
            .filter(Predicate::cmp("sensor", CmpOp::Le, 5.0))
            .to_query()
            .unwrap();
        assert_eq!(
            q.having,
            Predicate::cmp("sum(val)", CmpOp::Gt, 1.0)
                .and(Predicate::cmp("sensor", CmpOp::Le, 5.0))
        );
        // HAVING + limit plans (limit truncates after the HAVING filter).
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "val")], &["sensor"])
            .filter(Predicate::cmp("sum(val)", CmpOp::Gt, 1.0))
            .limit(3)
            .to_query()
            .is_ok());
        // Rejected shapes: scalar aggregate, unknown column, after limit.
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "val")], &[])
            .filter(Predicate::cmp("sum(val)", CmpOp::Gt, 1.0))
            .to_query()
            .is_err());
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "val")], &["sensor"])
            .filter(Predicate::cmp("val", CmpOp::Gt, 1.0))
            .to_query()
            .is_err());
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "val")], &["sensor"])
            .limit(3)
            .filter(Predicate::cmp("sum(val)", CmpOp::Gt, 1.0))
            .to_query()
            .is_err());
    }

    #[test]
    fn selectivity_estimates_track_uniform_ranges() {
        let range = |col: &str| match col {
            "val" => Some(ValueRange::exact(0.0, 100.0)),
            "k" => Some(ValueRange::exact(7.0, 7.0)),
            _ => None,
        };
        let sel = |p: &Predicate| estimate_selectivity(p, 1000, &range);
        let feq = |a: f64, b: f64| (a - b).abs() < 1e-9;
        assert!(feq(sel(&Predicate::True), 1.0));
        assert!(feq(sel(&Predicate::cmp("val", CmpOp::Lt, 25.0)), 0.25));
        assert!(feq(sel(&Predicate::cmp("val", CmpOp::Ge, 90.0)), 0.10));
        // Out-of-range comparisons clamp to 0 / 1.
        assert!(feq(sel(&Predicate::cmp("val", CmpOp::Gt, 200.0)), 0.0));
        assert!(feq(sel(&Predicate::cmp("val", CmpOp::Lt, 200.0)), 1.0));
        // Equality on a wide range matches a sliver; Ne the complement.
        assert!(sel(&Predicate::cmp("val", CmpOp::Eq, 50.0)) < 0.05);
        assert!(sel(&Predicate::cmp("val", CmpOp::Ne, 50.0)) > 0.95);
        // Point ranges are decided outright.
        assert!(feq(sel(&Predicate::cmp("k", CmpOp::Eq, 7.0)), 1.0));
        assert!(feq(sel(&Predicate::cmp("k", CmpOp::Gt, 7.0)), 0.0));
        // Unknown columns assume everything matches.
        assert!(feq(sel(&Predicate::cmp("ghost", CmpOp::Lt, -1e12)), 1.0));
        // Conjunction multiplies, disjunction unions, Not complements.
        let a = Predicate::cmp("val", CmpOp::Lt, 50.0);
        let b = Predicate::cmp("val", CmpOp::Ge, 90.0);
        assert!(feq(sel(&a.clone().and(b.clone())), 0.05));
        assert!(feq(sel(&a.clone().or(b.clone())), 0.5 + 0.1 - 0.05));
        assert!(feq(sel(&a.clone().not()), 0.5));
        // NaN rows only keep Ne alive.
        let nanny = |_: &str| {
            Some(ValueRange {
                lo: 0.0,
                hi: 100.0,
                nans: 500,
            })
        };
        let s = estimate_selectivity(&Predicate::cmp("v", CmpOp::Lt, 50.0), 1000, &nanny);
        assert!(feq(s, 0.25), "non-NaN half scaled: {s}");
        let s = estimate_selectivity(&Predicate::cmp("v", CmpOp::Ne, 200.0), 1000, &nanny);
        assert!(feq(s, 1.0), "Ne matches NaN rows too: {s}");
    }

    #[test]
    fn group_count_estimates_cap_at_rows() {
        let range = |col: &str| match col {
            "sensor" => Some(ValueRange::exact(0.0, 99.0)),
            "flag" => Some(ValueRange::exact(0.0, 1.0)),
            _ => None,
        };
        let keys = |ks: &[&str]| ks.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(estimate_groups(&keys(&["flag"]), 10_000, &range), 2);
        assert_eq!(estimate_groups(&keys(&["sensor"]), 10_000, &range), 100);
        assert_eq!(estimate_groups(&keys(&["sensor", "flag"]), 10_000, &range), 200);
        // Capped by matching rows.
        assert_eq!(estimate_groups(&keys(&["sensor", "flag"]), 50, &range), 50);
        // Unknown key → √rows heuristic.
        assert_eq!(estimate_groups(&keys(&["ghost"]), 10_000, &range), 100);
        // No keys → one (scalar) group.
        assert_eq!(estimate_groups(&[], 10_000, &range), 1);
    }

    /// Batch equality treating NaN as equal to itself (bitwise floats),
    /// so merge-vs-sort comparisons work on NaN-bearing sort keys.
    fn bit_equal(a: &Batch, b: &Batch) -> bool {
        a.schema == b.schema
            && a.nrows() == b.nrows()
            && a.columns.iter().zip(&b.columns).all(|(x, y)| match (x, y) {
                (Column::F32(u), Column::F32(v)) => {
                    u.iter().zip(v).all(|(p, q)| p.to_bits() == q.to_bits())
                }
                (Column::F64(u), Column::F64(v)) => {
                    u.iter().zip(v).all(|(p, q)| p.to_bits() == q.to_bits())
                }
                _ => x == y,
            })
    }

    #[test]
    fn merge_sorted_equals_stable_sort_of_concat() {
        let mut rng = crate::util::rng::Xoshiro256::new(99);
        for _ in 0..20 {
            // Random parts with shared schema, each pre-sorted.
            let keys = vec![SortKey::desc("val"), SortKey::asc("ts")];
            let nparts = rng.range(1, 5);
            let mut parts = Vec::new();
            let mut all: Option<Batch> = None;
            for _ in 0..nparts {
                let rows = rng.range(0, 40);
                let b = Batch::new(
                    TableSchema::new(&[("ts", DType::I64), ("val", DType::F32)]),
                    vec![
                        Column::I64((0..rows).map(|_| rng.range(0, 50) as i64).collect()),
                        Column::F32(
                            (0..rows)
                                .map(|_| {
                                    if rng.chance(0.05) {
                                        f32::NAN
                                    } else {
                                        (rng.range(0, 8)) as f32
                                    }
                                })
                                .collect(),
                        ),
                    ],
                )
                .unwrap();
                match &mut all {
                    Some(acc) => acc.concat(&b).unwrap(),
                    None => all = Some(b.clone()),
                }
                parts.push(sort_rows(&b, &keys).unwrap());
            }
            let reference = sort_rows(&all.unwrap(), &keys).unwrap();
            // Full merge equals the stable sort of the concatenation,
            // including duplicate-key runs and NaN placement.
            let merged = merge_sorted(&parts, &keys, None).unwrap();
            assert!(bit_equal(&merged, &reference));
            // Truncated merge equals its prefix (per-part pre-truncation
            // to k is what the driver does for top-k).
            let k = rng.range(0, 15);
            let truncated: Vec<Batch> = parts
                .iter()
                .map(|p| top_k_rows(p, &keys, k).unwrap())
                .collect();
            let merged_k = merge_sorted(&truncated, &keys, Some(k)).unwrap();
            let want = if reference.nrows() > k {
                reference.slice(0, k).unwrap()
            } else {
                reference.clone()
            };
            assert!(bit_equal(&merged_k, &want));
        }
    }

    #[test]
    fn merge_sorted_rejects_bad_inputs() {
        let a = Batch::new(
            TableSchema::new(&[("x", DType::I64)]),
            vec![Column::I64(vec![1, 2])],
        )
        .unwrap();
        let b = Batch::new(
            TableSchema::new(&[("y", DType::I64)]),
            vec![Column::I64(vec![3])],
        )
        .unwrap();
        assert!(merge_sorted(&[], &[SortKey::asc("x")], None).is_err());
        assert!(merge_sorted(&[a.clone(), b], &[SortKey::asc("x")], None).is_err());
        assert!(merge_sorted(&[a.clone()], &[SortKey::asc("ghost")], None).is_err());
        // Single part: identity (plus truncation).
        let m = merge_sorted(&[a.clone()], &[SortKey::asc("x")], Some(1)).unwrap();
        assert_eq!(m, a.slice(0, 1).unwrap());
    }

    #[test]
    fn explain_tree_lists_operators_top_down() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 10.0))
            .project(&["ts", "val"])
            .top_k(vec![SortKey::desc("val")], 8);
        let text = lp.explain_tree();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("TopK 8"));
        assert!(lines[1].trim_start().starts_with("Project"));
        assert!(lines[2].trim_start().starts_with("Filter"));
        assert!(lines[3].trim_start().starts_with("Scan t"));
    }

    #[test]
    fn pipeline_spec_wire_roundtrip() {
        let spec = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 1.5)
                .and(Predicate::cmp("ts", CmpOp::Ne, 0.0)),
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            aggs: vec![
                Aggregate::new(AggFunc::Mean, "val"),
                Aggregate::new(AggFunc::Median, "val"),
            ],
            keys: vec!["sensor".to_string(), "flag".to_string()],
            sort: vec![SortKey::desc("val"), SortKey::asc("ts")],
            limit: Some(17),
            zone_maps: true,
            index: Some("val".to_string()),
        };
        let dec = PipelineSpec::decode(&spec.encode()).unwrap();
        assert_eq!(dec, spec);
        assert!(dec.any_holistic());
        let plain = PipelineSpec {
            predicate: Predicate::True,
            projection: None,
            aggs: vec![],
            keys: vec![],
            sort: vec![],
            limit: None,
            zone_maps: false,
            index: None,
        };
        assert_eq!(PipelineSpec::decode(&plain.encode()).unwrap(), plain);
        assert!(!plain.any_holistic());
        assert!(PipelineSpec::decode(b"\xff\xff").is_err());
    }

    #[test]
    fn index_probe_window_takes_only_the_and_spine() {
        // Conjunctive range: both sides tighten.
        let p = Predicate::cmp("val", CmpOp::Ge, 10.0).and(Predicate::cmp("val", CmpOp::Lt, 20.0));
        let w = index_probe_window(&p, "val").unwrap();
        assert_eq!(w.lo, Some((10.0, true)));
        assert_eq!(w.hi, Some((20.0, false)));
        assert!(!w.empty);

        // Eq pins both bounds; conjuncts on other columns don't leak in.
        let p = Predicate::cmp("sensor", CmpOp::Eq, 3.0).and(Predicate::cmp("val", CmpOp::Gt, 0.0));
        let w = index_probe_window(&p, "sensor").unwrap();
        assert_eq!(w.lo, Some((3.0, true)));
        assert_eq!(w.hi, Some((3.0, true)));
        assert!(index_probe_window(&p, "ts").is_none());

        // Tightest bound wins: exclusive beats inclusive at the same value.
        let p = Predicate::cmp("val", CmpOp::Gt, 5.0).and(Predicate::cmp("val", CmpOp::Ge, 5.0));
        let w = index_probe_window(&p, "val").unwrap();
        assert_eq!(w.lo, Some((5.0, false)));

        // Disjuncts and negations must not tighten (superset safety).
        let p = Predicate::cmp("val", CmpOp::Gt, 100.0).or(Predicate::cmp("val", CmpOp::Lt, 0.0));
        assert!(index_probe_window(&p, "val").is_none());
        let p = Predicate::cmp("val", CmpOp::Lt, 1.0).not();
        assert!(index_probe_window(&p, "val").is_none());
        // Ne excludes a point — unusable as a range.
        let p = Predicate::cmp("val", CmpOp::Ne, 7.0);
        assert!(index_probe_window(&p, "val").is_none());

        // Contradictions and NaN literals are provably-empty windows.
        let p = Predicate::cmp("val", CmpOp::Gt, 9.0).and(Predicate::cmp("val", CmpOp::Lt, 3.0));
        assert!(index_probe_window(&p, "val").unwrap().empty);
        let p = Predicate::cmp("val", CmpOp::Eq, 4.0).and(Predicate::cmp("val", CmpOp::Lt, 4.0));
        assert!(index_probe_window(&p, "val").unwrap().empty);
        let p = Predicate::cmp("val", CmpOp::Le, f64::NAN);
        assert!(index_probe_window(&p, "val").unwrap().empty);
        assert!(index_probe_window(&Predicate::True, "val").is_none());
    }

    #[test]
    fn sort_rows_orders_and_is_stable() {
        let b = Batch::new(
            TableSchema::new(&[("k", DType::I64), ("v", DType::F32), ("s", DType::Str)]),
            vec![
                Column::I64(vec![2, 1, 2, 1]),
                Column::F32(vec![10.0, 20.0, 30.0, 20.0]),
                Column::Str(vec!["b".into(), "a".into(), "c".into(), "d".into()]),
            ],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("k")]).unwrap();
        assert_eq!(s.col("k").unwrap(), &Column::I64(vec![1, 1, 2, 2]));
        // Stability: equal keys keep original order.
        assert_eq!(
            s.col("s").unwrap(),
            &Column::Str(vec!["a".into(), "d".into(), "b".into(), "c".into()])
        );
        // Secondary key + descending.
        let s = sort_rows(&b, &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        assert_eq!(s.col("v").unwrap(), &Column::F32(vec![20.0, 20.0, 30.0, 10.0]));
        // String sort.
        let s = sort_rows(&b, &[SortKey::desc("s")]).unwrap();
        assert_eq!(
            s.col("s").unwrap(),
            &Column::Str(vec!["d".into(), "c".into(), "b".into(), "a".into()])
        );
        // Missing column errors — even on empty or single-row batches.
        assert!(sort_rows(&b, &[SortKey::asc("ghost")]).is_err());
        let empty = Batch::empty(&b.schema);
        assert!(sort_rows(&empty, &[SortKey::asc("ghost")]).is_err());
        assert!(top_k_rows(&empty, &[SortKey::asc("k")], 3).unwrap().nrows() == 0);
    }

    #[test]
    fn sort_rows_i64_keys_beyond_f64_precision() {
        // Adjacent nanosecond-scale timestamps collapse to the same f64;
        // i64 keys must compare natively.
        let base = 1_700_000_000_000_000_000i64; // > 2^53
        let b = Batch::new(
            TableSchema::new(&[("ts", DType::I64)]),
            vec![Column::I64(vec![base + 2, base + 1, base + 3, base])],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("ts")]).unwrap();
        assert_eq!(
            s.col("ts").unwrap(),
            &Column::I64(vec![base, base + 1, base + 2, base + 3])
        );
        let t = top_k_rows(&b, &[SortKey::desc("ts")], 2).unwrap();
        assert_eq!(
            t.col("ts").unwrap(),
            &Column::I64(vec![base + 3, base + 2])
        );
    }

    #[test]
    fn sort_rows_total_order_on_nan() {
        let b = Batch::new(
            TableSchema::new(&[("v", DType::F32)]),
            vec![Column::F32(vec![f32::NAN, 1.0, -2.0, f32::NAN, 0.5])],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("v")]).unwrap();
        let Column::F32(v) = s.col("v").unwrap() else {
            unreachable!()
        };
        assert_eq!(&v[..3], &[-2.0, 0.5, 1.0]);
        assert!(v[3].is_nan() && v[4].is_nan());
        // Deterministic: sorting twice gives bit-identical output.
        let s2 = sort_rows(&b, &[SortKey::asc("v")]).unwrap();
        let Column::F32(v2) = s2.col("v").unwrap() else {
            unreachable!()
        };
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn top_k_rows_truncates_after_sort() {
        let b = gen::sensor_table(100, 5);
        let t = top_k_rows(&b, &[SortKey::desc("val")], 10).unwrap();
        assert_eq!(t.nrows(), 10);
        let Column::F32(v) = t.col("val").unwrap() else {
            unreachable!()
        };
        assert!(v.windows(2).all(|w| w[0] >= w[1]));
        // n larger than the batch: everything, still sorted.
        let t = top_k_rows(&b, &[SortKey::asc("ts")], 500).unwrap();
        assert_eq!(t.nrows(), 100);
        // Head without keys preserves row order.
        let h = top_k_rows(&b, &[], 7).unwrap();
        assert_eq!(h, b.slice(0, 7).unwrap());
    }
}
