//! The logical-plan IR: dataset access operations as a composable
//! operator tree (§3.2 "Composability of Access Operations").
//!
//! A [`LogicalPlan`] is a chain of operators over a `Scan` leaf —
//! `Filter`, `Project`, `Aggregate` (any number of aggregate expressions
//! over any number of i64 group keys), `Sort`, `Limit`, and the fused
//! `TopK`. The fluent [`Query`] builder constructs the same shape
//! directly; [`LogicalPlan::to_query`] validates an arbitrary tree into
//! that flat form (rejecting shapes the engine cannot run, e.g. a filter
//! over aggregate output), and [`Query::logical`] lifts a query back
//! into the tree.
//!
//! The planner (`skyhook::plan`) compiles the IR into a staged
//! `QueryPlan`: the operators up to and including the per-object
//! partials ([`PipelineSpec`]) are encoded once onto the wire and
//! executed server-side in a single pass by the `skyhook.exec` object
//! class; the merge-side operators (partial merge, final sort, limit,
//! finalization) run at the driver. The offload boundary is chosen per
//! operator, not per query.

use super::query::{AggFunc, AggState, Aggregate, Predicate, Query, SortKey};
use crate::dataset::table::{Batch, Column};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A logical operator tree over one dataset.
#[derive(Clone, Debug, PartialEq)]
pub enum LogicalPlan {
    /// Leaf: read a table dataset.
    Scan { dataset: String },
    /// Keep rows matching a predicate.
    Filter {
        input: Box<LogicalPlan>,
        predicate: Predicate,
    },
    /// Keep only the named columns.
    Project {
        input: Box<LogicalPlan>,
        columns: Vec<String>,
    },
    /// Aggregate expressions over optional group keys (empty = scalar).
    Aggregate {
        input: Box<LogicalPlan>,
        aggs: Vec<Aggregate>,
        keys: Vec<String>,
    },
    /// Total order over the rows.
    Sort {
        input: Box<LogicalPlan>,
        keys: Vec<SortKey>,
    },
    /// Keep the first `n` rows (or group rows, over aggregate output).
    Limit { input: Box<LogicalPlan>, n: usize },
    /// Fused Sort+Limit: the best `n` rows under `keys` — the operator
    /// the planner offloads as per-object partial top-k.
    TopK {
        input: Box<LogicalPlan>,
        keys: Vec<SortKey>,
        n: usize,
    },
}

impl LogicalPlan {
    /// Leaf constructor.
    pub fn scan(dataset: &str) -> LogicalPlan {
        LogicalPlan::Scan {
            dataset: dataset.to_string(),
        }
    }

    pub fn filter(self, predicate: Predicate) -> LogicalPlan {
        LogicalPlan::Filter {
            input: Box::new(self),
            predicate,
        }
    }

    pub fn project(self, columns: &[&str]) -> LogicalPlan {
        LogicalPlan::Project {
            input: Box::new(self),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn aggregate(self, aggs: Vec<Aggregate>, keys: &[&str]) -> LogicalPlan {
        LogicalPlan::Aggregate {
            input: Box::new(self),
            aggs,
            keys: keys.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn sort(self, keys: Vec<SortKey>) -> LogicalPlan {
        LogicalPlan::Sort {
            input: Box::new(self),
            keys,
        }
    }

    pub fn limit(self, n: usize) -> LogicalPlan {
        LogicalPlan::Limit {
            input: Box::new(self),
            n,
        }
    }

    pub fn top_k(self, keys: Vec<SortKey>, n: usize) -> LogicalPlan {
        LogicalPlan::TopK {
            input: Box::new(self),
            keys,
            n,
        }
    }

    /// The operator below this one (`None` for the scan leaf).
    fn input(&self) -> Option<&LogicalPlan> {
        match self {
            LogicalPlan::Scan { .. } => None,
            LogicalPlan::Filter { input, .. }
            | LogicalPlan::Project { input, .. }
            | LogicalPlan::Aggregate { input, .. }
            | LogicalPlan::Sort { input, .. }
            | LogicalPlan::Limit { input, .. }
            | LogicalPlan::TopK { input, .. } => Some(input),
        }
    }

    /// One-line description of this node (no inputs).
    fn describe(&self) -> String {
        match self {
            LogicalPlan::Scan { dataset } => format!("Scan {dataset}"),
            LogicalPlan::Filter { predicate, .. } => format!("Filter {predicate}"),
            LogicalPlan::Project { columns, .. } => {
                format!("Project [{}]", columns.join(", "))
            }
            LogicalPlan::Aggregate { aggs, keys, .. } => {
                let a: Vec<String> = aggs.iter().map(|x| x.to_string()).collect();
                if keys.is_empty() {
                    format!("Aggregate [{}]", a.join(", "))
                } else {
                    format!("Aggregate [{}] by [{}]", a.join(", "), keys.join(", "))
                }
            }
            LogicalPlan::Sort { keys, .. } => {
                let k: Vec<String> = keys.iter().map(|x| x.to_string()).collect();
                format!("Sort [{}]", k.join(", "))
            }
            LogicalPlan::Limit { n, .. } => format!("Limit {n}"),
            LogicalPlan::TopK { keys, n, .. } => {
                let k: Vec<String> = keys.iter().map(|x| x.to_string()).collect();
                format!("TopK {n} by [{}]", k.join(", "))
            }
        }
    }

    /// Render the operator tree top-down with indentation — the logical
    /// half of `QueryPlan::explain`.
    pub fn explain_tree(&self) -> String {
        let mut nodes = Vec::new();
        let mut cur = Some(self);
        while let Some(op) = cur {
            nodes.push(op.describe());
            cur = op.input();
        }
        let mut out = String::new();
        for (depth, line) in nodes.iter().enumerate() {
            for _ in 0..depth {
                out.push_str("  ");
            }
            let _ = writeln!(out, "{line}");
        }
        out
    }

    /// Validate and flatten the tree into a [`Query`].
    ///
    /// Accepted shape (bottom-up): one `Scan`, any number of `Filter`s
    /// (AND-merged) below the first non-filter operator, at most one
    /// `Project`, at most one `Aggregate`, then `Sort`/`Limit` (or the
    /// fused `TopK`) on top. Anything else — a filter or projection over
    /// aggregate output, a sort above a limit, duplicated operators — is
    /// rejected with a query error rather than silently reordered.
    pub fn to_query(&self) -> Result<Query> {
        // Walk down to the leaf collecting the chain, then fold bottom-up.
        let mut chain = Vec::new();
        let mut cur = self;
        loop {
            chain.push(cur);
            match cur.input() {
                Some(next) => cur = next,
                None => break,
            }
        }
        let Some(LogicalPlan::Scan { dataset }) = chain.pop() else {
            return Err(Error::Query("plan must bottom out in a Scan".into()));
        };
        let mut q = Query::scan(dataset);
        let mut has_filter = false;
        let mut has_agg = false;
        let mut has_sort = false;
        let mut has_limit = false;
        for op in chain.into_iter().rev() {
            match op {
                LogicalPlan::Scan { .. } => {
                    return Err(Error::Query("Scan above the leaf".into()));
                }
                LogicalPlan::Filter { predicate, .. } => {
                    if has_agg {
                        return Err(Error::Query(
                            "Filter over aggregate output is not supported".into(),
                        ));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Filter must precede Sort/Limit".into(),
                        ));
                    }
                    q.predicate = if has_filter {
                        std::mem::replace(&mut q.predicate, Predicate::True)
                            .and(predicate.clone())
                    } else {
                        predicate.clone()
                    };
                    has_filter = true;
                }
                LogicalPlan::Project { columns, .. } => {
                    if has_agg {
                        return Err(Error::Query(
                            "Project over aggregate output is not supported".into(),
                        ));
                    }
                    if q.projection.is_some() {
                        return Err(Error::Query("multiple Project operators".into()));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Project must precede Sort/Limit".into(),
                        ));
                    }
                    q.projection = Some(columns.clone());
                }
                LogicalPlan::Aggregate { aggs, keys, .. } => {
                    if has_agg {
                        return Err(Error::Query("multiple Aggregate operators".into()));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "Aggregate must precede Sort/Limit".into(),
                        ));
                    }
                    if q.projection.is_some() {
                        return Err(Error::Query(
                            "Project below Aggregate is redundant; aggregate columns name their inputs"
                                .into(),
                        ));
                    }
                    if aggs.is_empty() {
                        return Err(Error::Query("Aggregate with no expressions".into()));
                    }
                    q.aggregates = aggs.clone();
                    q.group_by = keys.clone();
                    has_agg = true;
                }
                LogicalPlan::Sort { keys, .. } => {
                    if has_agg {
                        // Group output is already key-ordered; arbitrary
                        // sorts over aggregate rows are not supported.
                        return Err(Error::Query(
                            "Sort over aggregate output is not supported".into(),
                        ));
                    }
                    if has_sort {
                        return Err(Error::Query("multiple Sort operators".into()));
                    }
                    if has_limit {
                        // limit-then-sort has different semantics than the
                        // sort-then-limit the engine runs.
                        return Err(Error::Query("Sort above Limit is not supported".into()));
                    }
                    if keys.is_empty() {
                        return Err(Error::Query("Sort with no keys".into()));
                    }
                    q.sort_keys = keys.clone();
                    has_sort = true;
                }
                LogicalPlan::Limit { n, .. } => {
                    if has_limit {
                        return Err(Error::Query("multiple Limit operators".into()));
                    }
                    q.limit = Some(*n);
                    has_limit = true;
                }
                LogicalPlan::TopK { keys, n, .. } => {
                    if has_agg {
                        return Err(Error::Query(
                            "TopK over aggregate output is not supported".into(),
                        ));
                    }
                    if has_sort || has_limit {
                        return Err(Error::Query(
                            "TopK combined with Sort/Limit is not supported".into(),
                        ));
                    }
                    if keys.is_empty() {
                        return Err(Error::Query("TopK with no keys".into()));
                    }
                    q.sort_keys = keys.clone();
                    q.limit = Some(*n);
                    has_sort = true;
                    has_limit = true;
                }
            }
        }
        Ok(q)
    }
}

impl Query {
    /// Lift the flat query into the operator-tree IR (inverse of
    /// [`LogicalPlan::to_query`] on accepted shapes).
    pub fn logical(&self) -> LogicalPlan {
        let mut plan = LogicalPlan::scan(&self.dataset);
        if self.predicate != Predicate::True {
            plan = plan.filter(self.predicate.clone());
        }
        if self.is_aggregate() {
            let keys: Vec<&str> = self.group_by.iter().map(String::as_str).collect();
            plan = plan.aggregate(self.aggregates.clone(), &keys);
        } else if let Some(p) = &self.projection {
            let cols: Vec<&str> = p.iter().map(String::as_str).collect();
            plan = plan.project(&cols);
        }
        match (&self.sort_keys[..], self.limit) {
            ([], None) => {}
            ([], Some(n)) => plan = plan.limit(n),
            (keys, None) => plan = plan.sort(keys.to_vec()),
            (keys, Some(n)) => plan = plan.top_k(keys.to_vec(), n),
        }
        plan
    }
}

// ---- the wire form of the server-side stage --------------------------------

/// The chained operator pipeline one storage server executes in a single
/// pass over one object (`skyhook.exec`): filter → project/carry →
/// partial aggregate (scalar or grouped) or partial top-k/head. Encoded
/// once per sub-query; every field after the predicate describes work
/// the server does *so the client does not have to move the bytes*.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineSpec {
    pub predicate: Predicate,
    /// Columns row-query partials must carry (projection ∪ sort keys);
    /// `None` = all columns.
    pub projection: Option<Vec<String>>,
    /// Aggregate expressions (empty = row query). Holistic functions
    /// make the server ship raw values back for exact finalization.
    pub aggs: Vec<Aggregate>,
    /// Group-by key columns (i64); meaningful only with `aggs`.
    pub keys: Vec<String>,
    /// Per-object pre-sort for partial top-k (row queries with a limit).
    pub sort: Vec<SortKey>,
    /// Per-object row cap (head(n) without `sort`, top-k with it).
    pub limit: Option<u64>,
    /// May the handler consult the object's zone-map xattr?
    pub zone_maps: bool,
}

impl PipelineSpec {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.predicate.encode_into(&mut w);
        match &self.projection {
            Some(cols) => {
                w.u8(1);
                w.u32(cols.len() as u32);
                for c in cols {
                    w.str(c);
                }
            }
            None => {
                w.u8(0);
            }
        }
        w.u32(self.aggs.len() as u32);
        for a in &self.aggs {
            w.str(&a.col);
            w.u8(a.func.code());
        }
        w.u32(self.keys.len() as u32);
        for k in &self.keys {
            w.str(k);
        }
        w.u32(self.sort.len() as u32);
        for s in &self.sort {
            s.encode_into(&mut w);
        }
        match self.limit {
            Some(n) => {
                w.u8(1);
                w.u64(n);
            }
            None => {
                w.u8(0);
            }
        }
        w.u8(self.zone_maps as u8);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<PipelineSpec> {
        let mut r = ByteReader::new(buf);
        let predicate = Predicate::decode_from(&mut r)?;
        let projection = match r.u8()? {
            0 => None,
            1 => {
                let n = r.u32()? as usize;
                let mut cols = Vec::with_capacity(n);
                for _ in 0..n {
                    cols.push(r.str()?.to_string());
                }
                Some(cols)
            }
            o => return Err(Error::Corrupt(format!("bad projection tag {o}"))),
        };
        let n = r.u32()? as usize;
        let mut aggs = Vec::with_capacity(n);
        for _ in 0..n {
            let col = r.str()?.to_string();
            let func = AggFunc::from_code(r.u8()?)?;
            aggs.push(Aggregate { func, col });
        }
        let n = r.u32()? as usize;
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            keys.push(r.str()?.to_string());
        }
        let n = r.u32()? as usize;
        let mut sort = Vec::with_capacity(n);
        for _ in 0..n {
            sort.push(SortKey::decode_from(&mut r)?);
        }
        let limit = match r.u8()? {
            0 => None,
            1 => Some(r.u64()?),
            o => return Err(Error::Corrupt(format!("bad limit tag {o}"))),
        };
        let zone_maps = r.u8()? != 0;
        Ok(PipelineSpec {
            predicate,
            projection,
            aggs,
            keys,
            sort,
            limit,
            zone_maps,
        })
    }

    /// Does any aggregate need raw values shipped back (holistic
    /// finalization at the driver)?
    pub fn any_holistic(&self) -> bool {
        self.aggs.iter().any(|a| !a.func.is_algebraic())
    }
}

// ---- shared row ordering ---------------------------------------------------

/// One extracted sort-key column: floats compared with `total_cmp` (NaN
/// sorts after +inf, deterministically in every execution mode), i64
/// natively (no f64 widening — values beyond 2^53 must keep their
/// order), strings lexicographically.
enum KeyVals<'a> {
    Num(Vec<f64>),
    Int(&'a [i64]),
    Str(&'a [String]),
}

/// Stable sort of a batch's rows by `keys`. Shared by the storage-side
/// partial top-k (`skyhook.exec`) and the driver's merge-side sort, so
/// pushed-down and client-side executions order rows identically.
pub fn sort_rows(batch: &Batch, keys: &[SortKey]) -> Result<Batch> {
    // Resolve keys first: a missing sort column errors regardless of row
    // count, so error behavior never depends on how many rows matched.
    let mut cols = Vec::with_capacity(keys.len());
    for k in keys {
        let kv = match batch.col(&k.col)? {
            Column::Str(v) => KeyVals::Str(v),
            Column::F32(v) => KeyVals::Num(v.iter().map(|&x| x as f64).collect()),
            Column::F64(v) => KeyVals::Num(v.clone()),
            Column::I64(v) => KeyVals::Int(v),
        };
        cols.push((kv, k.desc));
    }
    if cols.is_empty() || batch.nrows() <= 1 {
        return Ok(batch.clone());
    }
    let mut idx: Vec<usize> = (0..batch.nrows()).collect();
    idx.sort_by(|&a, &b| {
        for (kv, desc) in &cols {
            let o = match kv {
                KeyVals::Num(v) => v[a].total_cmp(&v[b]),
                KeyVals::Int(v) => v[a].cmp(&v[b]),
                KeyVals::Str(v) => v[a].cmp(&v[b]),
            };
            let o = if *desc { o.reverse() } else { o };
            if o != std::cmp::Ordering::Equal {
                return o;
            }
        }
        std::cmp::Ordering::Equal
    });
    batch.take(&idx)
}

/// Sort by `keys` and keep the first `n` rows — the per-object partial
/// of the TopK operator (with empty `keys`: plain head(n)).
pub fn top_k_rows(batch: &Batch, keys: &[SortKey], n: usize) -> Result<Batch> {
    let sorted = sort_rows(batch, keys)?;
    if sorted.nrows() > n {
        sorted.slice(0, n)
    } else {
        Ok(sorted)
    }
}

/// Grouped multi-aggregate partials over a masked batch: multi-column
/// i64 key → one [`AggState`] per aggregate, sorted by key. Shared by
/// the storage-side `skyhook.exec` handler and the client-side worker,
/// so both execution modes fold the exact same arithmetic sequence and
/// produce bit-identical partials. The per-row key is probed through a
/// reused scratch buffer; an owned key is allocated only on the first
/// row of a new group.
pub fn grouped_partials(
    batch: &Batch,
    mask: &[bool],
    keys: &[String],
    aggs: &[Aggregate],
) -> Result<Vec<(Vec<i64>, Vec<AggState>)>> {
    let mut keycols: Vec<&[i64]> = Vec::with_capacity(keys.len());
    for k in keys {
        match batch.col(k)? {
            Column::I64(v) => keycols.push(v),
            _ => return Err(Error::Query("group_by needs an i64 column".into())),
        }
    }
    let valcols: Vec<&Column> = aggs
        .iter()
        .map(|a| batch.col(&a.col))
        .collect::<Result<_>>()?;
    let mut groups: BTreeMap<Vec<i64>, Vec<AggState>> = BTreeMap::new();
    let mut scratch: Vec<i64> = Vec::with_capacity(keys.len());
    for (i, &keep) in mask.iter().enumerate() {
        if !keep {
            continue;
        }
        scratch.clear();
        scratch.extend(keycols.iter().map(|k| k[i]));
        if !groups.contains_key(scratch.as_slice()) {
            groups.insert(
                scratch.clone(),
                aggs.iter()
                    .map(|a| AggState::new(!a.func.is_algebraic()))
                    .collect(),
            );
        }
        let states = groups
            .get_mut(scratch.as_slice())
            .expect("group inserted above");
        for (st, col) in states.iter_mut().zip(&valcols) {
            st.update(col.get_f64(i)?);
        }
    }
    Ok(groups.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::dataset::{DType, TableSchema};
    use crate::skyhook::query::CmpOp;

    #[test]
    fn builder_chain_flattens_to_query() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 10.0))
            .filter(Predicate::cmp("ts", CmpOp::Lt, 100.0))
            .project(&["ts", "val"])
            .sort(vec![SortKey::desc("val")])
            .limit(5);
        let q = lp.to_query().unwrap();
        assert_eq!(q.dataset, "t");
        // Two filters AND-merge in order.
        assert_eq!(
            q.predicate,
            Predicate::cmp("val", CmpOp::Gt, 10.0).and(Predicate::cmp("ts", CmpOp::Lt, 100.0))
        );
        assert_eq!(
            q.projection,
            Some(vec!["ts".to_string(), "val".to_string()])
        );
        assert_eq!(q.sort_keys, vec![SortKey::desc("val")]);
        assert_eq!(q.limit, Some(5));
        // And the round trip through Query::logical is the identity on
        // the flat form (filters already merged → single Filter node).
        assert_eq!(q.logical().to_query().unwrap(), q);
    }

    #[test]
    fn aggregate_chain_and_top_k() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(
                vec![
                    Aggregate::new(AggFunc::Sum, "val"),
                    Aggregate::new(AggFunc::Count, "val"),
                ],
                &["sensor", "flag"],
            );
        let q = lp.to_query().unwrap();
        assert_eq!(q.aggregates.len(), 2);
        assert_eq!(q.group_by, vec!["sensor", "flag"]);
        assert_eq!(q.logical().to_query().unwrap(), q);

        let topk = LogicalPlan::scan("t").top_k(vec![SortKey::desc("val")], 3);
        let q = topk.to_query().unwrap();
        assert_eq!(q.sort_keys, vec![SortKey::desc("val")]);
        assert_eq!(q.limit, Some(3));
        assert_eq!(q.logical().to_query().unwrap(), q);
    }

    #[test]
    fn illegal_shapes_are_rejected() {
        let agg = LogicalPlan::scan("t").aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &[]);
        assert!(agg
            .clone()
            .filter(Predicate::cmp("v", CmpOp::Gt, 0.0))
            .to_query()
            .is_err());
        assert!(agg.clone().project(&["v"]).to_query().is_err());
        assert!(agg
            .clone()
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &[])
            .to_query()
            .is_err());
        assert!(agg.clone().sort(vec![SortKey::asc("v")]).to_query().is_err());
        // Limit over aggregate output is shape-valid in the IR (it
        // truncates group rows; the planner rejects it for scalar
        // aggregates, where there is nothing to truncate).
        assert!(agg.limit(3).to_query().is_ok());
        let grouped = LogicalPlan::scan("t")
            .aggregate(vec![Aggregate::new(AggFunc::Sum, "v")], &["k"])
            .limit(3)
            .to_query()
            .unwrap();
        assert_eq!(grouped.limit, Some(3));
        // Sort above limit flips semantics → rejected.
        assert!(LogicalPlan::scan("t")
            .limit(3)
            .sort(vec![SortKey::asc("v")])
            .to_query()
            .is_err());
        // Empty sorts/aggregates and duplicate projections.
        assert!(LogicalPlan::scan("t").sort(vec![]).to_query().is_err());
        assert!(LogicalPlan::scan("t")
            .aggregate(vec![], &[])
            .to_query()
            .is_err());
        assert!(LogicalPlan::scan("t")
            .project(&["a"])
            .project(&["a"])
            .to_query()
            .is_err());
    }

    #[test]
    fn explain_tree_lists_operators_top_down() {
        let lp = LogicalPlan::scan("t")
            .filter(Predicate::cmp("val", CmpOp::Gt, 10.0))
            .project(&["ts", "val"])
            .top_k(vec![SortKey::desc("val")], 8);
        let text = lp.explain_tree();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("TopK 8"));
        assert!(lines[1].trim_start().starts_with("Project"));
        assert!(lines[2].trim_start().starts_with("Filter"));
        assert!(lines[3].trim_start().starts_with("Scan t"));
    }

    #[test]
    fn pipeline_spec_wire_roundtrip() {
        let spec = PipelineSpec {
            predicate: Predicate::cmp("val", CmpOp::Gt, 1.5)
                .and(Predicate::cmp("ts", CmpOp::Ne, 0.0)),
            projection: Some(vec!["ts".to_string(), "val".to_string()]),
            aggs: vec![
                Aggregate::new(AggFunc::Mean, "val"),
                Aggregate::new(AggFunc::Median, "val"),
            ],
            keys: vec!["sensor".to_string(), "flag".to_string()],
            sort: vec![SortKey::desc("val"), SortKey::asc("ts")],
            limit: Some(17),
            zone_maps: true,
        };
        let dec = PipelineSpec::decode(&spec.encode()).unwrap();
        assert_eq!(dec, spec);
        assert!(dec.any_holistic());
        let plain = PipelineSpec {
            predicate: Predicate::True,
            projection: None,
            aggs: vec![],
            keys: vec![],
            sort: vec![],
            limit: None,
            zone_maps: false,
        };
        assert_eq!(PipelineSpec::decode(&plain.encode()).unwrap(), plain);
        assert!(!plain.any_holistic());
        assert!(PipelineSpec::decode(b"\xff\xff").is_err());
    }

    #[test]
    fn sort_rows_orders_and_is_stable() {
        let b = Batch::new(
            TableSchema::new(&[("k", DType::I64), ("v", DType::F32), ("s", DType::Str)]),
            vec![
                Column::I64(vec![2, 1, 2, 1]),
                Column::F32(vec![10.0, 20.0, 30.0, 20.0]),
                Column::Str(vec!["b".into(), "a".into(), "c".into(), "d".into()]),
            ],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("k")]).unwrap();
        assert_eq!(s.col("k").unwrap(), &Column::I64(vec![1, 1, 2, 2]));
        // Stability: equal keys keep original order.
        assert_eq!(
            s.col("s").unwrap(),
            &Column::Str(vec!["a".into(), "d".into(), "b".into(), "c".into()])
        );
        // Secondary key + descending.
        let s = sort_rows(&b, &[SortKey::asc("k"), SortKey::desc("v")]).unwrap();
        assert_eq!(s.col("v").unwrap(), &Column::F32(vec![20.0, 20.0, 30.0, 10.0]));
        // String sort.
        let s = sort_rows(&b, &[SortKey::desc("s")]).unwrap();
        assert_eq!(
            s.col("s").unwrap(),
            &Column::Str(vec!["d".into(), "c".into(), "b".into(), "a".into()])
        );
        // Missing column errors — even on empty or single-row batches.
        assert!(sort_rows(&b, &[SortKey::asc("ghost")]).is_err());
        let empty = Batch::empty(&b.schema);
        assert!(sort_rows(&empty, &[SortKey::asc("ghost")]).is_err());
        assert!(top_k_rows(&empty, &[SortKey::asc("k")], 3).unwrap().nrows() == 0);
    }

    #[test]
    fn sort_rows_i64_keys_beyond_f64_precision() {
        // Adjacent nanosecond-scale timestamps collapse to the same f64;
        // i64 keys must compare natively.
        let base = 1_700_000_000_000_000_000i64; // > 2^53
        let b = Batch::new(
            TableSchema::new(&[("ts", DType::I64)]),
            vec![Column::I64(vec![base + 2, base + 1, base + 3, base])],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("ts")]).unwrap();
        assert_eq!(
            s.col("ts").unwrap(),
            &Column::I64(vec![base, base + 1, base + 2, base + 3])
        );
        let t = top_k_rows(&b, &[SortKey::desc("ts")], 2).unwrap();
        assert_eq!(
            t.col("ts").unwrap(),
            &Column::I64(vec![base + 3, base + 2])
        );
    }

    #[test]
    fn sort_rows_total_order_on_nan() {
        let b = Batch::new(
            TableSchema::new(&[("v", DType::F32)]),
            vec![Column::F32(vec![f32::NAN, 1.0, -2.0, f32::NAN, 0.5])],
        )
        .unwrap();
        let s = sort_rows(&b, &[SortKey::asc("v")]).unwrap();
        let Column::F32(v) = s.col("v").unwrap() else {
            unreachable!()
        };
        assert_eq!(&v[..3], &[-2.0, 0.5, 1.0]);
        assert!(v[3].is_nan() && v[4].is_nan());
        // Deterministic: sorting twice gives bit-identical output.
        let s2 = sort_rows(&b, &[SortKey::asc("v")]).unwrap();
        let Column::F32(v2) = s2.col("v").unwrap() else {
            unreachable!()
        };
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn top_k_rows_truncates_after_sort() {
        let b = gen::sensor_table(100, 5);
        let t = top_k_rows(&b, &[SortKey::desc("val")], 10).unwrap();
        assert_eq!(t.nrows(), 10);
        let Column::F32(v) = t.col("val").unwrap() else {
            unreachable!()
        };
        assert!(v.windows(2).all(|w| w[0] >= w[1]));
        // n larger than the batch: everything, still sorted.
        let t = top_k_rows(&b, &[SortKey::asc("ts")], 500).unwrap();
        assert_eq!(t.nrows(), 100);
        // Head without keys preserves row order.
        let h = top_k_rows(&b, &[], 7).unwrap();
        assert_eq!(h, b.slice(0, 7).unwrap());
    }
}
