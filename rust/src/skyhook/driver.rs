//! Skyhook-Driver (§4.2, Figure 4): accepts queries, generates object
//! names and sub-queries, schedules them over the worker pool, and
//! aggregates the partial results — the Dask-scheduler stand-in.

use super::plan::{plan_opts, ExecMode, QueryPlan};
use super::query::{AggState, Query};
use super::worker::{self, SubOutput, SubResult};
use crate::config::DriverConfig;
use crate::dataset::metadata::{self, ColumnStats, DatasetMeta, RowGroupMeta};
use crate::dataset::naming;
use crate::dataset::partition::PartitionSpec;
use crate::dataset::table::Batch;
use crate::dataset::Layout;
use crate::error::{Error, Result};
use crate::simnet::Timeline;
use crate::store::Cluster;
use crate::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::Instant;

/// Execution statistics of one query (feeds the E2/E5/E6 benches and the
/// CLI's reporting).
#[derive(Clone, Debug, Default)]
pub struct QueryStats {
    /// Bytes that crossed the client↔storage network.
    pub bytes_moved: u64,
    /// Virtual makespan (seconds) from dispatch to last sub-result.
    pub sim_seconds: f64,
    /// Wall-clock seconds spent executing.
    pub wall_seconds: f64,
    /// Number of objects touched.
    pub objects: usize,
    /// Objects the planner dropped via zone-map pruning — no request was
    /// issued for them at all.
    pub objects_pruned: usize,
    /// Serialized bytes of the pruned objects: I/O and decode work that
    /// provably could not contribute to the result and was skipped.
    pub bytes_skipped: u64,
    /// Execution mode used.
    pub pushdown: bool,
}

/// Result of a query.
#[derive(Debug)]
pub struct QueryResult {
    /// Returned rows (row queries).
    pub rows: Option<Batch>,
    /// Finalized aggregate values, parallel to `query.aggregates`.
    pub aggregates: Vec<f64>,
    /// Group-by results: (key, finalized value) sorted by key.
    pub groups: Option<Vec<(i64, f64)>>,
    pub stats: QueryStats,
}

/// Result of a table write.
#[derive(Clone, Debug)]
pub struct WriteReport {
    pub objects: usize,
    pub bytes_written: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
}

/// The driver: owns the worker pool and per-worker virtual CPU timelines.
pub struct Driver {
    cluster: Arc<Cluster>,
    pool: ThreadPool,
    worker_cpus: Vec<Arc<Timeline>>,
    cfg: DriverConfig,
}

impl Driver {
    pub fn new(cluster: Arc<Cluster>, cfg: DriverConfig) -> Self {
        let workers = cfg.workers.max(1);
        Self {
            cluster,
            pool: ThreadPool::new(workers),
            worker_cpus: (0..workers).map(|_| Arc::new(Timeline::new())).collect(),
            cfg,
        }
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn workers(&self) -> usize {
        self.worker_cpus.len()
    }

    /// Reset virtual time (between bench cases).
    pub fn reset_time(&self) {
        for t in &self.worker_cpus {
            t.reset();
        }
        self.cluster.reset_time();
    }

    // ---- write path -------------------------------------------------------

    /// Partition a table into row-group objects and store it. `locality`
    /// optionally assigns each row group a placement group key (§3.1).
    pub fn write_table(
        &self,
        dataset: &str,
        batch: &Batch,
        layout: Layout,
        spec: &PartitionSpec,
        locality: Option<&dyn Fn(usize, &Batch) -> String>,
    ) -> Result<WriteReport> {
        if metadata::load_meta(&self.cluster, 0.0, dataset).is_ok() {
            return Err(Error::AlreadyExists(format!("dataset {dataset}")));
        }
        let wall = Instant::now();
        let groups = spec.partition(batch)?;
        let localities: Vec<String> = groups
            .iter()
            .enumerate()
            .map(|(i, g)| locality.map(|f| f(i, g)).unwrap_or_default())
            .collect();

        // Fan the group writes out over the worker pool. Items move into
        // the pool (no batch clones); only the count is kept back.
        let cluster = Arc::clone(&self.cluster);
        let items: Vec<(usize, Batch, String)> = groups
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                let base = naming::table_object(dataset, i as u64);
                let name = if localities[i].is_empty() {
                    base
                } else {
                    naming::with_locality(&localities[i], &base)
                };
                (i, g, name)
            })
            .collect();
        let objects = items.len();
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let results: Vec<Result<(u64, u64, f64, Vec<ColumnStats>)>> =
            self.pool.map(items, move |(i, g, name)| {
                let cpu = &worker_cpus[i % nw];
                let (bytes, finish, stats) =
                    worker::write_row_group(&cluster, &name, &g, layout, 0.0, cpu)?;
                Ok((g.nrows() as u64, bytes, finish, stats))
            });

        let mut row_groups = Vec::with_capacity(objects);
        let mut bytes_written = 0u64;
        let mut sim_finish: f64 = 0.0;
        for r in results {
            let (rows, bytes, finish, stats) = r?;
            row_groups.push(RowGroupMeta { rows, bytes, stats });
            bytes_written += bytes;
            sim_finish = sim_finish.max(finish);
        }

        let meta = DatasetMeta::Table {
            schema: batch.schema.clone(),
            layout,
            row_groups,
            localities,
        };
        let t = metadata::save_meta(&self.cluster, sim_finish, dataset, &meta, false)?;
        Ok(WriteReport {
            objects,
            bytes_written,
            sim_seconds: t,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    // ---- read path ----------------------------------------------------------

    /// Plan and execute a query (zone-map pruning enabled). `force_mode`
    /// lets benches compare pushdown vs client-side on identical queries.
    pub fn execute(&self, query: &Query, force_mode: Option<ExecMode>) -> Result<QueryResult> {
        self.execute_opts(query, force_mode, true)
    }

    /// [`Driver::execute`] with zone-map pruning optionally disabled —
    /// the unpruned baseline the pruning benches compare against.
    pub fn execute_opts(
        &self,
        query: &Query,
        force_mode: Option<ExecMode>,
        prune: bool,
    ) -> Result<QueryResult> {
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, &query.dataset)?;
        let plan = plan_opts(query, &meta, force_mode, prune)?;
        self.execute_plan(&plan)
    }

    /// Execute a prepared plan.
    pub fn execute_plan(&self, plan: &QueryPlan) -> Result<QueryResult> {
        let wall = Instant::now();
        let at = self.cluster.clock.now();
        let query = &plan.query;
        let cluster = Arc::clone(&self.cluster);
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let subs: Vec<(usize, super::plan::SubQuery)> = plan
            .subqueries
            .iter()
            .cloned()
            .enumerate()
            .collect();
        let objects = subs.len();
        // One deep clone shared by every pool worker.
        let q = Arc::new(query.clone());
        let results: Vec<Result<SubResult>> = self.pool.map(subs, move |(i, sub)| {
            worker::execute_subquery(&cluster, &q, &sub, at, &worker_cpus[i % nw])
        });

        // Gather.
        let mut bytes_moved = 0u64;
        let mut sim_finish = at;
        let mut rows: Option<Batch> = None;
        let mut agg_states: Vec<AggState> = Vec::new();
        let mut groups: std::collections::BTreeMap<i64, AggState> = Default::default();
        for r in results {
            let r = r?;
            bytes_moved += r.bytes_moved;
            sim_finish = sim_finish.max(r.finish);
            match r.output {
                SubOutput::Rows(b) => match &mut rows {
                    Some(acc) => acc.concat(&b)?,
                    None => rows = Some(b),
                },
                SubOutput::Aggs(states) => {
                    if agg_states.is_empty() {
                        agg_states = states;
                    } else {
                        if states.len() != agg_states.len() {
                            return Err(Error::Query("partial arity mismatch".into()));
                        }
                        for (acc, s) in agg_states.iter_mut().zip(&states) {
                            acc.merge(s);
                        }
                    }
                }
                SubOutput::Groups(gs) => {
                    for (k, s) in gs {
                        groups
                            .entry(k)
                            .and_modify(|acc| acc.merge(&s))
                            .or_insert(s);
                    }
                }
            }
        }

        // Finalize. A dataset with zero objects still answers aggregate
        // queries (empty states).
        if query.is_aggregate() && agg_states.is_empty() {
            agg_states = vec![AggState::new(false); query.aggregates.len()];
        }
        let aggregates: Vec<f64> = if query.group_by.is_none() {
            query
                .aggregates
                .iter()
                .zip(&agg_states)
                .map(|(a, s)| s.finalize(a.func))
                .collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let group_out = if query.group_by.is_some() {
            let func = query.aggregates[0].func;
            Some(
                groups
                    .into_iter()
                    .map(|(k, s)| s.finalize(func).map(|v| (k, v)))
                    .collect::<Result<Vec<_>>>()?,
            )
        } else {
            None
        };

        // Row queries always return a batch — when every sub-query was
        // pruned (or the dataset has zero objects), synthesize an empty
        // batch with the projected schema so pruned and unpruned
        // executions are indistinguishable to callers.
        let rows = if query.is_aggregate() {
            None
        } else {
            Some(match rows {
                Some(b) => b,
                None => {
                    let schema = match &query.projection {
                        Some(cols) => {
                            let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                            plan.schema.project(&refs)?
                        }
                        None => plan.schema.clone(),
                    };
                    Batch::empty(&schema)
                }
            })
        };

        let pushdown = plan.mode == ExecMode::Pushdown;
        Ok(QueryResult {
            rows,
            aggregates,
            groups: group_out,
            stats: QueryStats {
                bytes_moved,
                sim_seconds: sim_finish - at,
                wall_seconds: wall.elapsed().as_secs_f64(),
                objects,
                objects_pruned: plan.objects_pruned,
                bytes_skipped: plan.bytes_skipped,
                pushdown,
            },
        })
    }

    /// Approximate quantile via the §3.2 de-composable approximation:
    /// each object returns a constant-size mergeable sketch, the driver
    /// merges and interpolates. Returns (value, worst-case abs error,
    /// stats). Compare with the exact (holistic) `AggFunc::Median` path,
    /// which ships every filtered value.
    pub fn approx_quantile(
        &self,
        dataset: &str,
        column: &str,
        q: f64,
        predicate: &super::query::Predicate,
    ) -> Result<(f64, f64, QueryStats)> {
        use super::sketch::QuantileSketch;
        let wall = Instant::now();
        let at = self.cluster.clock.now();
        let (meta, _) = metadata::load_meta(&self.cluster, at, dataset)?;
        let names = meta.object_names(dataset);
        let objects = names.len();
        let cluster = Arc::clone(&self.cluster);
        let input = {
            let mut w = crate::util::bytes::ByteWriter::new();
            predicate.encode_into(&mut w);
            w.str(column);
            w.u8(1); // zone-map short-circuit allowed
            w.finish()
        };
        let results: Vec<Result<(QuantileSketch, u64, f64)>> =
            self.pool.map(names, move |obj| {
                let t = cluster.call(at, &obj, "skyhook", "quantile_sketch", &input)?;
                let mut r = crate::util::bytes::ByteReader::new(&t.value);
                let sketch = QuantileSketch::decode_from(&mut r)?;
                Ok((sketch, t.value.len() as u64, t.finish))
            });
        let mut merged = QuantileSketch::empty();
        let mut bytes_moved = 0;
        let mut sim_finish = at;
        for r in results {
            let (s, bytes, finish) = r?;
            merged.merge(&s);
            bytes_moved += bytes;
            sim_finish = sim_finish.max(finish);
        }
        let value = merged.quantile(q)?;
        Ok((
            value,
            2.0 * merged.error_bound(),
            QueryStats {
                bytes_moved,
                sim_seconds: sim_finish - at,
                wall_seconds: wall.elapsed().as_secs_f64(),
                objects,
                pushdown: true,
                ..Default::default()
            },
        ))
    }

    /// Build the omap index on an i64 column of every object of a dataset.
    pub fn build_index(&self, dataset: &str, column: &str) -> Result<u64> {
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let names = meta.object_names(dataset);
        let cluster = Arc::clone(&self.cluster);
        let col = column.to_string();
        let results: Vec<Result<u64>> = self.pool.map(names, move |obj| {
            let mut w = crate::util::bytes::ByteWriter::new();
            w.str(&col);
            let t = cluster.call(0.0, &obj, "skyhook", "build_index", &w.finish())?;
            Ok(u64::from_le_bytes(t.value.try_into().map_err(|_| {
                Error::Corrupt("bad index count".into())
            })?))
        });
        let mut total = 0;
        for r in results {
            total += r?;
        }
        Ok(total)
    }

    /// Transform every object of a dataset to the target layout and update
    /// the dataset metadata (physical design management, §5).
    pub fn transform_layout(&self, dataset: &str, target: Layout) -> Result<WriteReport> {
        let wall = Instant::now();
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        if !matches!(meta, DatasetMeta::Table { .. }) {
            return Err(Error::Query("transform needs a table dataset".into()));
        }
        // Names are derived before destructuring so the meta fields can
        // move into the updated metadata below without cloning.
        let names = meta.object_names(dataset);
        let DatasetMeta::Table {
            schema,
            layout,
            row_groups,
            localities,
        } = meta
        else {
            unreachable!("table kind checked above");
        };
        if layout == target {
            return Ok(WriteReport {
                objects: 0,
                bytes_written: 0,
                sim_seconds: 0.0,
                wall_seconds: wall.elapsed().as_secs_f64(),
            });
        }
        let cluster = Arc::clone(&self.cluster);
        let results: Vec<Result<f64>> = self.pool.map(names, move |obj| {
            let t = cluster.call(
                0.0,
                &obj,
                "skyhook",
                "transform",
                &[match target {
                    Layout::Row => 0u8,
                    Layout::Col => 1u8,
                }],
            )?;
            Ok(t.finish)
        });
        let mut sim = 0.0f64;
        let mut n = 0;
        for r in results {
            sim = sim.max(r?);
            n += 1;
        }
        let meta = DatasetMeta::Table {
            schema,
            layout: target,
            row_groups,
            localities,
        };
        metadata::save_meta(&self.cluster, sim, dataset, &meta, true)?;
        Ok(WriteReport {
            objects: n,
            bytes_written: 0,
            sim_seconds: sim,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    /// Batch size configured for dispatch rounds.
    pub fn batch_size(&self) -> usize {
        self.cfg.batch_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::dataset::table::gen;
    use crate::skyhook::extension::register_skyhook_class;
    use crate::skyhook::query::{AggFunc, CmpOp, Predicate};
    use crate::store::ClassRegistry;

    fn driver(osds: usize, workers: usize) -> Driver {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        let cluster = Cluster::new(
            &ClusterConfig {
                osds,
                replicas: 1,
                ..Default::default()
            },
            reg,
        );
        Driver::new(
            cluster,
            DriverConfig {
                workers,
                ..Default::default()
            },
        )
    }

    fn seed(d: &Driver, rows: usize) -> Batch {
        let b = gen::sensor_table(rows, 99);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(8 * 1024),
            None,
        )
        .unwrap();
        b
    }

    #[test]
    fn write_then_scan_roundtrip() {
        let d = driver(4, 4);
        let b = seed(&d, 2000);
        let r = d.execute(&Query::scan("sensors"), None).unwrap();
        let rows = r.rows.unwrap();
        assert_eq!(rows.nrows(), 2000);
        assert_eq!(rows.schema, b.schema);
        assert!(r.stats.objects > 1, "should span multiple objects");
        assert!(r.stats.pushdown);
        assert!(r.stats.sim_seconds > 0.0);
    }

    #[test]
    fn write_rejects_duplicate_dataset() {
        let d = driver(2, 2);
        seed(&d, 100);
        let b = gen::sensor_table(50, 1);
        assert!(matches!(
            d.write_table("sensors", &b, Layout::Col, &PartitionSpec::default(), None),
            Err(Error::AlreadyExists(_))
        ));
    }

    #[test]
    fn filtered_scan_matches_direct() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        let pred = Predicate::cmp("val", CmpOp::Gt, 60.0);
        let r = d
            .execute(&Query::scan("sensors").filter(pred.clone()).select(&["ts"]), None)
            .unwrap();
        let got = r.rows.unwrap();
        let mask = pred.eval(&b).unwrap();
        assert_eq!(got.nrows(), mask.iter().filter(|&&m| m).count());
        assert_eq!(got.ncols(), 1);
    }

    #[test]
    fn aggregate_matches_direct_and_modes_agree() {
        let d = driver(4, 4);
        let b = seed(&d, 2500);
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(AggFunc::Mean, "val")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Var, "val");
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        for (a, b) in rp.aggregates.iter().zip(&rc.aggregates) {
            assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
        // Direct.
        let mask = q.predicate.eval(&b).unwrap();
        let mut st = AggState::new(false);
        st.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert!((rp.aggregates[0] - st.finalize(AggFunc::Mean).unwrap()).abs() < 1e-6);
        assert_eq!(rp.aggregates[1], st.count as f64);
        // Pushdown moves much less data for aggregates.
        assert!(rp.stats.bytes_moved * 5 < rc.stats.bytes_moved);
    }

    #[test]
    fn pruned_and_unpruned_execution_agree() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        // ts is sorted 0..3000, so a narrow range query prunes most
        // row-group objects at the planner.
        let pred = Predicate::cmp("ts", CmpOp::Lt, 100.0);
        let rq = Query::scan("sensors").filter(pred.clone()).select(&["ts", "val"]);
        let rp = d.execute(&rq, None).unwrap();
        let ru = d.execute_opts(&rq, None, false).unwrap();
        assert!(rp.stats.objects_pruned > 0, "nothing pruned");
        assert!(rp.stats.bytes_skipped > 0);
        assert_eq!(ru.stats.objects_pruned, 0);
        assert!(rp.stats.objects < ru.stats.objects);
        // Bit-identical rows.
        assert_eq!(rp.rows.unwrap(), ru.rows.unwrap());
        // Aggregates agree exactly too (pruned partials are a prefix of
        // the unpruned merge; empty states are merge identities).
        let aq = Query::scan("sensors")
            .filter(pred.clone())
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Sum, "val");
        let ap = d.execute(&aq, None).unwrap();
        let au = d.execute_opts(&aq, None, false).unwrap();
        assert_eq!(ap.aggregates, au.aggregates);
        assert_eq!(ap.aggregates[0], 100.0);
        assert!(ap.stats.bytes_moved < au.stats.bytes_moved);
        // Direct check against the source batch.
        let mask = pred.eval(&b).unwrap();
        let mut st = AggState::new(false);
        st.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert!((ap.aggregates[1] - st.sum).abs() < 1e-6);
    }

    #[test]
    fn fully_pruned_query_returns_empty_not_missing() {
        let d = driver(3, 2);
        seed(&d, 500);
        // ts never reaches 10^9: every object prunes.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .select(&["val"]);
        let r = d.execute(&q, None).unwrap();
        let rows = r.rows.unwrap();
        assert_eq!(rows.nrows(), 0);
        assert_eq!(rows.ncols(), 1);
        assert_eq!(rows.schema.columns[0].name, "val");
        assert_eq!(r.stats.objects, 0);
        assert!(r.stats.objects_pruned > 0);
        assert_eq!(r.stats.bytes_moved, 0);
        // Unpruned execution of the same dead query returns the same
        // (empty) result the long way around.
        let u = d.execute_opts(&q, None, false).unwrap();
        assert_eq!(u.rows.unwrap(), rows);
        // Aggregates over a fully pruned dataset behave like an empty set.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        assert_eq!(r.aggregates[0], 0.0);
        // Group-by: empty group list, same as unpruned.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .group("sensor")
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        assert_eq!(r.groups.unwrap(), vec![]);
    }

    #[test]
    fn median_is_correct_despite_holistic() {
        let d = driver(4, 4);
        let b = seed(&d, 1001);
        let q = Query::scan("sensors").aggregate(AggFunc::Median, "val");
        let r = d.execute(&q, None).unwrap();
        // Direct median.
        let mut vals: Vec<f64> = match b.col("val").unwrap() {
            crate::dataset::table::Column::F32(v) => {
                v.iter().map(|&x| x as f64).collect()
            }
            _ => unreachable!(),
        };
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want = vals[vals.len() / 2];
        assert!((r.aggregates[0] - want).abs() < 1e-9);
        // Holistic: bytes scale with rows.
        assert!(r.stats.bytes_moved > 1001 * 8);
    }

    #[test]
    fn group_by_matches_direct() {
        let d = driver(4, 4);
        let b = seed(&d, 2000);
        let q = Query::scan("sensors")
            .group("sensor")
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        let groups = r.groups.unwrap();
        let total: f64 = groups.iter().map(|(_, v)| v).sum();
        assert_eq!(total, 2000.0);
        // Direct group count for one key.
        let keys = match b.col("sensor").unwrap() {
            crate::dataset::table::Column::I64(v) => v.clone(),
            _ => unreachable!(),
        };
        let k0 = groups[0].0;
        let want = keys.iter().filter(|&&k| k == k0).count() as f64;
        assert_eq!(groups[0].1, want);
    }

    #[test]
    fn missing_dataset_errors() {
        let d = driver(2, 2);
        assert!(d.execute(&Query::scan("ghost"), None).is_err());
    }

    #[test]
    fn approx_quantile_matches_exact_within_bound() {
        let d = driver(4, 4);
        seed(&d, 20_000);
        let pred = Predicate::cmp("flag", CmpOp::Eq, 0.0);
        let exact = d
            .execute(
                &Query::scan("sensors")
                    .filter(pred.clone())
                    .aggregate(AggFunc::Median, "val"),
                None,
            )
            .unwrap();
        let (approx, bound, stats) = d.approx_quantile("sensors", "val", 0.5, &pred).unwrap();
        assert!(
            (approx - exact.aggregates[0]).abs() <= 2.0 * bound,
            "approx {approx} exact {} bound {bound}",
            exact.aggregates[0]
        );
        // The approximation is decomposable: per-object partials are
        // constant-size (bounded by the bin count), unlike the exact
        // path whose bytes grow with matching rows.
        assert!(
            stats.bytes_moved < exact.stats.bytes_moved,
            "sketch {} vs exact {}",
            stats.bytes_moved,
            exact.stats.bytes_moved
        );
        let per_object = stats.bytes_moved as usize / stats.objects.max(1);
        assert!(
            per_object <= crate::skyhook::sketch::BINS * 10 + 64,
            "sketch partial not constant-size: {per_object} B/object"
        );
        // Errors propagate.
        assert!(d
            .approx_quantile("sensors", "nope", 0.5, &Predicate::True)
            .is_err());
        assert!(d
            .approx_quantile("ghost", "val", 0.5, &Predicate::True)
            .is_err());
    }

    #[test]
    fn build_index_counts_rows() {
        let d = driver(3, 2);
        seed(&d, 1200);
        let total = d.build_index("sensors", "sensor").unwrap();
        assert_eq!(total, 1200);
        assert!(d.build_index("sensors", "val").is_err(), "f32 not indexable");
    }

    #[test]
    fn transform_layout_roundtrip() {
        let d = driver(3, 2);
        let b = seed(&d, 800);
        let rep = d.transform_layout("sensors", Layout::Row).unwrap();
        assert!(rep.objects > 0);
        // Query still works and agrees after transform.
        let r = d.execute(&Query::scan("sensors"), None).unwrap();
        assert_eq!(r.rows.unwrap().nrows(), b.nrows());
        // No-op transform.
        let rep2 = d.transform_layout("sensors", Layout::Row).unwrap();
        assert_eq!(rep2.objects, 0);
    }

    #[test]
    fn locality_assignment_places_groups_together() {
        let d = driver(4, 2);
        let b = gen::sensor_table(2000, 5);
        d.write_table(
            "loc",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(4 * 1024),
            Some(&|i, _| format!("bucket{}", i % 2)),
        )
        .unwrap();
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "loc").unwrap();
        let names = meta.object_names("loc");
        // All bucket0 objects share a placement, likewise bucket1.
        let p0: Vec<_> = names
            .iter()
            .filter(|n| n.starts_with("bucket0#"))
            .map(|n| d.cluster().placement(n))
            .collect();
        assert!(p0.len() > 1);
        assert!(p0.windows(2).all(|w| w[0] == w[1]), "bucket0 not co-located");
        // Query still reads everything.
        let r = d.execute(&Query::scan("loc"), None).unwrap();
        assert_eq!(r.rows.unwrap().nrows(), 2000);
    }

    #[test]
    fn more_osds_reduce_sim_makespan() {
        let rows = 20_000;
        let mut sims = Vec::new();
        for osds in [1, 4] {
            let d = driver(osds, 4);
            let b = gen::sensor_table(rows, 7);
            d.write_table(
                "ds",
                &b,
                Layout::Col,
                &PartitionSpec::with_target(16 * 1024),
                None,
            )
            .unwrap();
            d.reset_time();
            let r = d
                .execute(&Query::scan("ds").aggregate(AggFunc::Sum, "val"), None)
                .unwrap();
            sims.push(r.stats.sim_seconds);
        }
        assert!(
            sims[1] < sims[0] * 0.6,
            "4 OSDs should beat 1: {sims:?}"
        );
    }
}
