//! Skyhook-Driver (§4.2, Figure 4): accepts queries, generates object
//! names and sub-queries, schedules them over the worker pool, and
//! aggregates the partial results — the Dask-scheduler stand-in.

use super::logical::{merge_sorted, sort_rows};
use super::plan::{
    access_path_forced, group_prunes, plan_with_access, AccessForce, CalibrationMap, ExecMode,
    QueryPlan,
};
use super::query::{AggState, Predicate, Query};
use super::worker::{self, SubOutput, SubResult};
use crate::config::DriverConfig;
use crate::dataset::metadata::{self, ColumnStats, DatasetMeta, RowGroupMeta};
use crate::dataset::naming;
use crate::dataset::partition::PartitionSpec;
use crate::dataset::table::Batch;
use crate::dataset::{DType, Layout};
use crate::error::{Error, Result};
use crate::simnet::{CostParams, Timeline};
use crate::store::Cluster;
use crate::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::Instant;

/// Execution statistics of one query (feeds the E2/E5/E6 benches and the
/// CLI's reporting).
#[derive(Clone, Debug, Default)]
pub struct QueryStats {
    /// Bytes that crossed the client↔storage network.
    pub bytes_moved: u64,
    /// Virtual makespan (seconds) from dispatch to last sub-result.
    pub sim_seconds: f64,
    /// Wall-clock seconds spent executing.
    pub wall_seconds: f64,
    /// Number of objects touched.
    pub objects: usize,
    /// Objects the planner dropped via zone-map pruning — no request was
    /// issued for them at all.
    pub objects_pruned: usize,
    /// Serialized bytes of the pruned objects: I/O and decode work that
    /// provably could not contribute to the result and was skipped.
    pub bytes_skipped: u64,
    /// Ranged reads saved by coalescing adjacent column extents into one
    /// read (client-side partial-read scans only; pushdown coalesces on
    /// the storage device instead).
    pub reads_coalesced: u64,
    /// Sub-queries served as **bounded prefix reads** — head / ascending
    /// top-k over a column whose sortedness marker is stamped, where the
    /// partial is just the object's first k rows (the clustered layout's
    /// payoff, counted on whichever side executed).
    pub prefix_reads: u64,
    /// Rows the kernel's filter never charged for because a sortedness
    /// marker let it binary-search the matching run's boundaries on a
    /// range predicate.
    pub rows_short_circuited: u64,
    /// Fixed-size chunks the storage servers' **compiled execution
    /// tier** launched across all pushed-down sub-queries. Zero when the
    /// cluster's cost profile has the tier disabled, when the plan shape
    /// is ineligible, or when everything ran client-side (the client
    /// always runs the scalar kernel).
    pub compiled_chunks: u64,
    /// Rows covered by those compiled-tier chunks.
    pub compiled_rows: u64,
    /// Secondary-index probes the storage servers issued: sub-queries the
    /// planner routed through the IndexScan access path, each answered by
    /// one `scan_range` over the object's `ix1` postings. Always zero for
    /// client-side sub-queries (the index lives on the OSD).
    pub index_probes: u64,
    /// Postings those probes returned — the pre-mask population the
    /// kernel then re-filtered with the full predicate.
    pub index_postings: u64,
    /// Client-side sub-queries served from the **shared-scan cache**: a
    /// concurrent in-flight query had already fetched and decoded the
    /// same `(object, columns, prefix)` batch, so this one reused it and
    /// moved zero bytes. Always zero for serial workloads — the cache
    /// only lives while queries overlap.
    pub shared_scan_hits: u64,
    /// Overall execution mode the planner chose (or was forced to).
    pub pushdown: bool,
    /// Sub-queries the cost model assigned to the storage servers.
    pub objects_pushdown: usize,
    /// Sub-queries the cost model assigned to client-side execution.
    pub objects_client: usize,
    /// The planner's bytes-moved estimate for the chosen assignment —
    /// compare against `bytes_moved` to judge the cost model.
    pub bytes_estimated: u64,
    /// Observed `bytes_moved / bytes_estimated` of this execution
    /// (`None` when nothing was estimated or nothing moved). The driver
    /// feeds it into its per-column [`CalibrationMap`] so subsequent
    /// plans estimate closer to reality.
    ///
    /// [`CalibrationMap`]: super::plan::CalibrationMap
    pub est_ratio: Option<f64>,
}

/// Result of a query.
#[derive(Debug)]
pub struct QueryResult {
    /// Returned rows (row queries), already merged, sorted, limited and
    /// projected per the plan's merge-side stages.
    pub rows: Option<Batch>,
    /// Finalized aggregate values, parallel to `query.aggregates`
    /// (scalar aggregation only).
    pub aggregates: Vec<f64>,
    /// Group-by results, sorted by key: multi-column key → one finalized
    /// value per aggregate (parallel to `query.aggregates`).
    pub groups: Option<Vec<(Vec<i64>, Vec<f64>)>>,
    pub stats: QueryStats,
}

/// Result of a table write.
#[derive(Clone, Debug)]
pub struct WriteReport {
    pub objects: usize,
    pub bytes_written: u64,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
}

/// The driver: owns the worker pool, per-worker virtual CPU timelines,
/// and the per-column selectivity calibration learned from executed
/// queries (planner follow-up c).
pub struct Driver {
    cluster: Arc<Cluster>,
    pool: ThreadPool,
    worker_cpus: Vec<Arc<Timeline>>,
    cfg: DriverConfig,
    calibration: std::sync::RwLock<CalibrationMap>,
    /// Shared-scan batching across concurrent queries (see
    /// [`worker::ScanCache`]). Entries live only while queries overlap:
    /// `active_queries` counts executions in flight and the cache is
    /// cleared when it returns to zero (and on every write), so serial
    /// workloads — including back-to-back identical benches — always
    /// meter real fetches.
    scan_cache: Arc<worker::ScanCache>,
    active_queries: std::sync::atomic::AtomicUsize,
    /// Lifetime re-clustering compactions this driver committed (feeds
    /// the serve layer's `driver.compactions` gauge).
    compactions: std::sync::atomic::AtomicU64,
}

/// Counts one query out of [`Driver::active_queries`] on drop (panic-
/// safe) and clears the shared-scan cache when the count hits zero —
/// cache entries live exactly as long as some query overlaps them.
struct ActiveQueryGuard<'a> {
    driver: &'a Driver,
}

impl Drop for ActiveQueryGuard<'_> {
    fn drop(&mut self) {
        let prev = self
            .driver
            .active_queries
            .fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
        if prev == 1 {
            self.driver.scan_cache.clear();
        }
    }
}

impl Driver {
    pub fn new(cluster: Arc<Cluster>, cfg: DriverConfig) -> Self {
        let workers = cfg.workers.max(1);
        Self {
            cluster,
            pool: ThreadPool::new(workers),
            worker_cpus: (0..workers).map(|_| Arc::new(Timeline::new())).collect(),
            cfg,
            calibration: std::sync::RwLock::new(CalibrationMap::default()),
            scan_cache: Arc::new(worker::ScanCache::new()),
            active_queries: std::sync::atomic::AtomicUsize::new(0),
            compactions: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Lifetime compactions committed by this driver.
    pub fn compactions(&self) -> u64 {
        self.compactions.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Snapshot of the per-column est-vs-actual calibration the planner
    /// consults (empty until queries with byte estimates execute).
    pub fn calibration(&self) -> CalibrationMap {
        self.calibration.read().unwrap().clone()
    }

    pub fn workers(&self) -> usize {
        self.worker_cpus.len()
    }

    /// Reset virtual time (between bench cases).
    pub fn reset_time(&self) {
        for t in &self.worker_cpus {
            t.reset();
        }
        self.cluster.reset_time();
    }

    // ---- write path -------------------------------------------------------

    /// Partition a table into row-group objects and store it. `locality`
    /// optionally assigns each row group a placement group key (§3.1).
    pub fn write_table(
        &self,
        dataset: &str,
        batch: &Batch,
        layout: Layout,
        spec: &PartitionSpec,
        locality: Option<&dyn Fn(usize, &Batch) -> String>,
    ) -> Result<WriteReport> {
        if metadata::load_meta(&self.cluster, 0.0, dataset).is_ok() {
            return Err(Error::AlreadyExists(format!("dataset {dataset}")));
        }
        // New bytes are landing: concurrent shared scans must not serve
        // a batch decoded before this write.
        self.scan_cache.clear();
        if let Some(col) = &spec.cluster_by {
            // Fail fast on a ghost cluster column, before any object I/O.
            batch.schema.col_index(col)?;
        }
        // Same for declared index columns (and their dtypes): reject
        // before any object exists rather than after a partial write.
        metadata::validate_index_cols(&batch.schema, &spec.index_cols)?;
        let wall = Instant::now();
        let groups = spec.partition(batch)?;
        let localities: Vec<String> = groups
            .iter()
            .enumerate()
            .map(|(i, g)| locality.map(|f| f(i, g)).unwrap_or_default())
            .collect();

        // Fan the group writes out over the worker pool. Items move into
        // the pool (no batch clones); only the count is kept back.
        let cluster = Arc::clone(&self.cluster);
        let items: Vec<(usize, Batch, String)> = groups
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                let base = naming::table_object(dataset, i as u64);
                let name = if localities[i].is_empty() {
                    base
                } else {
                    naming::with_locality(&localities[i], &base)
                };
                (i, g, name)
            })
            .collect();
        let objects = items.len();
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let index_cols = spec.index_cols.clone();
        let results: Vec<Result<(u64, u64, f64, Vec<ColumnStats>)>> =
            self.pool.map(items, move |(i, g, name)| {
                let cpu = &worker_cpus[i % nw];
                let (bytes, mut finish, stats) =
                    worker::write_row_group(&cluster, &name, &g, layout, 0.0, cpu)?;
                // Index maintenance rides the same fan-out: each declared
                // column's postings are built right after the object seals,
                // so a freshly written dataset is immediately probe-able.
                for col in &index_cols {
                    let mut w = crate::util::bytes::ByteWriter::new();
                    w.str(col);
                    let t = cluster.call(finish, &name, "skyhook", "build_index", &w.finish())?;
                    finish = finish.max(t.finish);
                }
                Ok((g.nrows() as u64, bytes, finish, stats))
            });

        let mut row_groups = Vec::with_capacity(objects);
        let mut bytes_written = 0u64;
        let mut sim_finish: f64 = 0.0;
        for r in results {
            let (rows, bytes, finish, stats) = r?;
            row_groups.push(RowGroupMeta { rows, bytes, stats });
            bytes_written += bytes;
            sim_finish = sim_finish.max(finish);
        }

        let meta = DatasetMeta::Table {
            schema: batch.schema.clone(),
            layout,
            row_groups,
            localities,
            cluster_by: spec.cluster_by.clone().unwrap_or_default(),
            index_cols: spec.index_cols.clone(),
            muta: Default::default(),
        };
        let t = metadata::save_meta(&self.cluster, sim_finish, dataset, &meta, false)?;
        Ok(WriteReport {
            objects,
            bytes_written,
            sim_seconds: t,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    // ---- read path ----------------------------------------------------------

    /// Plan and execute a query (zone-map pruning enabled). `force_mode`
    /// lets benches compare pushdown vs client-side on identical queries.
    pub fn execute(&self, query: &Query, force_mode: Option<ExecMode>) -> Result<QueryResult> {
        self.execute_opts(query, force_mode, true)
    }

    /// [`Driver::execute`] with zone-map pruning optionally disabled —
    /// the unpruned baseline the pruning benches compare against. Plans
    /// against the cluster's calibrated cost profile, so the per-object
    /// offload choice reflects the hardware this driver runs on.
    pub fn execute_opts(
        &self,
        query: &Query,
        force_mode: Option<ExecMode>,
        prune: bool,
    ) -> Result<QueryResult> {
        self.execute_pinned(query, force_mode, prune, access_path_forced())
    }

    /// [`Driver::execute`] with the index-vs-scan access path pinned
    /// programmatically: `Some(_)` forces the path for every sub-query
    /// whose predicate the index can serve, `None` is the planner's free
    /// cost-model choice *ignoring* `SKYHOOK_FORCE_ACCESS_PATH` — which
    /// lets a single test compare forced-index, forced-scan, and free
    /// executions without racing other tests on the environment.
    pub fn execute_with_access(
        &self,
        query: &Query,
        force_mode: Option<ExecMode>,
        access: Option<AccessForce>,
    ) -> Result<QueryResult> {
        self.execute_pinned(query, force_mode, true, access)
    }

    fn execute_pinned(
        &self,
        query: &Query,
        force_mode: Option<ExecMode>,
        prune: bool,
        access: Option<AccessForce>,
    ) -> Result<QueryResult> {
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, &query.dataset)?;
        let cost = self.plan_cost(&meta);
        let plan = {
            let cal = self.calibration.read().unwrap();
            plan_with_access(query, &meta, force_mode, prune, &cost, &cal, access)?
        };
        self.execute_plan(&plan)
    }

    /// Cost profile for planning against `meta`: the cluster's calibrated
    /// params, with the live worst-case LSM read amplification stamped in
    /// when the dataset declares indexed columns. A probe pays one point
    /// lookup per memtable + sorted run, so the index-vs-scan choice must
    /// track the `KvStore`s' compaction state, not a static constant.
    fn plan_cost(&self, meta: &DatasetMeta) -> CostParams {
        let mut cost = self.cluster.cost().clone();
        if matches!(meta, DatasetMeta::Table { index_cols, .. } if !index_cols.is_empty()) {
            cost.index_read_amp = self
                .cluster
                .kv_stats()
                .iter()
                .map(|s| s.read_amp() as f64)
                .fold(1.0, f64::max);
        }
        // Live contention, same snapshot-at-plan-time pattern: the mean
        // in-flight sub-query count per OSD feeds `osd_saturation`, so a
        // busy cluster prices pushdown client-ward and the offload
        // boundary flips dynamically under concurrent load.
        cost.queue_depth = self.cluster.mean_inflight();
        cost
    }

    /// Execute a prepared plan.
    pub fn execute_plan(&self, plan: &QueryPlan) -> Result<QueryResult> {
        // Scope the shared-scan cache to overlapping executions: count
        // this query in, and (in the guard's Drop — panic-safe) clear
        // the cache when the last in-flight query finishes, so nothing
        // ever hits a batch cached by an already-completed serial run.
        self.active_queries
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let _active = ActiveQueryGuard { driver: self };
        let wall = Instant::now();
        let at = self.cluster.clock.now();
        let query = &plan.query;
        let cluster = Arc::clone(&self.cluster);
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let subs: Vec<(usize, super::plan::SubQuery)> = plan
            .subqueries
            .iter()
            .cloned()
            .enumerate()
            .collect();
        let objects = subs.len();
        // The plan's server-side stage block, cloned once and shared by
        // every pool worker — both execution modes evaluate this exact
        // spec (pushdown on the OSD, client-side through the kernel).
        let spec = Arc::new(plan.pipeline.clone());
        let scan_cache = Arc::clone(&self.scan_cache);
        let results: Vec<Result<SubResult>> = self.pool.map(subs, move |(i, sub)| {
            // Publish this sub-query on its primary OSD's live queue for
            // as long as it runs (guard drops even on error/panic):
            // that's the depth `plan_cost` snapshots for everyone else.
            let _load = cluster.track_inflight(&sub.object);
            worker::execute_subquery(
                &cluster,
                &spec,
                &sub,
                at,
                &worker_cpus[i % nw],
                Some(&scan_cache),
            )
        });

        // Gather: merge partials in sub-query (object) order, so every
        // execution mode folds the same arithmetic sequence. Row partials
        // are kept separate (with their pre-sortedness) so a sorted query
        // can k-way merge them instead of re-sorting the concatenation.
        let mut bytes_moved = 0u64;
        let mut reads_coalesced = 0u64;
        let mut prefix_reads = 0u64;
        let mut rows_short_circuited = 0u64;
        let mut compiled_chunks = 0u64;
        let mut compiled_rows = 0u64;
        let mut index_probes = 0u64;
        let mut index_postings = 0u64;
        let mut shared_scan_hits = 0u64;
        let mut sim_finish = at;
        let mut row_parts: Vec<(Batch, bool)> = Vec::new();
        let mut agg_states: Vec<AggState> = Vec::new();
        let mut groups: std::collections::BTreeMap<Vec<i64>, Vec<AggState>> = Default::default();
        for r in results {
            let r = r?;
            bytes_moved += r.bytes_moved;
            reads_coalesced += r.reads_coalesced;
            prefix_reads += r.prefix_reads;
            rows_short_circuited += r.rows_short_circuited;
            compiled_chunks += r.compiled_chunks;
            compiled_rows += r.compiled_rows;
            index_probes += r.index_probes;
            index_postings += r.index_postings;
            shared_scan_hits += r.shared_scan_hits;
            sim_finish = sim_finish.max(r.finish);
            match r.output {
                SubOutput::Rows(b) => row_parts.push((b, r.presorted)),
                SubOutput::Aggs(states) => {
                    if agg_states.is_empty() {
                        agg_states = states;
                    } else {
                        if states.len() != agg_states.len() {
                            return Err(Error::Query("partial arity mismatch".into()));
                        }
                        for (acc, s) in agg_states.iter_mut().zip(&states) {
                            acc.merge(s);
                        }
                    }
                }
                SubOutput::Groups(gs) => {
                    for (k, states) in gs {
                        match groups.get_mut(&k) {
                            Some(acc) => {
                                if acc.len() != states.len() {
                                    return Err(Error::Query("group partial arity mismatch".into()));
                                }
                                for (a, s) in acc.iter_mut().zip(&states) {
                                    a.merge(s);
                                }
                            }
                            None => {
                                groups.insert(k, states);
                            }
                        }
                    }
                }
            }
        }

        // Finalize. A dataset with zero objects still answers aggregate
        // queries (empty states).
        if query.is_aggregate() && agg_states.is_empty() && query.group_by.is_empty() {
            agg_states = query
                .aggregates
                .iter()
                .map(|a| AggState::new(!a.func.is_algebraic()))
                .collect();
        }
        let aggregates: Vec<f64> = if query.group_by.is_empty() && query.is_aggregate() {
            query
                .aggregates
                .iter()
                .zip(&agg_states)
                .map(|(a, s)| s.finalize(a.func))
                .collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let group_out = if !query.group_by.is_empty() {
            let mut out = Vec::with_capacity(groups.len());
            for (k, states) in groups {
                if states.len() != query.aggregates.len() {
                    return Err(Error::Query("group partial arity mismatch".into()));
                }
                let vals = query
                    .aggregates
                    .iter()
                    .zip(&states)
                    .map(|(a, s)| s.finalize(a.func))
                    .collect::<Result<Vec<f64>>>()?;
                out.push((k, vals));
            }
            // HAVING: filter the finalized group rows (merge-side by
            // nature — it needs cross-object totals). Group keys resolve
            // by name, aggregates by display form ("sum(val)") — the
            // same rule the planner validated; display names render once
            // up front, not per group.
            if query.having != Predicate::True {
                let agg_names: Vec<String> =
                    query.aggregates.iter().map(|a| a.to_string()).collect();
                let mut kept = Vec::with_capacity(out.len());
                for (k, vals) in out {
                    let keep = query.having.eval_row(&|name: &str| {
                        if let Some(i) = query.group_by.iter().position(|g| g == name) {
                            return Some(k[i] as f64);
                        }
                        agg_names.iter().position(|a| a == name).map(|i| vals[i])
                    })?;
                    if keep {
                        kept.push((k, vals));
                    }
                }
                out = kept;
            }
            // Merge-side limit over the key-ordered (HAVING-surviving)
            // group rows.
            if let Some(n) = query.limit {
                out.truncate(n);
            }
            Some(out)
        } else {
            None
        };

        // Row queries always return a batch — when every sub-query was
        // pruned (or the dataset has zero objects), synthesize an empty
        // batch with the carried schema so pruned and unpruned executions
        // are indistinguishable to callers. Then run the merge-side
        // stages: k-way merge of pre-sorted partials (or plain concat),
        // limit/truncate, final projection.
        let rows = if query.is_aggregate() {
            None
        } else {
            let mut batch = if row_parts.is_empty() {
                let schema = match query.carry_columns() {
                    Some(cols) => {
                        let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                        plan.schema.project(&refs)?
                    }
                    None => plan.schema.clone(),
                };
                Batch::empty(&schema)
            } else if query.sort_keys.is_empty() {
                // Unsorted: concatenate in object order.
                let mut it = row_parts.into_iter();
                let (mut acc, _) = it.next().expect("non-empty");
                for (b, _) in it {
                    acc.concat(&b)?;
                }
                acc
            } else if let Some(n) = query.limit {
                // Top-k: k-way partial-order merge. Pushed-down partials
                // arrive pre-sorted and truncated to k; client-side
                // partials are sorted and truncated here first, then the
                // runs merge in O(k × parts) without re-sorting the
                // concatenation.
                let mut parts = Vec::with_capacity(row_parts.len());
                for (b, presorted) in row_parts {
                    let mut b = if presorted {
                        b
                    } else {
                        sort_rows(&b, &query.sort_keys)?
                    };
                    if b.nrows() > n {
                        b = b.slice(0, n)?;
                    }
                    parts.push(b);
                }
                merge_sorted(&parts, &query.sort_keys, Some(n))?
            } else {
                // Bare sort (no limit): nothing was truncated per object,
                // so a merge saves no work — concatenate and stable-sort
                // (identical ordering to the k-way merge).
                let mut it = row_parts.into_iter();
                let (mut acc, _) = it.next().expect("non-empty");
                for (b, _) in it {
                    acc.concat(&b)?;
                }
                sort_rows(&acc, &query.sort_keys)?
            };
            // The empty-synthesis path still validates sort keys against
            // the carried schema, like the sorted path would.
            if batch.nrows() == 0 && !query.sort_keys.is_empty() {
                batch = sort_rows(&batch, &query.sort_keys)?;
            }
            if let Some(n) = query.limit {
                if batch.nrows() > n {
                    batch = batch.slice(0, n)?;
                }
            }
            // Final projection only when the partials carried extra sort
            // keys — otherwise they already hold exactly the projected
            // columns and re-projecting would just deep-clone the result.
            if let Some(p) = &query.projection {
                if query.sort_keys.iter().any(|k| !p.contains(&k.col)) {
                    let refs: Vec<&str> = p.iter().map(String::as_str).collect();
                    batch = batch.project(&refs)?;
                }
            }
            Some(batch)
        };

        let pushdown = plan.mode == ExecMode::Pushdown;
        // Calibration feedback (planner follow-up c): record how far the
        // byte estimate was from reality, attributed to the predicate's
        // columns, so the next plan's selectivity estimate is corrected.
        let est_ratio = (plan.est_bytes > 0 && bytes_moved > 0)
            .then(|| bytes_moved as f64 / plan.est_bytes as f64);
        if let Some(ratio) = est_ratio {
            // Only executions whose byte estimate actually *depended* on
            // the selectivity estimate teach the map: pushed-down row
            // partials (uncapped — a top-k/head partial pins both the
            // estimate and the actual at ~k rows, so its ratio says
            // nothing), grouped partials and holistic value shipping
            // scale with matching rows; constant-size algebraic partials
            // and pure client-side fetches do not — their ratio≈1 would
            // erase learned corrections through the EWMA.
            let sel_sensitive = (!query.is_aggregate() && query.limit.is_none())
                || !query.group_by.is_empty()
                || query.aggregates.iter().any(|a| !a.func.is_algebraic());
            let cols = query.predicate.columns();
            // …and only fully pushed-down plans: a mixed assignment's
            // ratio is dominated by deterministic client fetch bytes,
            // which say nothing about selectivity either.
            if sel_sensitive && plan.assignment.0 > 0 && plan.assignment.1 == 0 && !cols.is_empty()
            {
                self.calibration.write().unwrap().observe(&cols, ratio);
            }
        }
        Ok(QueryResult {
            rows,
            aggregates,
            groups: group_out,
            stats: QueryStats {
                bytes_moved,
                sim_seconds: sim_finish - at,
                wall_seconds: wall.elapsed().as_secs_f64(),
                objects,
                objects_pruned: plan.objects_pruned,
                bytes_skipped: plan.bytes_skipped,
                reads_coalesced,
                prefix_reads,
                rows_short_circuited,
                compiled_chunks,
                compiled_rows,
                index_probes,
                index_postings,
                shared_scan_hits,
                pushdown,
                objects_pushdown: plan.assignment.0,
                objects_client: plan.assignment.1,
                bytes_estimated: plan.est_bytes,
                est_ratio,
            },
        })
    }

    /// Plan a query against the live dataset metadata and render the
    /// staged pipeline (per-operator offload sides with their estimated
    /// costs) without executing it — the CLI's EXPLAIN.
    pub fn explain(&self, query: &Query, force_mode: Option<ExecMode>) -> Result<String> {
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, &query.dataset)?;
        let cost = self.plan_cost(&meta);
        let cal = self.calibration.read().unwrap();
        let plan = plan_with_access(
            query,
            &meta,
            force_mode,
            true,
            &cost,
            &cal,
            access_path_forced(),
        )?;
        Ok(plan.explain())
    }

    /// Approximate quantile via the §3.2 de-composable approximation:
    /// each object returns a constant-size mergeable sketch, the driver
    /// merges and interpolates. Returns (value, worst-case abs error,
    /// stats). Compare with the exact (holistic) `AggFunc::Median` path,
    /// which ships every filtered value. Zone-map pruning is applied on
    /// the sketch path exactly like scan/agg/group: provably-dead row
    /// groups are dropped before any request is issued.
    pub fn approx_quantile(
        &self,
        dataset: &str,
        column: &str,
        q: f64,
        predicate: &Predicate,
    ) -> Result<(f64, f64, QueryStats)> {
        self.approx_quantile_opts(dataset, column, q, predicate, true)
    }

    /// [`Driver::approx_quantile`] with zone-map pruning optionally
    /// disabled — the unpruned baseline for the sketch path (mirrors
    /// [`Driver::execute_opts`]).
    pub fn approx_quantile_opts(
        &self,
        dataset: &str,
        column: &str,
        q: f64,
        predicate: &Predicate,
        prune: bool,
    ) -> Result<(f64, f64, QueryStats)> {
        use super::sketch::QuantileSketch;
        let wall = Instant::now();
        let at = self.cluster.clock.now();
        let (meta, _) = metadata::load_meta(&self.cluster, at, dataset)?;
        let DatasetMeta::Table {
            schema, row_groups, ..
        } = &meta
        else {
            return Err(Error::Query(format!(
                "{dataset} is an array dataset; table query expected"
            )));
        };
        // Fail fast on unknown columns, identically with and without
        // pruning (a fully pruned fan-out must not hide the error).
        schema.col_index(column)?;
        for c in predicate.columns() {
            schema.col_index(c)?;
        }
        // Error parity: a string-typed predicate column fails during
        // evaluation, so pruning is disabled for it — the handlers run
        // and report the error the usual way.
        let dtype_of = |name: &str| schema.col_index(name).ok().map(|i| schema.col(i).dtype);
        let evaluable = !predicate
            .columns()
            .into_iter()
            .any(|c| dtype_of(c) == Some(DType::Str));
        let prune = prune && evaluable;
        let names = meta.object_names(dataset);
        let mut objects_pruned = 0usize;
        let mut bytes_skipped = 0u64;
        let mut survivors = Vec::with_capacity(names.len());
        for (i, obj) in names.into_iter().enumerate() {
            let rg = &row_groups[i];
            if prune && group_prunes(predicate, schema, rg) {
                objects_pruned += 1;
                bytes_skipped += rg.bytes;
                continue;
            }
            survivors.push(obj);
        }
        let objects = survivors.len();
        let cluster = Arc::clone(&self.cluster);
        let input = {
            let mut w = crate::util::bytes::ByteWriter::new();
            predicate.encode_into(&mut w);
            w.str(column);
            // Server-side zone-map short-circuit follows the same switch.
            w.u8(prune as u8);
            w.finish()
        };
        let results: Vec<Result<(QuantileSketch, u64, f64)>> =
            self.pool.map(survivors, move |obj| {
                let t = cluster.call(at, &obj, "skyhook", "quantile_sketch", &input)?;
                let mut r = crate::util::bytes::ByteReader::new(&t.value);
                let sketch = QuantileSketch::decode_from(&mut r)?;
                Ok((sketch, t.value.len() as u64, t.finish))
            });
        let mut merged = QuantileSketch::empty();
        let mut bytes_moved = 0;
        let mut sim_finish = at;
        for r in results {
            let (s, bytes, finish) = r?;
            merged.merge(&s);
            bytes_moved += bytes;
            sim_finish = sim_finish.max(finish);
        }
        let value = merged.quantile(q)?;
        Ok((
            value,
            2.0 * merged.error_bound(),
            QueryStats {
                bytes_moved,
                sim_seconds: sim_finish - at,
                wall_seconds: wall.elapsed().as_secs_f64(),
                objects,
                objects_pruned,
                bytes_skipped,
                pushdown: true,
                ..Default::default()
            },
        ))
    }

    /// Build the `ix1` postings index on an i64 or f32 column of every
    /// object of a dataset, and record the column in the dataset metadata
    /// so the planner offers the IndexScan access path and later layout
    /// transforms rebuild it. Returns the total rows indexed.
    pub fn build_index(&self, dataset: &str, column: &str) -> Result<u64> {
        self.scan_cache.clear();
        let (mut meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let DatasetMeta::Table { schema, .. } = &meta else {
            return Err(Error::Query(format!(
                "{dataset} is an array dataset; build_index expects a table"
            )));
        };
        // Fail fast on ghost / non-indexable columns, before any fan-out.
        metadata::validate_index_cols(schema, &[column.to_string()])?;
        let names = meta.object_names(dataset);
        let cluster = Arc::clone(&self.cluster);
        let col = column.to_string();
        let results: Vec<Result<(u64, f64)>> = self.pool.map(names, move |obj| {
            let mut w = crate::util::bytes::ByteWriter::new();
            w.str(&col);
            let t = cluster.call(0.0, &obj, "skyhook", "build_index", &w.finish())?;
            let n = u64::from_le_bytes(
                t.value
                    .try_into()
                    .map_err(|_| Error::Corrupt("bad index count".into()))?,
            );
            Ok((n, t.finish))
        });
        let mut total = 0;
        let mut sim = 0.0f64;
        for r in results {
            let (n, finish) = r?;
            total += n;
            sim = sim.max(finish);
        }
        let stamped = match &mut meta {
            DatasetMeta::Table { index_cols, .. } if !index_cols.iter().any(|c| c == column) => {
                index_cols.push(column.to_string());
                true
            }
            _ => false,
        };
        if stamped {
            metadata::save_meta(&self.cluster, sim, dataset, &meta, true)?;
        }
        Ok(total)
    }

    /// Transform every object of a dataset to the target layout and update
    /// the dataset metadata (physical design management, §5).
    pub fn transform_layout(&self, dataset: &str, target: Layout) -> Result<WriteReport> {
        // Objects are about to be rewritten in place: drop any batch a
        // concurrent shared scan might otherwise reuse across the swap.
        self.scan_cache.clear();
        let wall = Instant::now();
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        if !matches!(meta, DatasetMeta::Table { .. }) {
            return Err(Error::Query("transform needs a table dataset".into()));
        }
        // Names are derived before destructuring so the meta fields can
        // move into the updated metadata below without cloning.
        let names = meta.object_names(dataset);
        let DatasetMeta::Table {
            schema,
            layout,
            row_groups,
            localities,
            cluster_by,
            index_cols,
            muta,
        } = meta
        else {
            unreachable!("table kind checked above");
        };
        if layout == target {
            return Ok(WriteReport {
                objects: 0,
                bytes_written: 0,
                sim_seconds: 0.0,
                wall_seconds: wall.elapsed().as_secs_f64(),
            });
        }
        let cluster = Arc::clone(&self.cluster);
        let rebuild_cols = index_cols.clone();
        let results: Vec<Result<f64>> = self.pool.map(names, move |obj| {
            let t = cluster.call(
                0.0,
                &obj,
                "skyhook",
                "transform",
                &[match target {
                    Layout::Row => 0u8,
                    Layout::Col => 1u8,
                }],
            )?;
            // Re-stamp this object's postings against the rewritten
            // encoding before it serves probes again. A layout transform
            // happens to preserve row ids, but the maintenance rule is
            // "any rewrite rebuilds declared indexes" — the driver does
            // not get to reason about which rewrites are posting-safe.
            let mut finish = t.finish;
            for col in &rebuild_cols {
                let mut w = crate::util::bytes::ByteWriter::new();
                w.str(col);
                let tb = cluster.call(finish, &obj, "skyhook", "build_index", &w.finish())?;
                finish = finish.max(tb.finish);
            }
            Ok(finish)
        });
        let mut sim = 0.0f64;
        let mut n = 0;
        for r in results {
            sim = sim.max(r?);
            n += 1;
        }
        let meta = DatasetMeta::Table {
            schema,
            layout: target,
            row_groups,
            localities,
            cluster_by,
            index_cols,
            muta,
        };
        metadata::save_meta(&self.cluster, sim, dataset, &meta, true)?;
        Ok(WriteReport {
            objects: n,
            bytes_written: 0,
            sim_seconds: sim,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    // ---- mutation path ----------------------------------------------------

    /// Tombstone `rows` (object-local row ids) of row group
    /// `object_index`: stamps the object's `dv1/` delete-vector bitmap in
    /// its OSD's kvstore and records the handler's authoritative popcount
    /// in the dataset metadata, so the planner can discount selectivity
    /// estimates and clean objects skip the delete-vector round trip
    /// entirely. Idempotent — re-deleting the same rows changes nothing.
    /// Returns the object's total tombstone count.
    pub fn delete_rows(&self, dataset: &str, object_index: usize, rows: &[u32]) -> Result<u64> {
        // The cluster mutation epoch invalidates shared scans on its own;
        // clearing here as well keeps every Driver writer on the same
        // choke point.
        self.scan_cache.clear();
        let (mut meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let n_groups = match &meta {
            DatasetMeta::Table { row_groups, .. } => row_groups.len(),
            _ => {
                return Err(Error::Query(format!(
                    "{dataset} is an array dataset; delete_rows expects a table"
                )))
            }
        };
        if object_index >= n_groups {
            return Err(Error::Invalid(format!(
                "row group {object_index} out of {n_groups}"
            )));
        }
        let name = meta.object_names(dataset).swap_remove(object_index);
        let mut w = crate::util::bytes::ByteWriter::new();
        w.u32(rows.len() as u32);
        for &r in rows {
            w.u32(r);
        }
        let t = self
            .cluster
            .call(0.0, &name, "skyhook", "delete_rows", &w.finish())?;
        let popcount = u64::from_le_bytes(
            t.value
                .as_slice()
                .try_into()
                .map_err(|_| Error::Corrupt("bad delete_rows reply".into()))?,
        );
        let DatasetMeta::Table { muta, .. } = &mut meta else {
            unreachable!("table kind checked above");
        };
        if muta.tombstones.len() < n_groups {
            muta.tombstones.resize(n_groups, 0);
        }
        muta.tombstones[object_index] = popcount;
        metadata::save_meta(&self.cluster, t.finish, dataset, &meta, true)?;
        self.maybe_compact(dataset)?;
        Ok(popcount)
    }

    /// Append `batch` to an existing table dataset as new row groups,
    /// through the same partition→write→index fan-out as the initial
    /// ingest. Appended objects land after the existing groups in the
    /// dataset's *current* generation namespace; their zone maps and
    /// per-column sortedness markers are computed from the appended rows,
    /// so per-object markers stay truthful. The dataset-level
    /// `cluster_by` claim however is provably broken by any append (new
    /// rows do not extend the global sort), so it is cleared rather than
    /// lie to the read path — the intent moves to `muta.compact_by` and
    /// compaction restores it.
    pub fn append(&self, dataset: &str, batch: &Batch, target_bytes: u64) -> Result<WriteReport> {
        self.scan_cache.clear();
        let wall = Instant::now();
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let DatasetMeta::Table {
            schema,
            layout,
            mut row_groups,
            mut localities,
            cluster_by,
            index_cols,
            mut muta,
        } = meta
        else {
            return Err(Error::Query(format!(
                "{dataset} is an array dataset; append expects a table"
            )));
        };
        if batch.schema != schema {
            return Err(Error::Query(format!("append schema mismatch for {dataset}")));
        }
        if batch.nrows() == 0 {
            return Ok(WriteReport {
                objects: 0,
                bytes_written: 0,
                sim_seconds: 0.0,
                wall_seconds: wall.elapsed().as_secs_f64(),
            });
        }
        let groups = PartitionSpec::with_target(target_bytes).partition(batch)?;
        let base = row_groups.len();
        let cluster = Arc::clone(&self.cluster);
        let items: Vec<(usize, Batch, String)> = groups
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                let name = naming::table_object_gen(dataset, muta.generation, (base + i) as u64);
                (i, g, name)
            })
            .collect();
        let objects = items.len();
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let rebuild_cols = index_cols.clone();
        let results: Vec<Result<(u64, u64, f64, Vec<ColumnStats>)>> =
            self.pool.map(items, move |(i, g, name)| {
                let cpu = &worker_cpus[i % nw];
                let (bytes, mut finish, stats) =
                    worker::write_row_group(&cluster, &name, &g, layout, 0.0, cpu)?;
                // Declared indexes ride the append fan-out exactly like
                // the ingest one: appended objects are probe-able the
                // moment the dataset metadata lands.
                for col in &rebuild_cols {
                    let mut w = crate::util::bytes::ByteWriter::new();
                    w.str(col);
                    let t = cluster.call(finish, &name, "skyhook", "build_index", &w.finish())?;
                    finish = finish.max(t.finish);
                }
                Ok((g.nrows() as u64, bytes, finish, stats))
            });
        let mut bytes_written = 0u64;
        let mut sim_finish: f64 = 0.0;
        for r in results {
            let (rows, bytes, finish, stats) = r?;
            row_groups.push(RowGroupMeta { rows, bytes, stats });
            localities.push(String::new());
            bytes_written += bytes;
            sim_finish = sim_finish.max(finish);
        }
        if !muta.tombstones.is_empty() {
            muta.tombstones.resize(row_groups.len(), 0);
        }
        if !cluster_by.is_empty() {
            muta.compact_by = cluster_by;
        }
        let meta = DatasetMeta::Table {
            schema,
            layout,
            row_groups,
            localities,
            cluster_by: String::new(),
            index_cols,
            muta,
        };
        let t = metadata::save_meta(&self.cluster, sim_finish, dataset, &meta, true)?;
        self.maybe_compact(dataset)?;
        Ok(WriteReport {
            objects,
            bytes_written,
            sim_seconds: t,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    /// Re-clustering compaction: rewrite the dataset as generation N+1 —
    /// tombstoned rows dropped, rows re-sorted by the preserved
    /// `compact_by` intent (or the current `cluster_by`), fresh zone maps
    /// and sortedness markers stamped from the rewritten rows, declared
    /// `ix1/` indexes rebuilt per object. The new generation's objects
    /// are written *beside* the old ones under a distinct namespace, and
    /// the single metadata overwrite at the end is the commit point: an
    /// OSD death anywhere before it leaves the old generation fully
    /// readable with the metadata still pointing at it, so no reader can
    /// ever observe a half-compacted dataset. Superseded objects are
    /// deleted best-effort after the commit.
    pub fn compact(&self, dataset: &str) -> Result<WriteReport> {
        self.scan_cache.clear();
        let wall = Instant::now();
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let old_names = meta.object_names(dataset);
        let DatasetMeta::Table {
            schema,
            layout,
            row_groups,
            cluster_by,
            index_cols,
            muta,
            ..
        } = meta
        else {
            return Err(Error::Query(format!(
                "{dataset} is an array dataset; compact expects a table"
            )));
        };
        // Gather every live row client-side — object bytes plus the
        // object's delete vector. Reads only: the old generation stays
        // bit-identical until the commit below.
        let mut live = Batch::empty(&schema);
        let mut at = 0.0f64;
        for name in &old_names {
            let t = self.cluster.read_object(at, name)?;
            at = t.finish;
            let (mut b, _) = crate::dataset::layout::decode_batch(&t.value)?;
            let dv = self.cluster.call(at, name, "skyhook", "read_dv", &[])?;
            at = dv.finish;
            if !dv.value.is_empty() {
                let deleted = super::extension::decode_dv(&dv.value)?;
                let keep: Vec<bool> = deleted.iter().map(|&d| !d).collect();
                b = b.filter(&keep)?;
            }
            live.concat(&b)?;
        }
        let sort_key = if !muta.compact_by.is_empty() {
            muta.compact_by.clone()
        } else {
            cluster_by
        };
        // Keep the incumbent per-object sizing.
        let total_bytes: u64 = row_groups.iter().map(|g| g.bytes).sum();
        let target = (total_bytes / row_groups.len().max(1) as u64).max(1024);
        let mut spec = PartitionSpec::with_target(target);
        if !sort_key.is_empty() {
            spec.cluster_by = Some(sort_key.clone());
        }
        let groups = if live.nrows() == 0 {
            Vec::new()
        } else {
            spec.partition(&live)?
        };
        let next_gen = muta.generation + 1;
        let cluster = Arc::clone(&self.cluster);
        let items: Vec<(usize, Batch, String)> = groups
            .into_iter()
            .enumerate()
            .map(|(i, g)| {
                let name = naming::table_object_gen(dataset, next_gen, i as u64);
                (i, g, name)
            })
            .collect();
        let objects = items.len();
        let worker_cpus = self.worker_cpus.clone();
        let nw = worker_cpus.len();
        let rebuild_cols = index_cols.clone();
        let results: Vec<Result<(u64, u64, f64, Vec<ColumnStats>)>> =
            self.pool.map(items, move |(i, g, name)| {
                let cpu = &worker_cpus[i % nw];
                let (bytes, mut finish, stats) =
                    worker::write_row_group(&cluster, &name, &g, layout, at, cpu)?;
                for col in &rebuild_cols {
                    let mut w = crate::util::bytes::ByteWriter::new();
                    w.str(col);
                    let t = cluster.call(finish, &name, "skyhook", "build_index", &w.finish())?;
                    finish = finish.max(t.finish);
                }
                Ok((g.nrows() as u64, bytes, finish, stats))
            });
        let mut new_groups = Vec::with_capacity(objects);
        let mut bytes_written = 0u64;
        let mut sim_finish = at;
        for r in results {
            let (rows, bytes, finish, stats) = r?;
            new_groups.push(RowGroupMeta { rows, bytes, stats });
            bytes_written += bytes;
            sim_finish = sim_finish.max(finish);
        }
        let meta = DatasetMeta::Table {
            schema,
            layout,
            localities: vec![String::new(); new_groups.len()],
            row_groups: new_groups,
            // The re-sort restores the global ordering claim.
            cluster_by: sort_key,
            index_cols,
            muta: metadata::Mutability {
                generation: next_gen,
                tombstones: Vec::new(),
                compact_by: String::new(),
            },
        };
        // THE commit point: one metadata overwrite flips every reader to
        // the new generation atomically. Everything before this line was
        // additive; everything after is cleanup.
        let t = metadata::save_meta(&self.cluster, sim_finish, dataset, &meta, true)?;
        for name in &old_names {
            // Best-effort: a failed delete strands bytes, never results.
            let _ = self.cluster.delete_object(t, name);
        }
        self.compactions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(WriteReport {
            objects,
            bytes_written,
            sim_seconds: t,
            wall_seconds: wall.elapsed().as_secs_f64(),
        })
    }

    /// The compaction trigger every mutation path (and the serve loop)
    /// shares: compacts when churn crossed a threshold — more than 25%
    /// of rows tombstoned, or, when a clustering intent is pending
    /// (`compact_by` stamped by an append), more than half the row
    /// groups no longer sorted by it. `SKYHOOK_FORCE_COMPACT=1` compacts
    /// after every mutation regardless (the CI's forced second pass).
    /// Returns whether a compaction ran.
    pub fn maybe_compact(&self, dataset: &str) -> Result<bool> {
        let (meta, _) = metadata::load_meta(&self.cluster, 0.0, dataset)?;
        let DatasetMeta::Table {
            schema,
            row_groups,
            muta,
            ..
        } = &meta
        else {
            return Ok(false);
        };
        let forced = std::env::var("SKYHOOK_FORCE_COMPACT").map_or(false, |v| v == "1");
        let total_rows: u64 = row_groups.iter().map(|g| g.rows).sum();
        let dead = muta.total_tombstones();
        let churned = total_rows > 0 && dead as f64 > 0.25 * total_rows as f64;
        let unsorted = !muta.compact_by.is_empty()
            && match schema.col_index(&muta.compact_by) {
                Ok(ci) => {
                    let n = row_groups.len();
                    let u = row_groups.iter().filter(|g| !g.stats[ci].sorted).count();
                    n > 0 && u as f64 > 0.5 * n as f64
                }
                Err(_) => false,
            };
        if forced || churned || unsorted {
            self.compact(dataset)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Batch size configured for dispatch rounds.
    pub fn batch_size(&self) -> usize {
        self.cfg.batch_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::dataset::table::gen;
    use crate::skyhook::extension::register_skyhook_class;
    use crate::skyhook::query::{AggFunc, CmpOp, Predicate};
    use crate::store::ClassRegistry;

    fn driver(osds: usize, workers: usize) -> Driver {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        let cluster = Cluster::new(
            &ClusterConfig {
                osds,
                replicas: 1,
                ..Default::default()
            },
            reg,
        );
        Driver::new(
            cluster,
            DriverConfig {
                workers,
                ..Default::default()
            },
        )
    }

    /// Like [`driver`], but the cluster's cost profile enables the
    /// compiled execution tier (the launch.rs wiring when a PJRT engine
    /// is loaded). No engine here: the tier's native chunked pass runs.
    fn driver_compiled(osds: usize, workers: usize) -> Driver {
        let mut reg = ClassRegistry::with_builtins();
        register_skyhook_class(&mut reg, None);
        let cfg = ClusterConfig {
            osds,
            replicas: 1,
            ..Default::default()
        };
        let mut cost = cfg.profile.params();
        cost.exec = cost.exec.with_compiled_tier();
        let cluster = Cluster::with_cost(&cfg, reg, cost);
        Driver::new(
            cluster,
            DriverConfig {
                workers,
                ..Default::default()
            },
        )
    }

    fn seed(d: &Driver, rows: usize) -> Batch {
        let b = gen::sensor_table(rows, 99);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(8 * 1024),
            None,
        )
        .unwrap();
        b
    }

    #[test]
    fn write_then_scan_roundtrip() {
        let d = driver(4, 4);
        let b = seed(&d, 2000);
        let r = d.execute(&Query::scan("sensors"), None).unwrap();
        let rows = r.rows.unwrap();
        assert_eq!(rows.nrows(), 2000);
        assert_eq!(rows.schema, b.schema);
        assert!(r.stats.objects > 1, "should span multiple objects");
        // A full scan reduces nothing at the objects, so the cost model
        // assigns every sub-query to the plain (client-side) read path.
        assert!(!r.stats.pushdown);
        assert_eq!(r.stats.objects_client, r.stats.objects);
        assert_eq!(r.stats.objects_pushdown, 0);
        assert!(r.stats.bytes_estimated > 0);
        assert!(r.stats.sim_seconds > 0.0);
    }

    #[test]
    fn write_rejects_duplicate_dataset() {
        let d = driver(2, 2);
        seed(&d, 100);
        let b = gen::sensor_table(50, 1);
        assert!(matches!(
            d.write_table("sensors", &b, Layout::Col, &PartitionSpec::default(), None),
            Err(Error::AlreadyExists(_))
        ));
    }

    #[test]
    fn filtered_scan_matches_direct() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        let pred = Predicate::cmp("val", CmpOp::Gt, 60.0);
        let r = d
            .execute(&Query::scan("sensors").filter(pred.clone()).select(&["ts"]), None)
            .unwrap();
        let got = r.rows.unwrap();
        let mask = pred.eval(&b).unwrap();
        assert_eq!(got.nrows(), mask.iter().filter(|&&m| m).count());
        assert_eq!(got.ncols(), 1);
    }

    #[test]
    fn aggregate_matches_direct_and_modes_agree() {
        let d = driver(4, 4);
        let b = seed(&d, 2500);
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(AggFunc::Mean, "val")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Var, "val");
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        for (a, b) in rp.aggregates.iter().zip(&rc.aggregates) {
            assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
        // Direct.
        let mask = q.predicate.eval(&b).unwrap();
        let mut st = AggState::new(false);
        st.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert!((rp.aggregates[0] - st.finalize(AggFunc::Mean).unwrap()).abs() < 1e-6);
        assert_eq!(rp.aggregates[1], st.count as f64);
        // Pushdown moves much less data for aggregates.
        assert!(rp.stats.bytes_moved * 5 < rc.stats.bytes_moved);
    }

    #[test]
    fn compiled_tier_counters_flow_to_query_stats() {
        // Objects big enough (~9k rows) that the chunk-launch overhead
        // amortizes and the backend's Auto tier picks compiled.
        let seed_big = |d: &Driver| {
            d.write_table(
                "sensors",
                &gen::sensor_table(40_000, 99),
                Layout::Col,
                &PartitionSpec::with_target(256 * 1024),
                None,
            )
            .unwrap();
        };
        let dc = driver_compiled(4, 4);
        seed_big(&dc);
        let ds = driver(4, 4);
        seed_big(&ds);
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 0.0))
            .aggregate(AggFunc::Mean, "val");
        let rc = dc.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let rs = ds.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        // Same answer to the bit — the tier shows only in the counters.
        assert_eq!(rc.aggregates.len(), 1);
        for (a, b) in rc.aggregates.iter().zip(&rs.aggregates) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        // A scalar-profile cluster never reports compiled work, and the
        // client side always runs the scalar kernel.
        assert_eq!((rs.stats.compiled_chunks, rs.stats.compiled_rows), (0, 0));
        let rcs = dc.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert_eq!((rcs.stats.compiled_chunks, rcs.stats.compiled_rows), (0, 0));
        if crate::skyhook::scalar_forced() {
            eprintln!("skipping compiled-counter asserts: SKYHOOK_FORCE_SCALAR set");
            return;
        }
        // Every pushed-down object ran the tier; the unsorted predicate
        // column means no window shrink, so the chunked pass covered
        // every row of every object.
        assert!(rc.stats.compiled_chunks > 0, "compiled tier never ran");
        assert_eq!(rc.stats.compiled_rows, 40_000);
    }

    #[test]
    fn pruned_and_unpruned_execution_agree() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        // ts is sorted 0..3000, so a narrow range query prunes most
        // row-group objects at the planner.
        let pred = Predicate::cmp("ts", CmpOp::Lt, 100.0);
        let rq = Query::scan("sensors").filter(pred.clone()).select(&["ts", "val"]);
        let rp = d.execute(&rq, None).unwrap();
        let ru = d.execute_opts(&rq, None, false).unwrap();
        assert!(rp.stats.objects_pruned > 0, "nothing pruned");
        assert!(rp.stats.bytes_skipped > 0);
        assert_eq!(ru.stats.objects_pruned, 0);
        assert!(rp.stats.objects < ru.stats.objects);
        // Bit-identical rows.
        assert_eq!(rp.rows.unwrap(), ru.rows.unwrap());
        // Aggregates agree exactly too (pruned partials are a prefix of
        // the unpruned merge; empty states are merge identities).
        let aq = Query::scan("sensors")
            .filter(pred.clone())
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Sum, "val");
        let ap = d.execute(&aq, None).unwrap();
        let au = d.execute_opts(&aq, None, false).unwrap();
        assert_eq!(ap.aggregates, au.aggregates);
        assert_eq!(ap.aggregates[0], 100.0);
        assert!(ap.stats.bytes_moved < au.stats.bytes_moved);
        // Direct check against the source batch.
        let mask = pred.eval(&b).unwrap();
        let mut st = AggState::new(false);
        st.update_column(b.col("val").unwrap(), &mask).unwrap();
        assert!((ap.aggregates[1] - st.sum).abs() < 1e-6);
    }

    #[test]
    fn fully_pruned_query_returns_empty_not_missing() {
        let d = driver(3, 2);
        seed(&d, 500);
        // ts never reaches 10^9: every object prunes.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .select(&["val"]);
        let r = d.execute(&q, None).unwrap();
        let rows = r.rows.unwrap();
        assert_eq!(rows.nrows(), 0);
        assert_eq!(rows.ncols(), 1);
        assert_eq!(rows.schema.columns[0].name, "val");
        assert_eq!(r.stats.objects, 0);
        assert!(r.stats.objects_pruned > 0);
        assert_eq!(r.stats.bytes_moved, 0);
        // Unpruned execution of the same dead query returns the same
        // (empty) result the long way around.
        let u = d.execute_opts(&q, None, false).unwrap();
        assert_eq!(u.rows.unwrap(), rows);
        // Aggregates over a fully pruned dataset behave like an empty set.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        assert_eq!(r.aggregates[0], 0.0);
        // Group-by: empty group list, same as unpruned.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("ts", CmpOp::Ge, 1e9))
            .group("sensor")
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        assert_eq!(r.groups.unwrap(), vec![]);
    }

    #[test]
    fn median_is_correct_despite_holistic() {
        let d = driver(4, 4);
        let b = seed(&d, 1001);
        let q = Query::scan("sensors").aggregate(AggFunc::Median, "val");
        let r = d.execute(&q, None).unwrap();
        // Direct median.
        let mut vals: Vec<f64> = match b.col("val").unwrap() {
            crate::dataset::table::Column::F32(v) => {
                v.iter().map(|&x| x as f64).collect()
            }
            _ => unreachable!(),
        };
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want = vals[vals.len() / 2];
        assert!((r.aggregates[0] - want).abs() < 1e-9);
        // Holistic: bytes scale with rows.
        assert!(r.stats.bytes_moved > 1001 * 8);
    }

    #[test]
    fn group_by_matches_direct() {
        let d = driver(4, 4);
        let b = seed(&d, 2000);
        let q = Query::scan("sensors")
            .group("sensor")
            .aggregate(AggFunc::Count, "val");
        let r = d.execute(&q, None).unwrap();
        let groups = r.groups.unwrap();
        let total: f64 = groups.iter().map(|(_, v)| v[0]).sum();
        assert_eq!(total, 2000.0);
        // Direct group count for one key.
        let keys = match b.col("sensor").unwrap() {
            crate::dataset::table::Column::I64(v) => v.clone(),
            _ => unreachable!(),
        };
        let k0 = groups[0].0[0];
        let want = keys.iter().filter(|&&k| k == k0).count() as f64;
        assert_eq!(groups[0].1[0], want);
    }

    #[test]
    fn multi_key_multi_agg_group_by_all_modes() {
        let d = driver(4, 4);
        // Larger row groups so grouped partials amortize: the per-object
        // partial is O(groups), the client baseline O(rows).
        let b = gen::sensor_table(3000, 99);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(64 * 1024),
            None,
        )
        .unwrap();
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 40.0))
            .group("sensor")
            .group("flag")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Sum, "val")
            .aggregate(AggFunc::Max, "val");
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        let rd = d.execute(&q, None).unwrap();
        let (gp, gc, gd) = (
            rp.groups.unwrap(),
            rc.groups.unwrap(),
            rd.groups.unwrap(),
        );
        assert_eq!(gp, gc);
        assert_eq!(gp, gd);
        assert!(!gp.is_empty());
        assert!(gp.iter().all(|(k, v)| k.len() == 2 && v.len() == 3));
        // Keys sorted lexicographically and unique.
        for w in gp.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        // Direct totals.
        let mask = q.predicate.eval(&b).unwrap();
        let want = mask.iter().filter(|&&m| m).count() as f64;
        let total: f64 = gp.iter().map(|(_, v)| v[0]).sum();
        assert_eq!(total, want);
        // Grouped pushdown still moves only partials.
        assert!(rp.stats.bytes_moved < rc.stats.bytes_moved);
    }

    #[test]
    fn sort_limit_topk_all_modes_agree() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        // Sorted row query (no limit): total order over the merge.
        let sq = Query::scan("sensors")
            .filter(Predicate::cmp("flag", CmpOp::Eq, 1.0))
            .select(&["ts", "val"])
            .sort_desc("val");
        let rp = d.execute(&sq, Some(ExecMode::Pushdown)).unwrap().rows.unwrap();
        let rc = d.execute(&sq, Some(ExecMode::ClientSide)).unwrap().rows.unwrap();
        assert_eq!(rp, rc);
        let crate::dataset::table::Column::F32(v) = rp.col("val").unwrap() else {
            unreachable!()
        };
        assert!(v.windows(2).all(|w| w[0] >= w[1]));

        // Top-k with the sort key outside the projection: final schema
        // drops it after the merge-side sort.
        let tq = Query::scan("sensors").select(&["ts"]).top_k("val", true, 25);
        let tp = d.execute(&tq, Some(ExecMode::Pushdown)).unwrap();
        let tc = d.execute(&tq, Some(ExecMode::ClientSide)).unwrap();
        let td = d.execute(&tq, None).unwrap();
        let (bp, bc, bd) = (
            tp.rows.unwrap(),
            tc.rows.unwrap(),
            td.rows.unwrap(),
        );
        assert_eq!(bp, bc);
        assert_eq!(bp, bd);
        assert_eq!(bp.nrows(), 25);
        assert_eq!(bp.ncols(), 1);
        assert_eq!(bp.schema.columns[0].name, "ts");
        // Direct check: ts rows of the 25 largest vals.
        let crate::dataset::table::Column::F32(all) = b.col("val").unwrap() else {
            unreachable!()
        };
        let mut idx: Vec<usize> = (0..all.len()).collect();
        idx.sort_by(|&x, &y| all[y].partial_cmp(&all[x]).unwrap());
        let want: std::collections::BTreeSet<i64> = idx[..25].iter().map(|&i| i as i64).collect();
        let crate::dataset::table::Column::I64(got_ts) = bp.col("ts").unwrap() else {
            unreachable!()
        };
        let got: std::collections::BTreeSet<i64> = got_ts.iter().copied().collect();
        assert_eq!(got, want);
        // Per-object truncation makes top-k pushdown move far fewer
        // bytes than the client-side execution of the same plan.
        assert!(
            tp.stats.bytes_moved * 5 < tc.stats.bytes_moved,
            "topk pushdown {} vs client {}",
            tp.stats.bytes_moved,
            tc.stats.bytes_moved
        );

        // Plain limit (no sort): deterministic prefix in object order —
        // first n rows of the dataset, every mode.
        let lq = Query::scan("sensors").select(&["ts"]).limit(40);
        let lp = d.execute(&lq, Some(ExecMode::Pushdown)).unwrap().rows.unwrap();
        let lc = d.execute(&lq, Some(ExecMode::ClientSide)).unwrap().rows.unwrap();
        assert_eq!(lp, lc);
        assert_eq!(lp.nrows(), 40);
        let crate::dataset::table::Column::I64(ts) = lp.col("ts").unwrap() else {
            unreachable!()
        };
        assert!(ts.iter().enumerate().all(|(i, &t)| t == i as i64));
    }

    #[test]
    fn having_filters_groups_in_every_mode() {
        let d = driver(4, 4);
        seed(&d, 3000);
        let base = Query::scan("sensors")
            .group("sensor")
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Mean, "val");
        let all = d.execute(&base, None).unwrap().groups.unwrap();
        let hq = base
            .clone()
            .having(Predicate::cmp("count(val)", CmpOp::Gt, 40.0));
        let hp = d.execute(&hq, Some(ExecMode::Pushdown)).unwrap().groups.unwrap();
        let hc = d.execute(&hq, Some(ExecMode::ClientSide)).unwrap().groups.unwrap();
        let hd = d.execute(&hq, None).unwrap().groups.unwrap();
        assert_eq!(hp, hc);
        assert_eq!(hp, hd);
        // HAVING equals a manual filter of the finalized groups.
        let want: Vec<_> = all.iter().filter(|(_, v)| v[0] > 40.0).cloned().collect();
        assert_eq!(hp, want);
        assert!(!hp.is_empty() && hp.len() < all.len(), "uninteresting cut");
        // Group keys are valid HAVING columns; limit truncates after.
        let kq = base
            .clone()
            .having(Predicate::cmp("sensor", CmpOp::Le, 3.0))
            .limit(2);
        let kg = d.execute(&kq, None).unwrap().groups.unwrap();
        assert!(kg.len() <= 2);
        assert!(kg.iter().all(|(k, _)| k[0] <= 3));
        // Unknown HAVING columns and ungrouped HAVING fail at the plan.
        let bad = base.clone().having(Predicate::cmp("val", CmpOp::Gt, 0.0));
        assert!(d.execute(&bad, None).is_err());
        let scalar = Query::scan("sensors")
            .aggregate(AggFunc::Count, "val")
            .having(Predicate::cmp("count(val)", CmpOp::Gt, 0.0));
        assert!(d.execute(&scalar, None).is_err());
    }

    #[test]
    fn planner_chosen_mixed_modes_match_forced() {
        let d = driver(4, 4);
        let b = seed(&d, 3000);
        // ts < 600 straddles the zone maps: early objects match fully
        // (client-leaning full fetch), later ones partially or not at
        // all — whatever mix the cost model picks, results must equal
        // the forced single-mode runs.
        let q = Query::scan("sensors").filter(Predicate::cmp("ts", CmpOp::Lt, 600.0));
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        let rd = d.execute(&q, None).unwrap();
        let (bp, bc, bd) = (rp.rows.unwrap(), rc.rows.unwrap(), rd.rows.unwrap());
        assert_eq!(bp, bc);
        assert_eq!(bp, bd);
        assert_eq!(bp.nrows(), 600);
        // The chosen plan reports its assignment and its bytes estimate.
        assert_eq!(
            rd.stats.objects_pushdown + rd.stats.objects_client,
            rd.stats.objects
        );
        assert!(rd.stats.bytes_estimated > 0);
        // The estimate tracks the actual bytes within an order of
        // magnitude (it models payloads, not exact wire framing).
        let est = rd.stats.bytes_estimated as f64;
        let act = rd.stats.bytes_moved as f64;
        assert!(est / act < 10.0 && act / est < 10.0, "est {est} vs actual {act}");
        // Forced plans pin the assignment counters to one side.
        assert_eq!(rp.stats.objects_client, 0);
        assert_eq!(rc.stats.objects_pushdown, 0);
        // Direct row-content check against the source batch.
        let crate::dataset::table::Column::I64(ts) = bd.col("ts").unwrap() else {
            unreachable!()
        };
        assert!(ts.iter().all(|&t| t < 600));
        assert_eq!(b.schema, bd.schema);
    }

    #[test]
    fn calibration_feedback_improves_byte_estimates() {
        // val is normal while the zone-map model assumes uniform, so the
        // first estimate for a tail filter is far off; the observed
        // est-vs-actual ratio feeds the calibration map and the second,
        // identical query plans measurably closer to reality.
        let d = driver(4, 4);
        seed(&d, 3000);
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 85.0))
            .select(&["ts"]);
        let r1 = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        let ratio = r1.stats.est_ratio.expect("estimated query records its ratio");
        assert!(ratio > 0.0);
        let cal = d.calibration();
        assert!(!cal.is_empty());
        assert!(cal.column_factor("val").is_some());
        let r2 = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        // Same execution, same actual bytes — only the estimate moves.
        assert_eq!(r1.stats.bytes_moved, r2.stats.bytes_moved);
        let a = r1.stats.bytes_moved as f64;
        let (e1, e2) = (
            r1.stats.bytes_estimated as f64,
            r2.stats.bytes_estimated as f64,
        );
        assert_ne!(e1 as u64, e2 as u64, "calibration must move the estimate");
        assert!(
            (e2 - a).abs() <= (e1 - a).abs(),
            "estimate must move toward reality: e1={e1} e2={e2} actual={a}"
        );
        // Queries on other columns are untouched by this observation.
        let other = Query::scan("sensors").filter(Predicate::cmp("ts", CmpOp::Lt, 100.0));
        let o = d.execute(&other, Some(ExecMode::Pushdown)).unwrap();
        assert!(o.stats.bytes_estimated > 0);
    }

    #[test]
    fn kway_merge_matches_single_sort_semantics() {
        let d = driver(4, 4);
        seed(&d, 2500);
        // Duplicate-heavy sort key (flag ∈ {0,1}) exercises merge ties:
        // stability requires (object, row) order among equal keys, which
        // must match what a stable sort of the concatenation produced.
        let q = Query::scan("sensors")
            .select(&["ts", "flag"])
            .sort("flag")
            .sort_desc("ts");
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap().rows.unwrap();
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap().rows.unwrap();
        assert_eq!(rp, rc);
        assert_eq!(rp.nrows(), 2500);
        let crate::dataset::table::Column::I64(flags) = rp.col("flag").unwrap() else {
            unreachable!()
        };
        assert!(flags.windows(2).all(|w| w[0] <= w[1]));
        // Top-k across modes: pre-sorted pushdown partials and
        // driver-sorted client partials merge to the same answer.
        let tq = Query::scan("sensors").select(&["ts"]).top_k("flag", false, 100);
        let tp = d.execute(&tq, Some(ExecMode::Pushdown)).unwrap().rows.unwrap();
        let tc = d.execute(&tq, Some(ExecMode::ClientSide)).unwrap().rows.unwrap();
        let td = d.execute(&tq, None).unwrap().rows.unwrap();
        assert_eq!(tp, tc);
        assert_eq!(tp, td);
        assert_eq!(tp.nrows(), 100);
    }

    #[test]
    fn client_side_scans_report_coalesced_reads() {
        let d = driver(4, 4);
        // Objects must outgrow the 64 KiB header prefix for ranged reads
        // (and hence coalescing) to happen at all.
        let b = gen::sensor_table(50_000, 7);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(512 * 1024),
            None,
        )
        .unwrap();
        // ts+sensor are adjacent columns in the schema: their extents
        // coalesce into one ranged read per (large enough) object.
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .select(&["ts", "sensor"]);
        let rc = d.execute(&q, Some(ExecMode::ClientSide)).unwrap();
        assert!(
            rc.stats.reads_coalesced > 0,
            "no coalescing observed: {:?}",
            rc.stats
        );
        // Pushdown coalesces on the device; the client stat stays zero.
        let rp = d.execute(&q, Some(ExecMode::Pushdown)).unwrap();
        assert_eq!(rp.stats.reads_coalesced, 0);
        assert_eq!(rp.rows.unwrap(), rc.rows.unwrap());
    }

    #[test]
    fn explain_renders_staged_pipeline() {
        let d = driver(3, 2);
        seed(&d, 1000);
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
            .select(&["ts"])
            .top_k("val", true, 5);
        let e = d.explain(&q, None).unwrap();
        assert!(e.contains("[server] filter"));
        assert!(e.contains("partial top-5"));
        assert!(e.contains("[client] sort"));
        assert!(d.explain(&Query::scan("ghost"), None).is_err());
    }

    #[test]
    fn missing_dataset_errors() {
        let d = driver(2, 2);
        assert!(d.execute(&Query::scan("ghost"), None).is_err());
    }

    #[test]
    fn approx_quantile_matches_exact_within_bound() {
        let d = driver(4, 4);
        seed(&d, 20_000);
        let pred = Predicate::cmp("flag", CmpOp::Eq, 0.0);
        let exact = d
            .execute(
                &Query::scan("sensors")
                    .filter(pred.clone())
                    .aggregate(AggFunc::Median, "val"),
                None,
            )
            .unwrap();
        let (approx, bound, stats) = d.approx_quantile("sensors", "val", 0.5, &pred).unwrap();
        assert!(
            (approx - exact.aggregates[0]).abs() <= 2.0 * bound,
            "approx {approx} exact {} bound {bound}",
            exact.aggregates[0]
        );
        // The approximation is decomposable: per-object partials are
        // constant-size (bounded by the bin count), unlike the exact
        // path whose bytes grow with matching rows.
        assert!(
            stats.bytes_moved < exact.stats.bytes_moved,
            "sketch {} vs exact {}",
            stats.bytes_moved,
            exact.stats.bytes_moved
        );
        let per_object = stats.bytes_moved as usize / stats.objects.max(1);
        assert!(
            per_object <= crate::skyhook::sketch::BINS * 10 + 64,
            "sketch partial not constant-size: {per_object} B/object"
        );
        // Errors propagate.
        assert!(d
            .approx_quantile("sensors", "nope", 0.5, &Predicate::True)
            .is_err());
        assert!(d
            .approx_quantile("ghost", "val", 0.5, &Predicate::True)
            .is_err());
    }

    #[test]
    fn approx_quantile_prunes_like_scan_paths() {
        let d = driver(4, 4);
        seed(&d, 20_000);
        // ts is sorted 0..20000: a narrow range prunes most row groups
        // before any sketch request is issued.
        let pred = Predicate::cmp("ts", CmpOp::Lt, 500.0);
        let (vp, bp, sp) = d.approx_quantile("sensors", "val", 0.5, &pred).unwrap();
        let (vu, bu, su) = d
            .approx_quantile_opts("sensors", "val", 0.5, &pred, false)
            .unwrap();
        assert!(sp.objects_pruned > 0, "nothing pruned");
        assert!(sp.bytes_skipped > 0);
        assert_eq!(su.objects_pruned, 0);
        assert!(sp.objects < su.objects);
        assert!(sp.bytes_moved < su.bytes_moved);
        // Pruned partials are empty sketches (merge identities): the
        // answer and its error bound are bit-identical.
        assert_eq!(vp, vu);
        assert_eq!(bp, bu);
        // A provably dead predicate yields an empty merged sketch — the
        // same error with and without pruning.
        let dead = Predicate::cmp("ts", CmpOp::Ge, 1e12);
        assert!(d.approx_quantile("sensors", "val", 0.5, &dead).is_err());
        assert!(d
            .approx_quantile_opts("sensors", "val", 0.5, &dead, false)
            .is_err());
        // Unknown columns fail fast even when every group would prune.
        assert!(d.approx_quantile("sensors", "nope", 0.5, &dead).is_err());
    }

    #[test]
    fn build_index_counts_rows() {
        let d = driver(3, 2);
        seed(&d, 1200);
        let total = d.build_index("sensors", "sensor").unwrap();
        assert_eq!(total, 1200);
        // f32 columns index too, via the order-preserving total-order
        // encoding (satellite: the old driver rejected them).
        assert_eq!(d.build_index("sensors", "val").unwrap(), 1200);
        // Both columns are now recorded in the dataset metadata, so the
        // planner can offer the IndexScan path and transforms rebuild.
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "sensors").unwrap();
        let DatasetMeta::Table { index_cols, .. } = &meta else {
            unreachable!()
        };
        assert_eq!(index_cols, &["sensor".to_string(), "val".to_string()]);
        // Re-building an already-declared column is idempotent on meta.
        assert_eq!(d.build_index("sensors", "sensor").unwrap(), 1200);
        let (meta2, _) = metadata::load_meta(d.cluster(), 0.0, "sensors").unwrap();
        let DatasetMeta::Table { index_cols, .. } = &meta2 else {
            unreachable!()
        };
        assert_eq!(index_cols.len(), 2);
        // Ghost columns fail fast at the driver, before any fan-out.
        assert!(d.build_index("sensors", "nope").is_err());
    }

    #[test]
    fn transform_layout_roundtrip() {
        let d = driver(3, 2);
        let b = seed(&d, 800);
        let rep = d.transform_layout("sensors", Layout::Row).unwrap();
        assert!(rep.objects > 0);
        // Query still works and agrees after transform.
        let r = d.execute(&Query::scan("sensors"), None).unwrap();
        assert_eq!(r.rows.unwrap().nrows(), b.nrows());
        // No-op transform.
        let rep2 = d.transform_layout("sensors", Layout::Row).unwrap();
        assert_eq!(rep2.objects, 0);
    }

    /// The index subsystem's driver-level contract: the same query
    /// answered through the forced IndexScan path, the forced scan path,
    /// and the planner's free choice is bit-identical (the probe window
    /// over-approximates, the kernel re-filters), probe counters flow
    /// back through `QueryStats`, and a layout transform rebuilds the
    /// postings rather than stranding them.
    #[test]
    fn index_and_scan_paths_agree_bit_identically() {
        let d = driver(4, 4);
        let b = gen::sensor_table(20_000, 99);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(64 * 1024).index("val"),
            None,
        )
        .unwrap();
        let q = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 95.0))
            .aggregate(AggFunc::Count, "val")
            .aggregate(AggFunc::Sum, "val");
        let push = Some(ExecMode::Pushdown);
        let ri = d
            .execute_with_access(&q, push, Some(AccessForce::Index))
            .unwrap();
        let rs = d
            .execute_with_access(&q, push, Some(AccessForce::Scan))
            .unwrap();
        let rf = d.execute_with_access(&q, push, None).unwrap();
        for (a, s) in ri.aggregates.iter().zip(&rs.aggregates) {
            assert_eq!(a.to_bits(), s.to_bits());
        }
        for (a, f) in ri.aggregates.iter().zip(&rf.aggregates) {
            assert_eq!(a.to_bits(), f.to_bits());
        }
        // Ground truth straight off the source batch.
        let mut mask = Vec::new();
        q.predicate.eval_into(&b, &mut mask).unwrap();
        let expect = mask.iter().filter(|&&m| m).count();
        assert!(expect > 0, "needle should match a few rows");
        assert_eq!(ri.aggregates[0], expect as f64);
        // Counters: the forced-index run probed, and its postings are a
        // superset of the matches (pruned objects provably hold none);
        // the forced-scan run never touched the omap.
        assert!(ri.stats.index_probes > 0);
        assert!(ri.stats.index_postings >= expect as u64);
        assert_eq!(rs.stats.index_probes, 0);
        assert_eq!(rs.stats.index_postings, 0);
        // Row queries agree too.
        let qr = Query::scan("sensors")
            .filter(Predicate::cmp("val", CmpOp::Gt, 95.0))
            .select(&["ts", "val"]);
        let bi = d
            .execute_with_access(&qr, push, Some(AccessForce::Index))
            .unwrap()
            .rows
            .unwrap();
        let bs = d
            .execute_with_access(&qr, push, Some(AccessForce::Scan))
            .unwrap()
            .rows
            .unwrap();
        assert_eq!(bi.nrows(), expect);
        assert_eq!(bs.nrows(), expect);
        // A layout transform rewrites every object and re-stamps its
        // postings; the probe path answers identically afterwards.
        d.transform_layout("sensors", Layout::Row).unwrap();
        let rt = d
            .execute_with_access(&q, push, Some(AccessForce::Index))
            .unwrap();
        for (a, s) in rt.aggregates.iter().zip(&ri.aggregates) {
            assert_eq!(a.to_bits(), s.to_bits());
        }
        assert!(rt.stats.index_probes > 0);
    }

    #[test]
    fn locality_assignment_places_groups_together() {
        let d = driver(4, 2);
        let b = gen::sensor_table(2000, 5);
        d.write_table(
            "loc",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(4 * 1024),
            Some(&|i, _| format!("bucket{}", i % 2)),
        )
        .unwrap();
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "loc").unwrap();
        let names = meta.object_names("loc");
        // All bucket0 objects share a placement, likewise bucket1.
        let p0: Vec<_> = names
            .iter()
            .filter(|n| n.starts_with("bucket0#"))
            .map(|n| d.cluster().placement(n))
            .collect();
        assert!(p0.len() > 1);
        assert!(p0.windows(2).all(|w| w[0] == w[1]), "bucket0 not co-located");
        // Query still reads everything.
        let r = d.execute(&Query::scan("loc"), None).unwrap();
        assert_eq!(r.rows.unwrap().nrows(), 2000);
    }

    #[test]
    fn delete_append_compact_lifecycle() {
        // This test walks the *unforced* lifecycle: it asserts the
        // intermediate tombstone/claim states that SKYHOOK_FORCE_COMPACT=1
        // deliberately collapses (every mutation compacts on the spot).
        // The forced pass still covers mutations end to end via the
        // router, CLI serve, and mutate-then-query property tests.
        if std::env::var("SKYHOOK_FORCE_COMPACT").map_or(false, |v| v == "1") {
            return;
        }
        let d = driver(4, 4);
        let b = gen::sensor_table(4000, 99);
        d.write_table(
            "sensors",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(16 * 1024)
                .cluster_by("ts")
                .index("sensor"),
            None,
        )
        .unwrap();
        let count_q = Query::scan("sensors").aggregate(AggFunc::Count, "val");
        let count = |d: &Driver, m: Option<ExecMode>| d.execute(&count_q, m).unwrap().aggregates[0];
        assert_eq!(count(&d, None), 4000.0);

        // Delete the first 50 rows of row group 0 (ts 0..50 — the
        // cluster_by("ts") sort is the identity on this table).
        let rows: Vec<u32> = (0..50).collect();
        assert_eq!(d.delete_rows("sensors", 0, &rows).unwrap(), 50);
        // Idempotent: stamping the same rows again changes nothing.
        assert_eq!(d.delete_rows("sensors", 0, &rows).unwrap(), 50);
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "sensors").unwrap();
        let DatasetMeta::Table { muta, .. } = &meta else {
            unreachable!()
        };
        assert_eq!(muta.tombstones_of(0), 50);
        // Every execution mode answers without the tombstoned rows.
        assert_eq!(count(&d, Some(ExecMode::Pushdown)), 3950.0);
        assert_eq!(count(&d, Some(ExecMode::ClientSide)), 3950.0);
        assert_eq!(count(&d, None), 3950.0);
        // Out-of-range requests fail without touching anything.
        assert!(d.delete_rows("sensors", 99, &[0]).is_err());
        assert!(d.delete_rows("sensors", 0, &[u32::MAX]).is_err());

        // Append: counts rise, the global ordering claim drops, the
        // clustering intent is preserved for the compactor.
        let extra = gen::sensor_table(1000, 7);
        let rep = d.append("sensors", &extra, 16 * 1024).unwrap();
        assert!(rep.objects > 0);
        assert_eq!(count(&d, Some(ExecMode::Pushdown)), 4950.0);
        assert_eq!(count(&d, Some(ExecMode::ClientSide)), 4950.0);
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "sensors").unwrap();
        let DatasetMeta::Table {
            cluster_by, muta, ..
        } = &meta
        else {
            unreachable!()
        };
        assert_eq!(cluster_by, "", "append must clear the global claim");
        assert_eq!(muta.compact_by, "ts", "intent must be preserved");
        assert_eq!(muta.generation, 0);
        // Appends with a mismatched schema are rejected up front.
        let bad = gen::sensor_table(10, 1).project(&["ts", "val"]).unwrap();
        assert!(d.append("sensors", &bad, 16 * 1024).is_err());

        // The reference the compacted dataset must answer like: live
        // original rows then appended rows, stably re-sorted by ts.
        let mut reference = b.slice(50, 4000).unwrap();
        reference.concat(&extra).unwrap();
        let expected = reference.sort_by_column("ts").unwrap();

        // Compact: one generation flip — dead rows gone, re-sorted,
        // markers and postings fresh, claim restored.
        let old_names = meta.object_names("sensors");
        let crep = d.compact("sensors").unwrap();
        assert!(crep.objects > 0);
        assert_eq!(d.compactions(), 1);
        let (meta2, _) = metadata::load_meta(d.cluster(), 0.0, "sensors").unwrap();
        let DatasetMeta::Table {
            cluster_by, muta, ..
        } = &meta2
        else {
            unreachable!()
        };
        assert_eq!(cluster_by, "ts", "compaction restores the claim");
        assert_eq!(muta.generation, 1);
        assert!(muta.tombstones.is_empty());
        assert!(muta.compact_by.is_empty());
        let new_names = meta2.object_names("sensors");
        assert!(new_names.iter().all(|n| n.starts_with("sensors/g1/t/")));
        // Old-generation objects are gone after the commit.
        for n in &old_names {
            assert!(d.cluster().read_object(0.0, n).is_err(), "{n} survived");
        }
        // Answers: the full scan equals the re-sorted reference bit for
        // bit, in every mode.
        for m in [None, Some(ExecMode::Pushdown), Some(ExecMode::ClientSide)] {
            let got = d.execute(&Query::scan("sensors"), m).unwrap().rows.unwrap();
            assert_eq!(got, expected);
        }
        // Markers and postings hold up under the debug re-scans.
        assert_eq!(metadata::verify_sortedness(d.cluster(), "sensors").unwrap(), Vec::<String>::new());
        assert_eq!(metadata::verify_index(d.cluster(), "sensors").unwrap(), Vec::<String>::new());
        // The restored clustering serves bounded prefix reads again.
        let head = d
            .execute(&Query::scan("sensors").select(&["ts"]).top_k("ts", false, 5), None)
            .unwrap();
        assert!(head.stats.prefix_reads > 0, "clustered payoff lost");
    }

    #[test]
    fn heavy_deletes_trigger_auto_compaction() {
        let d = driver(3, 2);
        let b = gen::sensor_table(500, 11);
        d.write_table(
            "churn",
            &b,
            Layout::Col,
            &PartitionSpec::with_target(4 * 1024),
            None,
        )
        .unwrap();
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "churn").unwrap();
        let DatasetMeta::Table { row_groups, .. } = &meta else {
            unreachable!()
        };
        assert!(row_groups.len() > 1, "need multiple groups");
        let g0 = row_groups[0].rows;
        assert!(
            g0 as f64 > 0.25 * 500.0,
            "group 0 ({g0} rows) too small to cross the threshold"
        );
        // Tombstone all of group 0: the delete itself must auto-compact.
        let rows: Vec<u32> = (0..g0 as u32).collect();
        d.delete_rows("churn", 0, &rows).unwrap();
        assert_eq!(d.compactions(), 1, "threshold crossing must compact");
        let (meta, _) = metadata::load_meta(d.cluster(), 0.0, "churn").unwrap();
        let DatasetMeta::Table { muta, .. } = &meta else {
            unreachable!()
        };
        assert_eq!(muta.generation, 1);
        assert!(muta.tombstones.is_empty());
        let r = d.execute(&Query::scan("churn"), None).unwrap();
        assert_eq!(r.rows.unwrap(), b.slice(g0 as usize, 500).unwrap());
    }

    #[test]
    fn more_osds_reduce_sim_makespan() {
        let rows = 20_000;
        let mut sims = Vec::new();
        for osds in [1, 4] {
            let d = driver(osds, 4);
            let b = gen::sensor_table(rows, 7);
            d.write_table(
                "ds",
                &b,
                Layout::Col,
                &PartitionSpec::with_target(16 * 1024),
                None,
            )
            .unwrap();
            d.reset_time();
            let r = d
                .execute(&Query::scan("ds").aggregate(AggFunc::Sum, "val"), None)
                .unwrap();
            sims.push(r.stats.sim_seconds);
        }
        assert!(
            sims[1] < sims[0] * 0.6,
            "4 OSDs should beat 1: {sims:?}"
        );
    }
}
