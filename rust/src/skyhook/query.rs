//! Query model: predicates, projections and aggregates over table
//! datasets — the `select / project / filter / aggregate` surface the
//! paper offloads to the storage system (§2 goal 2), plus the partial-
//! aggregate algebra that decides composability (§3.2).

use crate::dataset::table::{Batch, Column};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};

/// Comparison operator for predicates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    pub fn eval(self, a: f64, b: f64) -> bool {
        match self {
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
        }
    }

    fn code(self) -> u8 {
        match self {
            CmpOp::Lt => 0,
            CmpOp::Le => 1,
            CmpOp::Gt => 2,
            CmpOp::Ge => 3,
            CmpOp::Eq => 4,
            CmpOp::Ne => 5,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => CmpOp::Lt,
            1 => CmpOp::Le,
            2 => CmpOp::Gt,
            3 => CmpOp::Ge,
            4 => CmpOp::Eq,
            5 => CmpOp::Ne,
            o => return Err(Error::Corrupt(format!("bad cmp op {o}"))),
        })
    }
}

/// Row predicate over numeric columns.
#[derive(Clone, Debug, PartialEq)]
pub enum Predicate {
    /// Matches every row.
    True,
    /// `col <op> value` (numeric columns; i64 compared as f64).
    Cmp {
        col: String,
        op: CmpOp,
        value: f64,
    },
    And(Box<Predicate>, Box<Predicate>),
    Or(Box<Predicate>, Box<Predicate>),
    Not(Box<Predicate>),
}

impl Predicate {
    /// Convenience constructor.
    pub fn cmp(col: &str, op: CmpOp, value: f64) -> Predicate {
        Predicate::Cmp {
            col: col.to_string(),
            op,
            value,
        }
    }

    pub fn and(self, other: Predicate) -> Predicate {
        Predicate::And(Box::new(self), Box::new(other))
    }

    pub fn or(self, other: Predicate) -> Predicate {
        Predicate::Or(Box::new(self), Box::new(other))
    }

    pub fn not(self) -> Predicate {
        Predicate::Not(Box::new(self))
    }

    /// Column names referenced by this predicate.
    pub fn columns(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_columns(&mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_columns(&self, out: &mut Vec<String>) {
        match self {
            Predicate::True => {}
            Predicate::Cmp { col, .. } => out.push(col.clone()),
            Predicate::And(a, b) | Predicate::Or(a, b) => {
                a.collect_columns(out);
                b.collect_columns(out);
            }
            Predicate::Not(p) => p.collect_columns(out),
        }
    }

    /// Evaluate to a row mask over a batch.
    pub fn eval(&self, batch: &Batch) -> Result<Vec<bool>> {
        let n = batch.nrows();
        match self {
            Predicate::True => Ok(vec![true; n]),
            Predicate::Cmp { col, op, value } => {
                let c = batch.col(col)?;
                let mut mask = Vec::with_capacity(n);
                match c {
                    Column::F32(v) => {
                        for &x in v {
                            mask.push(op.eval(x as f64, *value));
                        }
                    }
                    Column::F64(v) => {
                        for &x in v {
                            mask.push(op.eval(x, *value));
                        }
                    }
                    Column::I64(v) => {
                        for &x in v {
                            mask.push(op.eval(x as f64, *value));
                        }
                    }
                    Column::Str(_) => {
                        return Err(Error::Query(format!(
                            "predicate on string column {col:?}"
                        )))
                    }
                }
                Ok(mask)
            }
            Predicate::And(a, b) => {
                let ma = a.eval(batch)?;
                let mb = b.eval(batch)?;
                Ok(ma.into_iter().zip(mb).map(|(x, y)| x && y).collect())
            }
            Predicate::Or(a, b) => {
                let ma = a.eval(batch)?;
                let mb = b.eval(batch)?;
                Ok(ma.into_iter().zip(mb).map(|(x, y)| x || y).collect())
            }
            Predicate::Not(p) => Ok(p.eval(batch)?.into_iter().map(|x| !x).collect()),
        }
    }

    /// Wire encoding (for objclass input).
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            Predicate::True => {
                w.u8(0);
            }
            Predicate::Cmp { col, op, value } => {
                w.u8(1);
                w.str(col);
                w.u8(op.code());
                w.f64(*value);
            }
            Predicate::And(a, b) => {
                w.u8(2);
                a.encode_into(w);
                b.encode_into(w);
            }
            Predicate::Or(a, b) => {
                w.u8(3);
                a.encode_into(w);
                b.encode_into(w);
            }
            Predicate::Not(p) => {
                w.u8(4);
                p.encode_into(w);
            }
        }
    }

    pub fn decode_from(r: &mut ByteReader) -> Result<Predicate> {
        Ok(match r.u8()? {
            0 => Predicate::True,
            1 => Predicate::Cmp {
                col: r.str()?.to_string(),
                op: CmpOp::from_code(r.u8()?)?,
                value: r.f64()?,
            },
            2 => Predicate::And(
                Box::new(Self::decode_from(r)?),
                Box::new(Self::decode_from(r)?),
            ),
            3 => Predicate::Or(
                Box::new(Self::decode_from(r)?),
                Box::new(Self::decode_from(r)?),
            ),
            4 => Predicate::Not(Box::new(Self::decode_from(r)?)),
            o => return Err(Error::Corrupt(format!("bad predicate tag {o}"))),
        })
    }
}

/// Aggregate functions. All but `Median` are *algebraic*: they have a
/// constant-size partial state that merges associatively, so they
/// decompose over objects (§3.2). `Median` is *holistic*: its exact
/// computation needs the values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Count,
    Sum,
    Min,
    Max,
    Mean,
    Var,
    Median,
}

impl AggFunc {
    /// Algebraic aggregates decompose into constant-size partials.
    pub fn is_algebraic(self) -> bool {
        !matches!(self, AggFunc::Median)
    }

    pub fn name(self) -> &'static str {
        match self {
            AggFunc::Count => "count",
            AggFunc::Sum => "sum",
            AggFunc::Min => "min",
            AggFunc::Max => "max",
            AggFunc::Mean => "mean",
            AggFunc::Var => "var",
            AggFunc::Median => "median",
        }
    }

    pub(crate) fn code(self) -> u8 {
        match self {
            AggFunc::Count => 0,
            AggFunc::Sum => 1,
            AggFunc::Min => 2,
            AggFunc::Max => 3,
            AggFunc::Mean => 4,
            AggFunc::Var => 5,
            AggFunc::Median => 6,
        }
    }

    #[allow(dead_code)]
    pub(crate) fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => AggFunc::Count,
            1 => AggFunc::Sum,
            2 => AggFunc::Min,
            3 => AggFunc::Max,
            4 => AggFunc::Mean,
            5 => AggFunc::Var,
            6 => AggFunc::Median,
            o => return Err(Error::Corrupt(format!("bad agg code {o}"))),
        })
    }
}

/// One aggregate column request.
#[derive(Clone, Debug, PartialEq)]
pub struct Aggregate {
    pub func: AggFunc,
    pub col: String,
}

impl Aggregate {
    pub fn new(func: AggFunc, col: &str) -> Self {
        Self {
            func,
            col: col.to_string(),
        }
    }
}

/// Mergeable partial aggregate state. Constant-size for algebraic
/// functions; carries raw values only when a holistic function needs them.
#[derive(Clone, Debug, PartialEq)]
pub struct AggState {
    pub count: u64,
    pub sum: f64,
    pub sumsq: f64,
    pub min: f64,
    pub max: f64,
    /// Raw values, kept only for holistic aggregates.
    pub values: Option<Vec<f64>>,
}

impl AggState {
    pub fn new(keep_values: bool) -> Self {
        Self {
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            values: keep_values.then(Vec::new),
        }
    }

    /// Fold a column (under a mask) into the state. One type dispatch per
    /// column, tight masked loop (native fallback of the pushdown
    /// aggregate hot path — the PJRT kernel replaces it when loaded).
    pub fn update_column(&mut self, col: &Column, mask: &[bool]) -> Result<()> {
        if mask.len() != col.len() {
            return Err(Error::Query(format!(
                "mask len {} != column len {}",
                mask.len(),
                col.len()
            )));
        }
        match col {
            Column::F32(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x as f64);
                    }
                }
            }
            Column::F64(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x);
                    }
                }
            }
            Column::I64(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x as f64);
                    }
                }
            }
            Column::Str(_) => {
                return Err(Error::Query("cannot aggregate a string column".into()))
            }
        }
        Ok(())
    }

    #[inline]
    pub fn update(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sumsq += x * x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if let Some(v) = &mut self.values {
            v.push(x);
        }
    }

    pub fn merge(&mut self, other: &AggState) {
        self.count += other.count;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        match (&mut self.values, &other.values) {
            (Some(a), Some(b)) => a.extend_from_slice(b),
            (Some(_), None) | (None, Some(_)) => {
                // Mixed states: drop values (caller decides holistic needs).
                self.values = None;
            }
            (None, None) => {}
        }
    }

    /// Final value for a function.
    pub fn finalize(&self, func: AggFunc) -> Result<f64> {
        if self.count == 0 {
            return match func {
                AggFunc::Count => Ok(0.0),
                AggFunc::Sum => Ok(0.0),
                _ => Err(Error::Query(format!("{} of empty set", func.name()))),
            };
        }
        Ok(match func {
            AggFunc::Count => self.count as f64,
            AggFunc::Sum => self.sum,
            AggFunc::Min => self.min,
            AggFunc::Max => self.max,
            AggFunc::Mean => self.sum / self.count as f64,
            AggFunc::Var => {
                let n = self.count as f64;
                (self.sumsq - self.sum * self.sum / n) / n
            }
            AggFunc::Median => {
                let mut v = self
                    .values
                    .clone()
                    .ok_or_else(|| Error::Query("median needs raw values".into()))?;
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = v.len();
                if n % 2 == 1 {
                    v[n / 2]
                } else {
                    (v[n / 2 - 1] + v[n / 2]) / 2.0
                }
            }
        })
    }

    /// Serialized size estimate (what crosses the network as a partial).
    pub fn wire_bytes(&self) -> usize {
        8 * 5 + 1 + self.values.as_ref().map_or(0, |v| 4 + v.len() * 8)
    }

    pub fn encode_into(&self, w: &mut ByteWriter) {
        w.u64(self.count);
        w.f64(self.sum);
        w.f64(self.sumsq);
        w.f64(self.min);
        w.f64(self.max);
        match &self.values {
            Some(v) => {
                w.u8(1);
                w.u32(v.len() as u32);
                for &x in v {
                    w.f64(x);
                }
            }
            None => {
                w.u8(0);
            }
        }
    }

    pub fn decode_from(r: &mut ByteReader) -> Result<AggState> {
        let count = r.u64()?;
        let sum = r.f64()?;
        let sumsq = r.f64()?;
        let min = r.f64()?;
        let max = r.f64()?;
        let values = match r.u8()? {
            0 => None,
            1 => {
                let n = r.u32()? as usize;
                let mut v = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    v.push(r.f64()?);
                }
                Some(v)
            }
            o => return Err(Error::Corrupt(format!("bad values tag {o}"))),
        };
        Ok(AggState {
            count,
            sum,
            sumsq,
            min,
            max,
            values,
        })
    }
}

/// A full query against a table dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    pub dataset: String,
    /// Row filter.
    pub predicate: Predicate,
    /// Columns to return (row queries). `None` = all columns.
    pub projection: Option<Vec<String>>,
    /// Aggregates (if non-empty, the query returns aggregate values, not
    /// rows).
    pub aggregates: Vec<Aggregate>,
    /// Optional group-by column (i64) for aggregate queries.
    pub group_by: Option<String>,
}

impl Query {
    /// A full-scan row query.
    pub fn scan(dataset: &str) -> Query {
        Query {
            dataset: dataset.to_string(),
            predicate: Predicate::True,
            projection: None,
            aggregates: Vec::new(),
            group_by: None,
        }
    }

    pub fn filter(mut self, p: Predicate) -> Query {
        self.predicate = p;
        self
    }

    pub fn select(mut self, cols: &[&str]) -> Query {
        self.projection = Some(cols.iter().map(|s| s.to_string()).collect());
        self
    }

    pub fn aggregate(mut self, func: AggFunc, col: &str) -> Query {
        self.aggregates.push(Aggregate::new(func, col));
        self
    }

    pub fn group(mut self, col: &str) -> Query {
        self.group_by = Some(col.to_string());
        self
    }

    pub fn is_aggregate(&self) -> bool {
        !self.aggregates.is_empty()
    }

    /// All aggregates algebraic → fully decomposable (§3.2).
    pub fn is_decomposable(&self) -> bool {
        self.aggregates.iter().all(|a| a.func.is_algebraic())
    }

    /// Columns this query needs to touch (predicate ∪ projection ∪ aggs ∪
    /// group key).
    pub fn needed_columns(&self, all: &[String]) -> Vec<String> {
        let mut out = self.predicate.columns();
        match (&self.projection, self.is_aggregate()) {
            (_, true) => {
                out.extend(self.aggregates.iter().map(|a| a.col.clone()));
                if let Some(g) = &self.group_by {
                    out.push(g.clone());
                }
            }
            (Some(p), false) => out.extend(p.iter().cloned()),
            (None, false) => out.extend(all.iter().cloned()),
        }
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::dataset::{DType, TableSchema};

    fn batch() -> Batch {
        Batch::new(
            TableSchema::new(&[("id", DType::I64), ("v", DType::F32)]),
            vec![
                Column::I64(vec![1, 2, 3, 4, 5]),
                Column::F32(vec![10.0, 20.0, 30.0, 40.0, 50.0]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn cmp_ops() {
        assert!(CmpOp::Lt.eval(1.0, 2.0));
        assert!(CmpOp::Le.eval(2.0, 2.0));
        assert!(CmpOp::Gt.eval(3.0, 2.0));
        assert!(CmpOp::Ge.eval(2.0, 2.0));
        assert!(CmpOp::Eq.eval(2.0, 2.0));
        assert!(CmpOp::Ne.eval(1.0, 2.0));
    }

    #[test]
    fn predicate_eval() {
        let b = batch();
        let p = Predicate::cmp("v", CmpOp::Gt, 25.0);
        assert_eq!(p.eval(&b).unwrap(), vec![false, false, true, true, true]);
        let p = Predicate::cmp("v", CmpOp::Gt, 15.0).and(Predicate::cmp("id", CmpOp::Lt, 4.0));
        assert_eq!(p.eval(&b).unwrap(), vec![false, true, true, false, false]);
        let p = Predicate::cmp("id", CmpOp::Eq, 1.0).or(Predicate::cmp("id", CmpOp::Eq, 5.0));
        assert_eq!(p.eval(&b).unwrap(), vec![true, false, false, false, true]);
        let p = Predicate::cmp("v", CmpOp::Gt, 25.0).not();
        assert_eq!(p.eval(&b).unwrap(), vec![true, true, false, false, false]);
        assert_eq!(Predicate::True.eval(&b).unwrap(), vec![true; 5]);
    }

    #[test]
    fn predicate_errors() {
        let b = Batch::new(
            TableSchema::new(&[("s", DType::Str)]),
            vec![Column::Str(vec!["x".into()])],
        )
        .unwrap();
        assert!(Predicate::cmp("s", CmpOp::Eq, 1.0).eval(&b).is_err());
        assert!(Predicate::cmp("zzz", CmpOp::Eq, 1.0).eval(&batch()).is_err());
    }

    #[test]
    fn predicate_columns() {
        let p = Predicate::cmp("a", CmpOp::Gt, 0.0)
            .and(Predicate::cmp("b", CmpOp::Lt, 1.0).or(Predicate::cmp("a", CmpOp::Eq, 2.0)));
        assert_eq!(p.columns(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn predicate_wire_roundtrip() {
        let p = Predicate::cmp("col x", CmpOp::Ge, -2.5)
            .and(Predicate::True.or(Predicate::cmp("y", CmpOp::Ne, 7.0).not()));
        let mut w = ByteWriter::new();
        p.encode_into(&mut w);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(Predicate::decode_from(&mut r).unwrap(), p);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn agg_state_basics() {
        let mut s = AggState::new(false);
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.update(x);
        }
        assert_eq!(s.finalize(AggFunc::Count).unwrap(), 4.0);
        assert_eq!(s.finalize(AggFunc::Sum).unwrap(), 10.0);
        assert_eq!(s.finalize(AggFunc::Min).unwrap(), 1.0);
        assert_eq!(s.finalize(AggFunc::Max).unwrap(), 4.0);
        assert_eq!(s.finalize(AggFunc::Mean).unwrap(), 2.5);
        assert!((s.finalize(AggFunc::Var).unwrap() - 1.25).abs() < 1e-12);
        assert!(s.finalize(AggFunc::Median).is_err(), "no values kept");
    }

    #[test]
    fn agg_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 19) as f64 - 9.0).collect();
        let mut whole = AggState::new(true);
        let mut a = AggState::new(true);
        let mut b = AggState::new(true);
        for (i, &x) in xs.iter().enumerate() {
            whole.update(x);
            if i % 2 == 0 {
                a.update(x)
            } else {
                b.update(x)
            }
        }
        a.merge(&b);
        for f in [
            AggFunc::Count,
            AggFunc::Sum,
            AggFunc::Min,
            AggFunc::Max,
            AggFunc::Mean,
            AggFunc::Var,
            AggFunc::Median,
        ] {
            let x = a.finalize(f).unwrap();
            let y = whole.finalize(f).unwrap();
            assert!((x - y).abs() < 1e-9, "{}: {x} vs {y}", f.name());
        }
    }

    #[test]
    fn agg_empty_set() {
        let s = AggState::new(false);
        assert_eq!(s.finalize(AggFunc::Count).unwrap(), 0.0);
        assert_eq!(s.finalize(AggFunc::Sum).unwrap(), 0.0);
        assert!(s.finalize(AggFunc::Min).is_err());
        assert!(s.finalize(AggFunc::Mean).is_err());
    }

    #[test]
    fn agg_median_even_odd() {
        let mut s = AggState::new(true);
        for x in [5.0, 1.0, 3.0] {
            s.update(x);
        }
        assert_eq!(s.finalize(AggFunc::Median).unwrap(), 3.0);
        s.update(7.0);
        assert_eq!(s.finalize(AggFunc::Median).unwrap(), 4.0);
    }

    #[test]
    fn agg_state_wire_roundtrip() {
        let mut s = AggState::new(true);
        for x in [1.5, -2.0, 8.25] {
            s.update(x);
        }
        let mut w = ByteWriter::new();
        s.encode_into(&mut w);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        let d = AggState::decode_from(&mut r).unwrap();
        assert_eq!(d, s);
        assert!(s.wire_bytes() >= buf.len());

        // Without values the wire size is constant.
        let mut s2 = AggState::new(false);
        for i in 0..10_000 {
            s2.update(i as f64);
        }
        assert!(s2.wire_bytes() < 64);
    }

    #[test]
    fn agg_merge_mixed_values_drops() {
        let mut a = AggState::new(true);
        a.update(1.0);
        let mut b = AggState::new(false);
        b.update(2.0);
        a.merge(&b);
        assert!(a.values.is_none());
        assert_eq!(a.count, 2);
    }

    #[test]
    fn update_column_with_mask() {
        let b = batch();
        let mut s = AggState::new(false);
        s.update_column(b.col("v").unwrap(), &[true, false, true, false, true])
            .unwrap();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 90.0);
    }

    #[test]
    fn query_builder_and_properties() {
        let q = Query::scan("ds")
            .filter(Predicate::cmp("v", CmpOp::Gt, 0.0))
            .aggregate(AggFunc::Mean, "v")
            .aggregate(AggFunc::Count, "v");
        assert!(q.is_aggregate());
        assert!(q.is_decomposable());
        let q2 = Query::scan("ds").aggregate(AggFunc::Median, "v");
        assert!(!q2.is_decomposable());
        let q3 = Query::scan("ds").select(&["a", "b"]);
        assert!(!q3.is_aggregate());
    }

    #[test]
    fn needed_columns() {
        let all = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let q = Query::scan("ds")
            .filter(Predicate::cmp("a", CmpOp::Gt, 0.0))
            .select(&["b"]);
        assert_eq!(q.needed_columns(&all), vec!["a", "b"]);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("a", CmpOp::Gt, 0.0))
            .aggregate(AggFunc::Sum, "c")
            .group("b");
        assert_eq!(q.needed_columns(&all), vec!["a", "b", "c"]);
        let q = Query::scan("ds");
        assert_eq!(q.needed_columns(&all), all);
    }

    #[test]
    fn agg_on_generated_table() {
        let b = gen::sensor_table(1000, 4);
        let mask = Predicate::cmp("flag", CmpOp::Eq, 1.0).eval(&b).unwrap();
        let mut s = AggState::new(false);
        s.update_column(b.col("val").unwrap(), &mask).unwrap();
        let frac = s.count as f64 / 1000.0;
        assert!(frac > 0.01 && frac < 0.15, "flag fraction {frac}");
    }
}
