//! Query model: predicates, projections and aggregates over table
//! datasets — the `select / project / filter / aggregate` surface the
//! paper offloads to the storage system (§2 goal 2), plus the partial-
//! aggregate algebra that decides composability (§3.2).

use crate::dataset::metadata::ValueRange;
use crate::dataset::table::{Batch, Column};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, ByteWriter};
use std::fmt;

/// Comparison operator for predicates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    pub fn eval(self, a: f64, b: f64) -> bool {
        match self {
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
        }
    }

    pub fn symbol(self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
        }
    }

    fn code(self) -> u8 {
        match self {
            CmpOp::Lt => 0,
            CmpOp::Le => 1,
            CmpOp::Gt => 2,
            CmpOp::Ge => 3,
            CmpOp::Eq => 4,
            CmpOp::Ne => 5,
        }
    }

    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => CmpOp::Lt,
            1 => CmpOp::Le,
            2 => CmpOp::Gt,
            3 => CmpOp::Ge,
            4 => CmpOp::Eq,
            5 => CmpOp::Ne,
            o => return Err(Error::Corrupt(format!("bad cmp op {o}"))),
        })
    }
}

/// Row predicate over numeric columns.
#[derive(Clone, Debug, PartialEq)]
pub enum Predicate {
    /// Matches every row.
    True,
    /// `col <op> value` (numeric columns; i64 compared as f64).
    Cmp {
        col: String,
        op: CmpOp,
        value: f64,
    },
    And(Box<Predicate>, Box<Predicate>),
    Or(Box<Predicate>, Box<Predicate>),
    Not(Box<Predicate>),
}

impl Predicate {
    /// Convenience constructor.
    pub fn cmp(col: &str, op: CmpOp, value: f64) -> Predicate {
        Predicate::Cmp {
            col: col.to_string(),
            op,
            value,
        }
    }

    pub fn and(self, other: Predicate) -> Predicate {
        Predicate::And(Box::new(self), Box::new(other))
    }

    pub fn or(self, other: Predicate) -> Predicate {
        Predicate::Or(Box::new(self), Box::new(other))
    }

    pub fn not(self) -> Predicate {
        Predicate::Not(Box::new(self))
    }

    /// Column names referenced by this predicate (borrowed, sorted,
    /// deduped).
    pub fn columns(&self) -> Vec<&str> {
        let mut out = Vec::new();
        self.collect_columns(&mut out);
        out.sort_unstable();
        out.dedup();
        out
    }

    fn collect_columns<'a>(&'a self, out: &mut Vec<&'a str>) {
        match self {
            Predicate::True => {}
            Predicate::Cmp { col, .. } => out.push(col.as_str()),
            Predicate::And(a, b) | Predicate::Or(a, b) => {
                a.collect_columns(out);
                b.collect_columns(out);
            }
            Predicate::Not(p) => p.collect_columns(out),
        }
    }

    /// Evaluate to a row mask over a batch.
    pub fn eval(&self, batch: &Batch) -> Result<Vec<bool>> {
        let mut mask = Vec::new();
        self.eval_into(batch, &mut mask)?;
        Ok(mask)
    }

    /// Evaluate into a caller-owned, reusable mask buffer.
    ///
    /// The scan hot loop calls this once per object; `mask` is cleared
    /// and resized, so a reused buffer costs zero allocations after the
    /// first object. Conjunctive/disjunctive chains combine in place
    /// (`mask &= leaf` / `mask |= leaf`); a scratch buffer is allocated
    /// only where the tree alternates between And- and Or-shaped
    /// subtrees.
    pub fn eval_into(&self, batch: &Batch, mask: &mut Vec<bool>) -> Result<()> {
        mask.clear();
        mask.resize(batch.nrows(), true);
        self.apply(batch, mask, false, Comb::And)
    }

    /// Fold `(negate ? !self : self)` into `mask` under `comb`.
    fn apply(&self, batch: &Batch, mask: &mut [bool], negate: bool, comb: Comb) -> Result<()> {
        match self {
            Predicate::True => {
                match (comb, negate) {
                    (Comb::And, true) => mask.fill(false),
                    (Comb::Or, false) => mask.fill(true),
                    _ => {}
                }
                Ok(())
            }
            Predicate::Cmp { col, op, value } => {
                cmp_apply(batch.col(col)?, col, *op, *value, mask, negate, comb)
            }
            Predicate::And(a, b) => match (comb, negate) {
                (Comb::And, false) => {
                    a.apply(batch, mask, false, Comb::And)?;
                    b.apply(batch, mask, false, Comb::And)
                }
                // De Morgan: !(a && b) == !a || !b.
                (Comb::Or, true) => {
                    a.apply(batch, mask, true, Comb::Or)?;
                    b.apply(batch, mask, true, Comb::Or)
                }
                (Comb::Or, false) => {
                    let mut scratch = vec![true; mask.len()];
                    a.apply(batch, &mut scratch, false, Comb::And)?;
                    b.apply(batch, &mut scratch, false, Comb::And)?;
                    for (m, s) in mask.iter_mut().zip(&scratch) {
                        *m |= *s;
                    }
                    Ok(())
                }
                (Comb::And, true) => {
                    let mut scratch = vec![false; mask.len()];
                    a.apply(batch, &mut scratch, true, Comb::Or)?;
                    b.apply(batch, &mut scratch, true, Comb::Or)?;
                    for (m, s) in mask.iter_mut().zip(&scratch) {
                        *m &= *s;
                    }
                    Ok(())
                }
            },
            Predicate::Or(a, b) => match (comb, negate) {
                (Comb::Or, false) => {
                    a.apply(batch, mask, false, Comb::Or)?;
                    b.apply(batch, mask, false, Comb::Or)
                }
                // De Morgan: !(a || b) == !a && !b.
                (Comb::And, true) => {
                    a.apply(batch, mask, true, Comb::And)?;
                    b.apply(batch, mask, true, Comb::And)
                }
                (Comb::And, false) => {
                    let mut scratch = vec![false; mask.len()];
                    a.apply(batch, &mut scratch, false, Comb::Or)?;
                    b.apply(batch, &mut scratch, false, Comb::Or)?;
                    for (m, s) in mask.iter_mut().zip(&scratch) {
                        *m &= *s;
                    }
                    Ok(())
                }
                (Comb::Or, true) => {
                    let mut scratch = vec![true; mask.len()];
                    a.apply(batch, &mut scratch, true, Comb::And)?;
                    b.apply(batch, &mut scratch, true, Comb::And)?;
                    for (m, s) in mask.iter_mut().zip(&scratch) {
                        *m |= *s;
                    }
                    Ok(())
                }
            },
            Predicate::Not(p) => p.apply(batch, mask, !negate, comb),
        }
    }

    /// Zone-map pruning test: `true` iff the predicate provably matches
    /// zero rows of an object whose per-column statistics are given by
    /// `range` (`None` = unknown, assume anything — including NaNs).
    /// Conservative: a `false` return says nothing; a `true` return is a
    /// proof, so the planner may skip the object before any I/O without
    /// changing results.
    ///
    /// NaN rows satisfy `Ne` comparisons and nothing else, so a
    /// [`ValueRange`] with `nans > 0` keeps `Ne` alive while the non-NaN
    /// min/max still prune range predicates — and a range proven NaN-free
    /// (`nans == 0`) lets `Ne` prune constant columns.
    pub fn prune(&self, range: &dyn Fn(&str) -> Option<ValueRange>) -> bool {
        !self.maybe_some(range)
    }

    /// Over-approximation: may at least one row match?
    fn maybe_some(&self, range: &dyn Fn(&str) -> Option<ValueRange>) -> bool {
        match self {
            Predicate::True => true,
            Predicate::Cmp { col, op, value } => match range(col) {
                None => true,
                Some(r) => {
                    let (lo, hi) = (r.lo, r.hi);
                    let non_nan = r.has_values()
                        && match op {
                            CmpOp::Lt => lo < *value,
                            CmpOp::Le => lo <= *value,
                            CmpOp::Gt => hi > *value,
                            CmpOp::Ge => hi >= *value,
                            CmpOp::Eq => lo <= *value && *value <= hi,
                            CmpOp::Ne => !(lo == *value && hi == *value),
                        };
                    // A NaN row matches only `Ne` (NaN != x for every x).
                    non_nan || (r.nans > 0 && *op == CmpOp::Ne)
                }
            },
            Predicate::And(a, b) => a.maybe_some(range) && b.maybe_some(range),
            Predicate::Or(a, b) => a.maybe_some(range) || b.maybe_some(range),
            Predicate::Not(p) => !p.all_match(range),
        }
    }

    /// Under-approximation: do provably *all* rows match?
    fn all_match(&self, range: &dyn Fn(&str) -> Option<ValueRange>) -> bool {
        match self {
            Predicate::True => true,
            Predicate::Cmp { col, op, value } => match range(col) {
                None => false,
                Some(r) => {
                    let (lo, hi) = (r.lo, r.hi);
                    // All non-NaN rows match (vacuously, if there are none)…
                    let non_nan = !r.has_values()
                        || match op {
                            CmpOp::Lt => hi < *value,
                            CmpOp::Le => hi <= *value,
                            CmpOp::Gt => lo > *value,
                            CmpOp::Ge => lo >= *value,
                            CmpOp::Eq => lo == *value && hi == *value,
                            CmpOp::Ne => *value < lo || hi < *value,
                        };
                    // …and so do all NaN rows (only `Ne` matches NaN).
                    non_nan && (r.nans == 0 || *op == CmpOp::Ne)
                }
            },
            Predicate::And(a, b) => a.all_match(range) && b.all_match(range),
            Predicate::Or(a, b) => a.all_match(range) || b.all_match(range),
            Predicate::Not(p) => !p.maybe_some(range),
        }
    }

    /// Evaluate the predicate over one *virtual row* whose column values
    /// come from `get` (`None` = unknown column → error). This is how the
    /// driver applies a HAVING predicate to finalized group rows: group
    /// keys resolve by name, aggregate values by their display form
    /// (`"sum(val)"`). NaN semantics match the batch evaluator (`Ne`
    /// matches NaN, nothing else does).
    pub fn eval_row(&self, get: &dyn Fn(&str) -> Option<f64>) -> Result<bool> {
        Ok(match self {
            Predicate::True => true,
            Predicate::Cmp { col, op, value } => {
                let x = get(col).ok_or_else(|| {
                    Error::Query(format!("unknown column {col:?} in HAVING predicate"))
                })?;
                op.eval(x, *value)
            }
            Predicate::And(a, b) => a.eval_row(get)? && b.eval_row(get)?,
            Predicate::Or(a, b) => a.eval_row(get)? || b.eval_row(get)?,
            Predicate::Not(p) => !p.eval_row(get)?,
        })
    }

    /// Wire encoding (for objclass input).
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            Predicate::True => {
                w.u8(0);
            }
            Predicate::Cmp { col, op, value } => {
                w.u8(1);
                w.str(col);
                w.u8(op.code());
                w.f64(*value);
            }
            Predicate::And(a, b) => {
                w.u8(2);
                a.encode_into(w);
                b.encode_into(w);
            }
            Predicate::Or(a, b) => {
                w.u8(3);
                a.encode_into(w);
                b.encode_into(w);
            }
            Predicate::Not(p) => {
                w.u8(4);
                p.encode_into(w);
            }
        }
    }

    pub fn decode_from(r: &mut ByteReader) -> Result<Predicate> {
        Ok(match r.u8()? {
            0 => Predicate::True,
            1 => Predicate::Cmp {
                col: r.str()?.to_string(),
                op: CmpOp::from_code(r.u8()?)?,
                value: r.f64()?,
            },
            2 => Predicate::And(
                Box::new(Self::decode_from(r)?),
                Box::new(Self::decode_from(r)?),
            ),
            3 => Predicate::Or(
                Box::new(Self::decode_from(r)?),
                Box::new(Self::decode_from(r)?),
            ),
            4 => Predicate::Not(Box::new(Self::decode_from(r)?)),
            o => return Err(Error::Corrupt(format!("bad predicate tag {o}"))),
        })
    }
}

impl fmt::Display for Predicate {
    /// Parseable text form (the `parse::parse_predicate` syntax) — used
    /// by `QueryPlan::explain` and the CLI.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Predicate::True => write!(f, "true"),
            Predicate::Cmp { col, op, value } => {
                write!(f, "{col} {} {value}", op.symbol())
            }
            Predicate::And(a, b) => write!(f, "({a} && {b})"),
            Predicate::Or(a, b) => write!(f, "({a} || {b})"),
            Predicate::Not(p) => write!(f, "!({p})"),
        }
    }
}

/// How a sub-predicate folds into the in-place evaluation mask.
#[derive(Clone, Copy)]
enum Comb {
    /// `mask[i] &= value`
    And,
    /// `mask[i] |= value`
    Or,
}

/// Fold one comparison leaf into the mask: one type dispatch per column,
/// then a tight branch-free combine loop (no per-node `Vec<bool>`
/// allocation — the scan hot path).
fn cmp_apply(
    col: &Column,
    name: &str,
    op: CmpOp,
    value: f64,
    mask: &mut [bool],
    negate: bool,
    comb: Comb,
) -> Result<()> {
    fn lanes<T: Copy>(
        v: &[T],
        cast: impl Fn(T) -> f64,
        op: CmpOp,
        value: f64,
        mask: &mut [bool],
        negate: bool,
        comb: Comb,
    ) {
        match comb {
            Comb::And => {
                for (m, &x) in mask.iter_mut().zip(v) {
                    *m &= op.eval(cast(x), value) ^ negate;
                }
            }
            Comb::Or => {
                for (m, &x) in mask.iter_mut().zip(v) {
                    *m |= op.eval(cast(x), value) ^ negate;
                }
            }
        }
    }
    match col {
        Column::F32(v) => lanes(v, |x| x as f64, op, value, mask, negate, comb),
        Column::F64(v) => lanes(v, |x| x, op, value, mask, negate, comb),
        Column::I64(v) => lanes(v, |x| x as f64, op, value, mask, negate, comb),
        Column::Str(_) => {
            return Err(Error::Query(format!(
                "predicate on string column {name:?}"
            )))
        }
    }
    Ok(())
}

/// Aggregate functions. All but `Median` are *algebraic*: they have a
/// constant-size partial state that merges associatively, so they
/// decompose over objects (§3.2). `Median` is *holistic*: its exact
/// computation needs the values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Count,
    Sum,
    Min,
    Max,
    Mean,
    Var,
    Median,
}

impl AggFunc {
    /// Algebraic aggregates decompose into constant-size partials.
    pub fn is_algebraic(self) -> bool {
        !matches!(self, AggFunc::Median)
    }

    pub fn name(self) -> &'static str {
        match self {
            AggFunc::Count => "count",
            AggFunc::Sum => "sum",
            AggFunc::Min => "min",
            AggFunc::Max => "max",
            AggFunc::Mean => "mean",
            AggFunc::Var => "var",
            AggFunc::Median => "median",
        }
    }

    pub(crate) fn code(self) -> u8 {
        match self {
            AggFunc::Count => 0,
            AggFunc::Sum => 1,
            AggFunc::Min => 2,
            AggFunc::Max => 3,
            AggFunc::Mean => 4,
            AggFunc::Var => 5,
            AggFunc::Median => 6,
        }
    }

    pub(crate) fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => AggFunc::Count,
            1 => AggFunc::Sum,
            2 => AggFunc::Min,
            3 => AggFunc::Max,
            4 => AggFunc::Mean,
            5 => AggFunc::Var,
            6 => AggFunc::Median,
            o => return Err(Error::Corrupt(format!("bad agg code {o}"))),
        })
    }
}

/// One aggregate column request.
#[derive(Clone, Debug, PartialEq)]
pub struct Aggregate {
    pub func: AggFunc,
    pub col: String,
}

impl Aggregate {
    pub fn new(func: AggFunc, col: &str) -> Self {
        Self {
            func,
            col: col.to_string(),
        }
    }
}

impl fmt::Display for Aggregate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}({})", self.func.name(), self.col)
    }
}

/// One sort key of an order-by: column name + direction. Ordering is
/// total — f32/f64 compare via `f64::total_cmp` (NaN sorts after +inf,
/// deterministically), i64 compares natively (no f64 widening, so
/// values beyond 2^53 keep their order), strings lexicographically —
/// and every execution mode produces the same row order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SortKey {
    pub col: String,
    pub desc: bool,
}

impl SortKey {
    pub fn asc(col: &str) -> SortKey {
        SortKey {
            col: col.to_string(),
            desc: false,
        }
    }

    pub fn desc(col: &str) -> SortKey {
        SortKey {
            col: col.to_string(),
            desc: true,
        }
    }

    pub fn encode_into(&self, w: &mut ByteWriter) {
        w.str(&self.col);
        w.u8(self.desc as u8);
    }

    pub fn decode_from(r: &mut ByteReader) -> Result<SortKey> {
        Ok(SortKey {
            col: r.str()?.to_string(),
            desc: r.u8()? != 0,
        })
    }
}

impl fmt::Display for SortKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.col, if self.desc { " desc" } else { "" })
    }
}

/// Mergeable partial aggregate state. Constant-size for algebraic
/// functions; carries raw values only when a holistic function needs them.
#[derive(Clone, Debug, PartialEq)]
pub struct AggState {
    pub count: u64,
    pub sum: f64,
    pub sumsq: f64,
    pub min: f64,
    pub max: f64,
    /// Raw values, kept only for holistic aggregates.
    pub values: Option<Vec<f64>>,
}

impl AggState {
    pub fn new(keep_values: bool) -> Self {
        Self {
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            values: keep_values.then(Vec::new),
        }
    }

    /// Fold a column (under a mask) into the state. One type dispatch per
    /// column, tight masked loop (native fallback of the pushdown
    /// aggregate hot path — the PJRT kernel replaces it when loaded).
    pub fn update_column(&mut self, col: &Column, mask: &[bool]) -> Result<()> {
        if mask.len() != col.len() {
            return Err(Error::Query(format!(
                "mask len {} != column len {}",
                mask.len(),
                col.len()
            )));
        }
        match col {
            Column::F32(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x as f64);
                    }
                }
            }
            Column::F64(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x);
                    }
                }
            }
            Column::I64(v) => {
                for (x, &m) in v.iter().zip(mask) {
                    if m {
                        self.update(*x as f64);
                    }
                }
            }
            Column::Str(_) => {
                return Err(Error::Query("cannot aggregate a string column".into()))
            }
        }
        Ok(())
    }

    #[inline]
    pub fn update(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.sumsq += x * x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if let Some(v) = &mut self.values {
            v.push(x);
        }
    }

    pub fn merge(&mut self, other: &AggState) {
        self.count += other.count;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        match (&mut self.values, &other.values) {
            (Some(a), Some(b)) => a.extend_from_slice(b),
            (Some(_), None) | (None, Some(_)) => {
                // Mixed states: drop values (caller decides holistic needs).
                self.values = None;
            }
            (None, None) => {}
        }
    }

    /// Final value for a function.
    pub fn finalize(&self, func: AggFunc) -> Result<f64> {
        if self.count == 0 {
            return match func {
                AggFunc::Count => Ok(0.0),
                AggFunc::Sum => Ok(0.0),
                _ => Err(Error::Query(format!("{} of empty set", func.name()))),
            };
        }
        Ok(match func {
            AggFunc::Count => self.count as f64,
            AggFunc::Sum => self.sum,
            AggFunc::Min => self.min,
            AggFunc::Max => self.max,
            AggFunc::Mean => self.sum / self.count as f64,
            AggFunc::Var => {
                let n = self.count as f64;
                (self.sumsq - self.sum * self.sum / n) / n
            }
            AggFunc::Median => {
                let mut v = self
                    .values
                    .clone()
                    .ok_or_else(|| Error::Query("median needs raw values".into()))?;
                // Total order: NaN values sort last instead of panicking,
                // so every execution mode finalizes identically.
                v.sort_by(|a, b| a.total_cmp(b));
                let n = v.len();
                if n % 2 == 1 {
                    v[n / 2]
                } else {
                    (v[n / 2 - 1] + v[n / 2]) / 2.0
                }
            }
        })
    }

    /// Serialized size estimate (what crosses the network as a partial).
    pub fn wire_bytes(&self) -> usize {
        8 * 5 + 1 + self.values.as_ref().map_or(0, |v| 4 + v.len() * 8)
    }

    pub fn encode_into(&self, w: &mut ByteWriter) {
        w.u64(self.count);
        w.f64(self.sum);
        w.f64(self.sumsq);
        w.f64(self.min);
        w.f64(self.max);
        match &self.values {
            Some(v) => {
                w.u8(1);
                w.u32(v.len() as u32);
                for &x in v {
                    w.f64(x);
                }
            }
            None => {
                w.u8(0);
            }
        }
    }

    pub fn decode_from(r: &mut ByteReader) -> Result<AggState> {
        let count = r.u64()?;
        let sum = r.f64()?;
        let sumsq = r.f64()?;
        let min = r.f64()?;
        let max = r.f64()?;
        let values = match r.u8()? {
            0 => None,
            1 => {
                let n = r.u32()? as usize;
                let mut v = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    v.push(r.f64()?);
                }
                Some(v)
            }
            o => return Err(Error::Corrupt(format!("bad values tag {o}"))),
        };
        Ok(AggState {
            count,
            sum,
            sumsq,
            min,
            max,
            values,
        })
    }
}

/// A full query against a table dataset: the flat, validated form of a
/// [`super::logical::LogicalPlan`] operator chain. The fluent builder
/// below (`Query::scan(..).filter(..).select(..).sort(..).limit(..)`)
/// constructs it directly; [`Query::logical`] lifts it back into the
/// operator-tree IR the planner compiles.
///
/// # Examples
///
/// A filtered, projected top-k — the planner pushes the filter, the
/// carry-projection and a per-object partial top-k to the storage
/// servers and runs the k-way merge at the driver:
///
/// ```
/// use skyhook_map::skyhook::{CmpOp, Predicate, Query, SortKey};
///
/// let q = Query::scan("sensors")
///     .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
///     .select(&["ts"])
///     .top_k("val", true, 10);
/// assert!(!q.is_aggregate());
/// assert_eq!(q.sort_keys, vec![SortKey::desc("val")]);
/// assert_eq!(q.limit, Some(10));
/// // The partials carry the sort key alongside the projection.
/// assert_eq!(q.carry_columns(), Some(vec!["ts".into(), "val".into()]));
/// ```
///
/// A grouped multi-aggregate with a HAVING filter over the finalized
/// group rows (aggregate values are addressed by their display form):
///
/// ```
/// use skyhook_map::skyhook::{AggFunc, CmpOp, Predicate, Query};
///
/// let q = Query::scan("sensors")
///     .group("sensor")
///     .aggregate(AggFunc::Count, "val")
///     .aggregate(AggFunc::Mean, "val")
///     .having(Predicate::cmp("count(val)", CmpOp::Ge, 100.0));
/// assert!(q.is_aggregate() && q.is_decomposable());
/// assert_eq!(q.group_by, vec!["sensor"]);
/// assert_ne!(q.having, Predicate::True);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    /// Dataset name the scan reads.
    pub dataset: String,
    /// Row filter.
    pub predicate: Predicate,
    /// Columns to return (row queries). `None` = all columns.
    pub projection: Option<Vec<String>>,
    /// Aggregates (if non-empty, the query returns aggregate values, not
    /// rows).
    pub aggregates: Vec<Aggregate>,
    /// Group-by key columns (i64) for aggregate queries; empty = scalar
    /// aggregation.
    pub group_by: Vec<String>,
    /// HAVING filter over the finalized group rows (`Predicate::True` =
    /// keep all groups). Columns resolve against the group keys by name
    /// and the aggregates by display form (`"sum(val)"`). Always a
    /// merge-side (client) stage: it needs cross-object totals.
    pub having: Predicate,
    /// Order-by keys (row queries). Applied over the merged result; with
    /// `limit`, each storage server pre-sorts and truncates its partial
    /// (distributed top-k).
    pub sort_keys: Vec<SortKey>,
    /// Row-count cap, applied after sorting. On row queries without sort
    /// keys it is pushed down as a per-object head(n).
    pub limit: Option<usize>,
}

impl Query {
    /// A full-scan row query.
    pub fn scan(dataset: &str) -> Query {
        Query {
            dataset: dataset.to_string(),
            predicate: Predicate::True,
            projection: None,
            aggregates: Vec::new(),
            group_by: Vec::new(),
            having: Predicate::True,
            sort_keys: Vec::new(),
            limit: None,
        }
    }

    pub fn filter(mut self, p: Predicate) -> Query {
        self.predicate = p;
        self
    }

    pub fn select(mut self, cols: &[&str]) -> Query {
        self.projection = Some(cols.iter().map(|s| s.to_string()).collect());
        self
    }

    pub fn aggregate(mut self, func: AggFunc, col: &str) -> Query {
        self.aggregates.push(Aggregate::new(func, col));
        self
    }

    /// Add a group-by key column (repeatable for multi-column keys).
    pub fn group(mut self, col: &str) -> Query {
        self.group_by.push(col.to_string());
        self
    }

    /// Filter the finalized group rows (the HAVING clause; the planner
    /// rejects it without a grouped aggregate). Predicate columns name
    /// group keys or aggregates by display form, e.g.
    /// `Predicate::cmp("count(val)", CmpOp::Gt, 10.0)`.
    pub fn having(mut self, p: Predicate) -> Query {
        self.having = p;
        self
    }

    /// Ascending sort on `col` (appended: earlier keys order first).
    pub fn sort(mut self, col: &str) -> Query {
        self.sort_keys.push(SortKey::asc(col));
        self
    }

    /// Descending sort on `col`.
    pub fn sort_desc(mut self, col: &str) -> Query {
        self.sort_keys.push(SortKey::desc(col));
        self
    }

    /// Replace the full sort-key list.
    pub fn sort_by(mut self, keys: &[SortKey]) -> Query {
        self.sort_keys = keys.to_vec();
        self
    }

    /// Keep only the first `n` rows (after sorting, if any).
    pub fn limit(mut self, n: usize) -> Query {
        self.limit = Some(n);
        self
    }

    /// Top-k shorthand: sort by `col` (descending if `desc`) and keep
    /// the best `n` rows — the fused Sort+Limit operator, offloaded as
    /// per-object partial top-k.
    pub fn top_k(self, col: &str, desc: bool, n: usize) -> Query {
        let key = if desc {
            SortKey::desc(col)
        } else {
            SortKey::asc(col)
        };
        self.sort_by(&[key]).limit(n)
    }

    pub fn is_aggregate(&self) -> bool {
        !self.aggregates.is_empty()
    }

    /// Validate the HAVING clause against this query's *shape* (its
    /// columns are virtual, so the schema is not consulted): it needs a
    /// grouped aggregate, and every predicate column must name a group
    /// key or an aggregate by display form (`"sum(val)"`). The single
    /// source of the rule — shared by [`super::logical::LogicalPlan::to_query`]
    /// and the planner, and mirrored by the driver's merge-side
    /// evaluation.
    pub fn validate_having(&self) -> Result<()> {
        if self.having == Predicate::True {
            return Ok(());
        }
        if !self.is_aggregate() || self.group_by.is_empty() {
            return Err(Error::Query("HAVING requires a grouped aggregate".into()));
        }
        for c in self.having.columns() {
            let known = self.group_by.iter().any(|k| k == c)
                || self.aggregates.iter().any(|a| a.to_string() == c);
            if !known {
                return Err(Error::Query(format!(
                    "HAVING column {c:?} is neither a group key nor an aggregate \
                     of this query"
                )));
            }
        }
        Ok(())
    }

    /// All aggregates algebraic → fully decomposable (§3.2).
    pub fn is_decomposable(&self) -> bool {
        self.aggregates.iter().all(|a| a.func.is_algebraic())
    }

    /// Columns a row query's per-object partials must carry: the
    /// projection plus any sort keys outside it (the final projection at
    /// the driver drops them after the merge-side sort). `None` = all.
    pub fn carry_columns(&self) -> Option<Vec<String>> {
        let proj = self.projection.as_ref()?;
        let mut out = proj.clone();
        for k in &self.sort_keys {
            if !out.contains(&k.col) {
                out.push(k.col.clone());
            }
        }
        Some(out)
    }

    /// Columns this query needs to touch (predicate ∪ projection ∪ aggs ∪
    /// group keys ∪ sort keys).
    pub fn needed_columns(&self, all: &[String]) -> Vec<String> {
        let mut out: Vec<String> = self
            .predicate
            .columns()
            .into_iter()
            .map(str::to_string)
            .collect();
        match (&self.projection, self.is_aggregate()) {
            (_, true) => {
                out.extend(self.aggregates.iter().map(|a| a.col.clone()));
                out.extend(self.group_by.iter().cloned());
            }
            (Some(p), false) => {
                out.extend(p.iter().cloned());
                out.extend(self.sort_keys.iter().map(|k| k.col.clone()));
            }
            (None, false) => out.extend(all.iter().cloned()),
        }
        out.sort();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;
    use crate::dataset::{DType, TableSchema};

    fn batch() -> Batch {
        Batch::new(
            TableSchema::new(&[("id", DType::I64), ("v", DType::F32)]),
            vec![
                Column::I64(vec![1, 2, 3, 4, 5]),
                Column::F32(vec![10.0, 20.0, 30.0, 40.0, 50.0]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn cmp_ops() {
        assert!(CmpOp::Lt.eval(1.0, 2.0));
        assert!(CmpOp::Le.eval(2.0, 2.0));
        assert!(CmpOp::Gt.eval(3.0, 2.0));
        assert!(CmpOp::Ge.eval(2.0, 2.0));
        assert!(CmpOp::Eq.eval(2.0, 2.0));
        assert!(CmpOp::Ne.eval(1.0, 2.0));
    }

    #[test]
    fn predicate_eval() {
        let b = batch();
        let p = Predicate::cmp("v", CmpOp::Gt, 25.0);
        assert_eq!(p.eval(&b).unwrap(), vec![false, false, true, true, true]);
        let p = Predicate::cmp("v", CmpOp::Gt, 15.0).and(Predicate::cmp("id", CmpOp::Lt, 4.0));
        assert_eq!(p.eval(&b).unwrap(), vec![false, true, true, false, false]);
        let p = Predicate::cmp("id", CmpOp::Eq, 1.0).or(Predicate::cmp("id", CmpOp::Eq, 5.0));
        assert_eq!(p.eval(&b).unwrap(), vec![true, false, false, false, true]);
        let p = Predicate::cmp("v", CmpOp::Gt, 25.0).not();
        assert_eq!(p.eval(&b).unwrap(), vec![true, true, false, false, false]);
        assert_eq!(Predicate::True.eval(&b).unwrap(), vec![true; 5]);
    }

    #[test]
    fn predicate_errors() {
        let b = Batch::new(
            TableSchema::new(&[("s", DType::Str)]),
            vec![Column::Str(vec!["x".into()])],
        )
        .unwrap();
        assert!(Predicate::cmp("s", CmpOp::Eq, 1.0).eval(&b).is_err());
        assert!(Predicate::cmp("zzz", CmpOp::Eq, 1.0).eval(&batch()).is_err());
    }

    #[test]
    fn predicate_columns() {
        let p = Predicate::cmp("a", CmpOp::Gt, 0.0)
            .and(Predicate::cmp("b", CmpOp::Lt, 1.0).or(Predicate::cmp("a", CmpOp::Eq, 2.0)));
        assert_eq!(p.columns(), vec!["a", "b"]);
    }

    #[test]
    fn eval_into_reuses_buffer() {
        let b = batch();
        let mut mask = Vec::new();
        let p = Predicate::cmp("v", CmpOp::Gt, 25.0);
        p.eval_into(&b, &mut mask).unwrap();
        assert_eq!(mask, vec![false, false, true, true, true]);
        // Reuse with a different predicate: buffer is reset, not merged.
        let p = Predicate::cmp("v", CmpOp::Lt, 25.0).or(Predicate::cmp("id", CmpOp::Eq, 4.0));
        p.eval_into(&b, &mut mask).unwrap();
        assert_eq!(mask, vec![true, true, false, true, false]);
        assert!(Predicate::cmp("zzz", CmpOp::Eq, 1.0)
            .eval_into(&b, &mut mask)
            .is_err());
    }

    #[test]
    fn eval_handles_mixed_and_or_not_shapes() {
        let b = batch();
        // Or-of-Ands and And-of-Ors exercise the scratch-buffer paths.
        let p = Predicate::cmp("v", CmpOp::Gt, 15.0)
            .and(Predicate::cmp("v", CmpOp::Lt, 45.0))
            .or(Predicate::cmp("id", CmpOp::Eq, 5.0));
        assert_eq!(p.eval(&b).unwrap(), vec![false, true, true, true, true]);
        let p = Predicate::cmp("v", CmpOp::Lt, 15.0)
            .or(Predicate::cmp("v", CmpOp::Gt, 45.0))
            .and(Predicate::cmp("id", CmpOp::Ne, 5.0));
        assert_eq!(p.eval(&b).unwrap(), vec![true, false, false, false, false]);
        // Negations of both shapes (the De Morgan rewrites).
        let p = Predicate::cmp("v", CmpOp::Gt, 15.0)
            .and(Predicate::cmp("id", CmpOp::Lt, 4.0))
            .not();
        assert_eq!(p.eval(&b).unwrap(), vec![true, false, false, true, true]);
        let p = Predicate::cmp("v", CmpOp::Lt, 15.0)
            .or(Predicate::cmp("id", CmpOp::Gt, 4.0))
            .not();
        assert_eq!(p.eval(&b).unwrap(), vec![false, true, true, true, false]);
        // True under negation.
        assert_eq!(
            Predicate::True.not().eval(&b).unwrap(),
            vec![false; 5]
        );
    }

    #[test]
    fn prune_on_ranges() {
        // Object with v in [10, 50], id in [1, 5], both NaN-free.
        let range = |col: &str| match col {
            "v" => Some(ValueRange::exact(10.0, 50.0)),
            "id" => Some(ValueRange::exact(1.0, 5.0)),
            _ => None,
        };
        // Provably empty.
        assert!(Predicate::cmp("v", CmpOp::Gt, 50.0).prune(&range));
        assert!(Predicate::cmp("v", CmpOp::Lt, 10.0).prune(&range));
        assert!(Predicate::cmp("v", CmpOp::Ge, 50.5).prune(&range));
        assert!(Predicate::cmp("v", CmpOp::Eq, 60.0).prune(&range));
        // Possibly matching.
        assert!(!Predicate::cmp("v", CmpOp::Ge, 50.0).prune(&range));
        assert!(!Predicate::cmp("v", CmpOp::Le, 10.0).prune(&range));
        assert!(!Predicate::cmp("v", CmpOp::Eq, 30.0).prune(&range));
        assert!(!Predicate::cmp("v", CmpOp::Ne, 30.0).prune(&range));
        // Ne prunes only a NaN-free constant column.
        let constant = |_: &str| Some(ValueRange::exact(7.0, 7.0));
        assert!(Predicate::cmp("x", CmpOp::Ne, 7.0).prune(&constant));
        assert!(!Predicate::cmp("x", CmpOp::Ne, 8.0).prune(&constant));
        // Unknown columns never prune.
        assert!(!Predicate::cmp("ghost", CmpOp::Gt, 1e12).prune(&range));
        // Conjunction prunes if either side does; disjunction needs both.
        let dead = Predicate::cmp("v", CmpOp::Gt, 99.0);
        let alive = Predicate::cmp("id", CmpOp::Ge, 3.0);
        assert!(dead.clone().and(alive.clone()).prune(&range));
        assert!(!dead.clone().or(alive.clone()).prune(&range));
        assert!(dead.clone().or(dead.clone()).prune(&range));
        // Not: prune iff the inner provably matches every row.
        assert!(Predicate::cmp("v", CmpOp::Le, 50.0).not().prune(&range));
        assert!(!Predicate::cmp("v", CmpOp::Le, 30.0).not().prune(&range));
        assert!(!Predicate::True.prune(&range));
        assert!(Predicate::True.not().prune(&range));
    }

    #[test]
    fn prune_with_nan_counts() {
        // v in [10, 50] plus 3 NaN rows.
        let nanny = |_: &str| {
            Some(ValueRange {
                lo: 10.0,
                hi: 50.0,
                nans: 3,
            })
        };
        // Range predicates still prune on the non-NaN bounds (NaN rows
        // never satisfy them).
        assert!(Predicate::cmp("v", CmpOp::Gt, 50.0).prune(&nanny));
        assert!(Predicate::cmp("v", CmpOp::Lt, 10.0).prune(&nanny));
        assert!(Predicate::cmp("v", CmpOp::Eq, 99.0).prune(&nanny));
        assert!(!Predicate::cmp("v", CmpOp::Ge, 50.0).prune(&nanny));
        // Ne on a NaN-bearing constant column cannot prune (NaN != 7).
        let nan_const = |_: &str| {
            Some(ValueRange {
                lo: 7.0,
                hi: 7.0,
                nans: 1,
            })
        };
        assert!(!Predicate::cmp("v", CmpOp::Ne, 7.0).prune(&nan_const));
        // The same column proven NaN-free prunes.
        assert!(Predicate::cmp("v", CmpOp::Ne, 7.0).prune(&|_| Some(ValueRange::exact(7.0, 7.0))));
        // An all-NaN column: every op but Ne prunes.
        let all_nan = |_: &str| {
            Some(ValueRange {
                lo: f64::INFINITY,
                hi: f64::NEG_INFINITY,
                nans: 5,
            })
        };
        assert!(Predicate::cmp("v", CmpOp::Lt, 1e12).prune(&all_nan));
        assert!(Predicate::cmp("v", CmpOp::Eq, 0.0).prune(&all_nan));
        assert!(!Predicate::cmp("v", CmpOp::Ne, 0.0).prune(&all_nan));
        // Not over a NaN-bearing column: !(v <= 50) is true on NaN rows,
        // so it must NOT prune even though the non-NaN range is covered.
        assert!(Predicate::cmp("v", CmpOp::Le, 50.0).not().prune(&|_| {
            Some(ValueRange::exact(10.0, 50.0))
        }));
        assert!(!Predicate::cmp("v", CmpOp::Le, 50.0).not().prune(&nanny));
        // !(v != x) ≡ v == x: prunes on a NaN-free constant != x…
        let p = Predicate::cmp("v", CmpOp::Ne, 7.0).not();
        assert!(p.prune(&|_| Some(ValueRange::exact(9.0, 9.0))));
        // …and on an all-NaN column (NaN != 7 holds on every row).
        assert!(p.prune(&all_nan));
    }

    #[test]
    fn prune_never_lies_on_real_batch() {
        // Every predicate that prunes must evaluate to an all-false mask
        // on the batch its ranges were computed from.
        let b = batch();
        let range = |col: &str| match col {
            "id" => Some(ValueRange::exact(1.0, 5.0)),
            "v" => Some(ValueRange::exact(10.0, 50.0)),
            _ => None,
        };
        let preds = [
            Predicate::cmp("v", CmpOp::Gt, 50.0),
            Predicate::cmp("v", CmpOp::Gt, 20.0),
            Predicate::cmp("id", CmpOp::Eq, 3.0).and(Predicate::cmp("v", CmpOp::Lt, 5.0)),
            Predicate::cmp("id", CmpOp::Ge, 1.0).not(),
            Predicate::cmp("v", CmpOp::Le, 50.0)
                .and(Predicate::cmp("id", CmpOp::Ge, 1.0))
                .not(),
        ];
        for p in preds {
            if p.prune(&range) {
                assert!(
                    p.eval(&b).unwrap().iter().all(|&m| !m),
                    "{p:?} pruned but matches rows"
                );
            }
        }
    }

    #[test]
    fn predicate_wire_roundtrip() {
        let p = Predicate::cmp("col x", CmpOp::Ge, -2.5)
            .and(Predicate::True.or(Predicate::cmp("y", CmpOp::Ne, 7.0).not()));
        let mut w = ByteWriter::new();
        p.encode_into(&mut w);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(Predicate::decode_from(&mut r).unwrap(), p);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn agg_state_basics() {
        let mut s = AggState::new(false);
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.update(x);
        }
        assert_eq!(s.finalize(AggFunc::Count).unwrap(), 4.0);
        assert_eq!(s.finalize(AggFunc::Sum).unwrap(), 10.0);
        assert_eq!(s.finalize(AggFunc::Min).unwrap(), 1.0);
        assert_eq!(s.finalize(AggFunc::Max).unwrap(), 4.0);
        assert_eq!(s.finalize(AggFunc::Mean).unwrap(), 2.5);
        assert!((s.finalize(AggFunc::Var).unwrap() - 1.25).abs() < 1e-12);
        assert!(s.finalize(AggFunc::Median).is_err(), "no values kept");
    }

    #[test]
    fn agg_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 19) as f64 - 9.0).collect();
        let mut whole = AggState::new(true);
        let mut a = AggState::new(true);
        let mut b = AggState::new(true);
        for (i, &x) in xs.iter().enumerate() {
            whole.update(x);
            if i % 2 == 0 {
                a.update(x)
            } else {
                b.update(x)
            }
        }
        a.merge(&b);
        for f in [
            AggFunc::Count,
            AggFunc::Sum,
            AggFunc::Min,
            AggFunc::Max,
            AggFunc::Mean,
            AggFunc::Var,
            AggFunc::Median,
        ] {
            let x = a.finalize(f).unwrap();
            let y = whole.finalize(f).unwrap();
            assert!((x - y).abs() < 1e-9, "{}: {x} vs {y}", f.name());
        }
    }

    #[test]
    fn agg_empty_set() {
        let s = AggState::new(false);
        assert_eq!(s.finalize(AggFunc::Count).unwrap(), 0.0);
        assert_eq!(s.finalize(AggFunc::Sum).unwrap(), 0.0);
        assert!(s.finalize(AggFunc::Min).is_err());
        assert!(s.finalize(AggFunc::Mean).is_err());
    }

    #[test]
    fn agg_median_even_odd() {
        let mut s = AggState::new(true);
        for x in [5.0, 1.0, 3.0] {
            s.update(x);
        }
        assert_eq!(s.finalize(AggFunc::Median).unwrap(), 3.0);
        s.update(7.0);
        assert_eq!(s.finalize(AggFunc::Median).unwrap(), 4.0);
    }

    #[test]
    fn agg_state_wire_roundtrip() {
        let mut s = AggState::new(true);
        for x in [1.5, -2.0, 8.25] {
            s.update(x);
        }
        let mut w = ByteWriter::new();
        s.encode_into(&mut w);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        let d = AggState::decode_from(&mut r).unwrap();
        assert_eq!(d, s);
        assert!(s.wire_bytes() >= buf.len());

        // Without values the wire size is constant.
        let mut s2 = AggState::new(false);
        for i in 0..10_000 {
            s2.update(i as f64);
        }
        assert!(s2.wire_bytes() < 64);
    }

    #[test]
    fn agg_merge_mixed_values_drops() {
        let mut a = AggState::new(true);
        a.update(1.0);
        let mut b = AggState::new(false);
        b.update(2.0);
        a.merge(&b);
        assert!(a.values.is_none());
        assert_eq!(a.count, 2);
    }

    #[test]
    fn update_column_with_mask() {
        let b = batch();
        let mut s = AggState::new(false);
        s.update_column(b.col("v").unwrap(), &[true, false, true, false, true])
            .unwrap();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 90.0);
    }

    #[test]
    fn query_builder_and_properties() {
        let q = Query::scan("ds")
            .filter(Predicate::cmp("v", CmpOp::Gt, 0.0))
            .aggregate(AggFunc::Mean, "v")
            .aggregate(AggFunc::Count, "v");
        assert!(q.is_aggregate());
        assert!(q.is_decomposable());
        let q2 = Query::scan("ds").aggregate(AggFunc::Median, "v");
        assert!(!q2.is_decomposable());
        let q3 = Query::scan("ds").select(&["a", "b"]);
        assert!(!q3.is_aggregate());
        // Multi-key group-by accumulates keys.
        let q4 = Query::scan("ds")
            .group("a")
            .group("b")
            .aggregate(AggFunc::Sum, "v");
        assert_eq!(q4.group_by, vec!["a", "b"]);
        // Sort/limit/top-k builders.
        let q5 = Query::scan("ds").sort("a").sort_desc("b").limit(7);
        assert_eq!(
            q5.sort_keys,
            vec![SortKey::asc("a"), SortKey::desc("b")]
        );
        assert_eq!(q5.limit, Some(7));
        let q6 = Query::scan("ds").top_k("v", true, 10);
        assert_eq!(q6.sort_keys, vec![SortKey::desc("v")]);
        assert_eq!(q6.limit, Some(10));
    }

    #[test]
    fn needed_columns() {
        let all = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let q = Query::scan("ds")
            .filter(Predicate::cmp("a", CmpOp::Gt, 0.0))
            .select(&["b"]);
        assert_eq!(q.needed_columns(&all), vec!["a", "b"]);
        let q = Query::scan("ds")
            .filter(Predicate::cmp("a", CmpOp::Gt, 0.0))
            .aggregate(AggFunc::Sum, "c")
            .group("b");
        assert_eq!(q.needed_columns(&all), vec!["a", "b", "c"]);
        let q = Query::scan("ds");
        assert_eq!(q.needed_columns(&all), all);
        // Sort keys outside the projection are carried.
        let q = Query::scan("ds").select(&["b"]).sort_desc("c");
        assert_eq!(q.needed_columns(&all), vec!["b", "c"]);
        assert_eq!(
            q.carry_columns(),
            Some(vec!["b".to_string(), "c".to_string()])
        );
        // Without a projection everything is carried implicitly.
        assert_eq!(Query::scan("ds").sort("a").carry_columns(), None);
    }

    #[test]
    fn eval_row_resolves_virtual_columns() {
        // The HAVING evaluator: a lookup over one finalized group row.
        let get = |name: &str| match name {
            "sensor" => Some(3.0),
            "count(val)" => Some(12.0),
            "mean(val)" => Some(f64::NAN),
            _ => None,
        };
        let p = Predicate::cmp("count(val)", CmpOp::Gt, 10.0);
        assert!(p.eval_row(&get).unwrap());
        let p = Predicate::cmp("count(val)", CmpOp::Gt, 10.0)
            .and(Predicate::cmp("sensor", CmpOp::Le, 2.0));
        assert!(!p.eval_row(&get).unwrap());
        // NaN aggregate values match only Ne (same as the batch path).
        assert!(!Predicate::cmp("mean(val)", CmpOp::Gt, 0.0).eval_row(&get).unwrap());
        assert!(Predicate::cmp("mean(val)", CmpOp::Ne, 0.0).eval_row(&get).unwrap());
        // Not / Or shapes and unknown columns.
        assert!(Predicate::cmp("sensor", CmpOp::Eq, 9.0)
            .or(Predicate::cmp("sensor", CmpOp::Eq, 3.0))
            .eval_row(&get)
            .unwrap());
        assert!(Predicate::True.not().eval_row(&get).map(|b| !b).unwrap());
        assert!(Predicate::cmp("ghost", CmpOp::Eq, 0.0).eval_row(&get).is_err());
    }

    #[test]
    fn predicate_display_roundtrips_through_parser() {
        let p = Predicate::cmp("val", CmpOp::Ge, -2.5)
            .and(Predicate::True.or(Predicate::cmp("ts", CmpOp::Ne, 7.0).not()));
        let text = p.to_string();
        assert_eq!(crate::skyhook::parse::parse_predicate(&text).unwrap(), p);
        assert_eq!(
            Predicate::cmp("v", CmpOp::Lt, 3.0).to_string(),
            "v < 3"
        );
    }

    #[test]
    fn agg_on_generated_table() {
        let b = gen::sensor_table(1000, 4);
        let mask = Predicate::cmp("flag", CmpOp::Eq, 1.0).eval(&b).unwrap();
        let mut s = AggState::new(false);
        s.update_column(b.col("val").unwrap(), &mask).unwrap();
        let frac = s.count as f64 / 1000.0;
        assert!(frac > 0.01 && frac < 0.15, "flag fraction {frac}");
    }
}
