//! The SkyhookDM-like query layer (§4.2): driver/worker scheduling over
//! the object store, with storage-side extensions for pushdown.
//!
//! - [`query`] — predicates, projections, aggregates + partial algebra
//! - [`plan`] — decomposability analysis and pushdown planning
//! - [`extension`] — the Skyhook-Extension object class (server-side)
//! - [`worker`] — per-sub-query execution (pushdown or client-side)
//! - [`driver`] — scheduling, result aggregation, write path, physical
//!   design transforms

pub mod driver;
pub mod extension;
pub mod parse;
pub mod plan;
pub mod query;
pub mod sketch;
pub mod worker;

pub use driver::{Driver, QueryResult, QueryStats, WriteReport};
pub use extension::{register_skyhook_class, ChunkCompute};
pub use plan::{plan, plan_opts, ExecMode, QueryPlan, SubQuery};
pub use query::{AggFunc, AggState, Aggregate, CmpOp, Predicate, Query};
pub use sketch::QuantileSketch;
