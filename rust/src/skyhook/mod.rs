//! The SkyhookDM-like query layer (§4.2): driver/worker scheduling over
//! the object store, with storage-side extensions for pushdown.
//!
//! - [`query`] — predicates, sort keys, aggregates + partial algebra,
//!   and the fluent flat [`Query`] builder
//! - [`logical`] — the [`LogicalPlan`] operator-tree IR and the
//!   [`PipelineSpec`] wire form of the server-side stage block
//! - [`plan`] — decomposability analysis and per-operator pushdown
//!   planning into a staged [`QueryPlan`]
//! - [`exec_kernel`] — the **unified execution kernel**: the one
//!   pipeline evaluator both the server extension and the client worker
//!   run, with its work counters priced by the cluster's single-sourced
//!   `ExecProfile`
//! - [`extension`] — the Skyhook-Extension object class (server-side),
//!   including the single-pass `skyhook.exec` pipeline handler
//! - [`worker`] — per-sub-query execution (pushdown or client-side)
//! - [`driver`] — scheduling, partial merging, merge-side sort/limit,
//!   write path, physical design transforms, selectivity calibration

pub mod driver;
pub mod exec_kernel;
pub mod extension;
pub mod logical;
pub mod parse;
pub mod plan;
pub mod query;
pub mod sketch;
pub mod worker;

pub use driver::{Driver, QueryResult, QueryStats, WriteReport};
pub use exec_kernel::{
    compiled_eligible, filter_mask, prefix_limit, run_pipeline, run_pipeline_tiered,
    scalar_forced, ChunkCompute, ExecOut, ExecTier, KernelWork, CHUNK_ROWS,
};
pub use extension::register_skyhook_class;
pub use logical::{
    estimate_groups, estimate_selectivity, merge_sorted, sort_rows, top_k_rows, LogicalPlan,
    PipelineSpec,
};
pub use plan::{
    access_path_forced, plan, plan_calibrated, plan_costed, plan_logical, plan_opts,
    plan_vol_read, plan_with_access, vol_mode_forced, AccessForce, CalibrationMap, ExecMode,
    PlanStage, QueryPlan, SubQuery, VolPlan, VolSubQuery,
};
pub use query::{AggFunc, AggState, Aggregate, CmpOp, Predicate, Query, SortKey};
pub use sketch::QuantileSketch;
