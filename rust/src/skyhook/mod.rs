//! The SkyhookDM-like query layer (§4.2): driver/worker scheduling over
//! the object store, with storage-side extensions for pushdown.
//!
//! - [`query`] — predicates, sort keys, aggregates + partial algebra,
//!   and the fluent flat [`Query`] builder
//! - [`logical`] — the [`LogicalPlan`] operator-tree IR and the
//!   [`PipelineSpec`] wire form of the server-side stage block
//! - [`plan`] — decomposability analysis and per-operator pushdown
//!   planning into a staged [`QueryPlan`]
//! - [`extension`] — the Skyhook-Extension object class (server-side),
//!   including the single-pass `skyhook.exec` pipeline handler
//! - [`worker`] — per-sub-query execution (pushdown or client-side)
//! - [`driver`] — scheduling, partial merging, merge-side sort/limit,
//!   write path, physical design transforms

pub mod driver;
pub mod extension;
pub mod logical;
pub mod parse;
pub mod plan;
pub mod query;
pub mod sketch;
pub mod worker;

pub use driver::{Driver, QueryResult, QueryStats, WriteReport};
pub use extension::{register_skyhook_class, ChunkCompute};
pub use logical::{
    estimate_groups, estimate_selectivity, merge_sorted, sort_rows, top_k_rows, LogicalPlan,
    PipelineSpec,
};
pub use plan::{
    plan, plan_costed, plan_logical, plan_opts, ExecMode, PlanStage, QueryPlan, SubQuery,
};
pub use query::{AggFunc, AggState, Aggregate, CmpOp, Predicate, Query, SortKey};
pub use sketch::QuantileSketch;
