//! A small text syntax for queries (the CLI front-end):
//!
//! - predicates: `val > 50`, `flag == 1 && val <= 3.5`, `!(a < 2) || b != 0`
//! - aggregates: `mean:val`, `count:*` (any column), `median:val`
//! - sort specs: `val desc`, `sensor, ts desc`
//! - pipelines: stages separated by `|`, assembled into a
//!   [`LogicalPlan`] and validated by [`LogicalPlan::to_query`]:
//!
//!   ```text
//!   filter val > 50 | select ts,val | sort val desc | limit 10
//!   filter flag == 0 | agg sum:val,count:val | by sensor,flag
//!   topk 10 val desc
//!   ```
//!
//! Grammar (precedence low→high): `||`, `&&`, `!`, comparison, parens.

use super::logical::LogicalPlan;
use super::query::{AggFunc, Aggregate, CmpOp, Predicate, Query, SortKey};
use crate::error::{Error, Result};

/// Parse a predicate expression.
pub fn parse_predicate(s: &str) -> Result<Predicate> {
    let mut p = Parser::new(s);
    let pred = p.or_expr()?;
    p.skip_ws();
    if !p.done() {
        return Err(Error::Query(format!(
            "trailing input at {}: {:?}",
            p.pos,
            &p.src[p.pos..]
        )));
    }
    Ok(pred)
}

/// Parse an aggregate spec `func:column` (e.g. `mean:val`).
pub fn parse_aggregate(s: &str) -> Result<Aggregate> {
    let (f, c) = s
        .split_once(':')
        .ok_or_else(|| Error::Query(format!("aggregate must be func:col, got {s:?}")))?;
    let func = match f.trim() {
        "count" => AggFunc::Count,
        "sum" => AggFunc::Sum,
        "min" => AggFunc::Min,
        "max" => AggFunc::Max,
        "mean" | "avg" => AggFunc::Mean,
        "var" => AggFunc::Var,
        "median" => AggFunc::Median,
        other => return Err(Error::Query(format!("unknown aggregate {other:?}"))),
    };
    let col = c.trim();
    if col.is_empty() {
        return Err(Error::Query("empty aggregate column".into()));
    }
    Ok(Aggregate::new(func, col))
}

/// Parse a sort spec: comma-separated `col [asc|desc]` keys.
pub fn parse_sort(s: &str) -> Result<Vec<SortKey>> {
    let mut keys = Vec::new();
    for part in s.split(',') {
        let mut it = part.split_whitespace();
        let Some(col) = it.next() else {
            return Err(Error::Query(format!("empty sort key in {s:?}")));
        };
        let key = match it.next() {
            None | Some("asc") => SortKey::asc(col),
            Some("desc") => SortKey::desc(col),
            Some(o) => {
                return Err(Error::Query(format!(
                    "sort direction must be asc|desc, got {o:?}"
                )))
            }
        };
        if let Some(extra) = it.next() {
            return Err(Error::Query(format!("trailing sort token {extra:?}")));
        }
        keys.push(key);
    }
    Ok(keys)
}

/// Parse a `|`-separated pipeline into a query over `dataset`.
///
/// Stages: `filter EXPR`, `select C1,C2`, `agg F:COL[,F:COL...]`,
/// `by C1,C2` (immediately after `agg`), `having EXPR` (after a grouped
/// `agg`; columns name group keys or aggregates like `sum(val)`),
/// `sort SPEC`, `limit N`, `topk N SPEC`. The text assembles a
/// [`LogicalPlan`] operator chain in written order, so illegal
/// compositions (ungrouped having, sort above limit, …) fail with the
/// IR's validation errors.
///
/// # Examples
///
/// ```
/// use skyhook_map::skyhook::parse::parse_pipeline;
/// use skyhook_map::skyhook::{CmpOp, Predicate, Query};
///
/// let q = parse_pipeline(
///     "sensors",
///     "filter val > 50 | select ts,val | sort val desc | limit 10",
/// )
/// .unwrap();
/// assert_eq!(
///     q,
///     Query::scan("sensors")
///         .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
///         .select(&["ts", "val"])
///         .sort_desc("val")
///         .limit(10)
/// );
/// ```
///
/// A grouped aggregate with a HAVING stage over the finalized groups:
///
/// ```
/// use skyhook_map::skyhook::parse::parse_pipeline;
///
/// let q = parse_pipeline(
///     "sensors",
///     "filter flag == 0 | agg count:val,mean:val | by sensor | having count(val) >= 100",
/// )
/// .unwrap();
/// assert_eq!(q.group_by, vec!["sensor"]);
/// assert_eq!(q.having.to_string(), "count(val) >= 100");
/// ```
pub fn parse_pipeline(dataset: &str, s: &str) -> Result<Query> {
    enum Stage {
        Filter(Predicate),
        Select(Vec<String>),
        Agg(Vec<Aggregate>),
        By(Vec<String>),
        Having(Predicate),
        Sort(Vec<SortKey>),
        Limit(usize),
        TopK(usize, Vec<SortKey>),
    }
    let mut stages = Vec::new();
    for chunk in s.split('|') {
        let chunk = chunk.trim();
        let (op, rest) = match chunk.split_once(char::is_whitespace) {
            Some((op, rest)) => (op, rest.trim()),
            None => (chunk, ""),
        };
        let split_names = |rest: &str| -> Vec<String> {
            rest.split(',')
                .map(|c| c.trim().to_string())
                .filter(|c| !c.is_empty())
                .collect()
        };
        stages.push(match op {
            "filter" => Stage::Filter(parse_predicate(rest)?),
            "select" => {
                let cols = split_names(rest);
                if cols.is_empty() {
                    return Err(Error::Query("select needs columns".into()));
                }
                Stage::Select(cols)
            }
            "agg" => {
                let aggs = rest
                    .split(',')
                    .map(parse_aggregate)
                    .collect::<Result<Vec<_>>>()?;
                Stage::Agg(aggs)
            }
            "by" => {
                let keys = split_names(rest);
                if keys.is_empty() {
                    return Err(Error::Query("by needs key columns".into()));
                }
                Stage::By(keys)
            }
            "having" => Stage::Having(parse_predicate(rest)?),
            "sort" => Stage::Sort(parse_sort(rest)?),
            "limit" => Stage::Limit(
                rest.parse()
                    .map_err(|_| Error::Query(format!("bad limit {rest:?}")))?,
            ),
            "topk" => {
                let (n, spec) = match rest.split_once(char::is_whitespace) {
                    Some((n, spec)) => (n, spec.trim()),
                    None => (rest, ""),
                };
                let n = n
                    .parse()
                    .map_err(|_| Error::Query(format!("bad topk count {n:?}")))?;
                if spec.is_empty() {
                    return Err(Error::Query("topk needs a sort spec".into()));
                }
                Stage::TopK(n, parse_sort(spec)?)
            }
            other => {
                return Err(Error::Query(format!(
                    "unknown pipeline stage {other:?} \
                     (filter|select|agg|by|having|sort|limit|topk)"
                )))
            }
        });
    }
    let mut plan = LogicalPlan::scan(dataset);
    let mut i = 0;
    let mut aggregated = false;
    while i < stages.len() {
        match &stages[i] {
            Stage::Filter(p) => plan = plan.filter(p.clone()),
            Stage::Select(cols) => {
                let refs: Vec<&str> = cols.iter().map(String::as_str).collect();
                plan = plan.project(&refs);
            }
            Stage::Agg(aggs) => {
                let keys: Vec<String> = match stages.get(i + 1) {
                    Some(Stage::By(k)) => {
                        i += 1;
                        k.clone()
                    }
                    _ => Vec::new(),
                };
                let refs: Vec<&str> = keys.iter().map(String::as_str).collect();
                plan = plan.aggregate(aggs.clone(), &refs);
                aggregated = true;
            }
            Stage::By(_) => {
                return Err(Error::Query("`by` must directly follow `agg`".into()));
            }
            Stage::Having(p) => {
                if !aggregated {
                    return Err(Error::Query("`having` must follow `agg`".into()));
                }
                // Filter above Aggregate is the IR's HAVING operator.
                plan = plan.filter(p.clone());
            }
            Stage::Sort(keys) => plan = plan.sort(keys.clone()),
            Stage::Limit(n) => plan = plan.limit(*n),
            Stage::TopK(n, keys) => plan = plan.top_k(keys.clone(), *n),
        }
        i += 1;
    }
    plan.to_query()
}

struct Parser<'a> {
    src: &'a str,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        Self { src, pos: 0 }
    }

    fn done(&self) -> bool {
        self.pos >= self.src.len()
    }

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    fn skip_ws(&mut self) {
        while self.rest().starts_with(|c: char| c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.rest().starts_with(tok) {
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn or_expr(&mut self) -> Result<Predicate> {
        let mut left = self.and_expr()?;
        while self.eat("||") {
            let right = self.and_expr()?;
            left = left.or(right);
        }
        Ok(left)
    }

    fn and_expr(&mut self) -> Result<Predicate> {
        let mut left = self.unary()?;
        while self.eat("&&") {
            let right = self.unary()?;
            left = left.and(right);
        }
        Ok(left)
    }

    fn unary(&mut self) -> Result<Predicate> {
        if self.eat("!") {
            return Ok(self.unary()?.not());
        }
        if self.eat("(") {
            let inner = self.or_expr()?;
            if !self.eat(")") {
                return Err(Error::Query(format!("expected ) at {}", self.pos)));
            }
            return Ok(inner);
        }
        self.comparison()
    }

    fn comparison(&mut self) -> Result<Predicate> {
        self.skip_ws();
        if self.eat("true") {
            return Ok(Predicate::True);
        }
        let mut col = self.identifier()?;
        // HAVING predicates address aggregate values by display form
        // (`count(val)`), so an identifier may carry one call-shaped
        // suffix; it stays a plain (virtual) column name.
        if self.rest().starts_with('(') {
            self.pos += 1;
            let inner = self.identifier()?;
            self.skip_ws();
            if !self.rest().starts_with(')') {
                return Err(Error::Query(format!(
                    "expected ) after {col}({inner} at {}",
                    self.pos
                )));
            }
            self.pos += 1;
            col = format!("{col}({inner})");
        }
        self.skip_ws();
        let op = if self.eat("<=") {
            CmpOp::Le
        } else if self.eat(">=") {
            CmpOp::Ge
        } else if self.eat("==") {
            CmpOp::Eq
        } else if self.eat("!=") {
            CmpOp::Ne
        } else if self.eat("<") {
            CmpOp::Lt
        } else if self.eat(">") {
            CmpOp::Gt
        } else {
            return Err(Error::Query(format!(
                "expected comparison operator at {}: {:?}",
                self.pos,
                self.rest()
            )));
        };
        let value = self.number()?;
        Ok(Predicate::cmp(&col, op, value))
    }

    fn identifier(&mut self) -> Result<String> {
        self.skip_ws();
        let start = self.pos;
        while self
            .rest()
            .starts_with(|c: char| c.is_ascii_alphanumeric() || c == '_')
        {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(Error::Query(format!(
                "expected identifier at {}: {:?}",
                start,
                self.rest()
            )));
        }
        Ok(self.src[start..self.pos].to_string())
    }

    fn number(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.pos;
        if self.rest().starts_with('-') {
            self.pos += 1;
        }
        while self
            .rest()
            .starts_with(|c: char| c.is_ascii_digit() || c == '.' || c == 'e' || c == 'E' || c == '+' || c == '-')
        {
            // Stop '-'/'+' unless right after e/E.
            let c = self.rest().chars().next().unwrap();
            if (c == '-' || c == '+') && self.pos > start {
                let prev = self.src.as_bytes()[self.pos - 1];
                if prev != b'e' && prev != b'E' {
                    break;
                }
            }
            self.pos += 1;
        }
        self.src[start..self.pos]
            .parse()
            .map_err(|_| Error::Query(format!("bad number at {start}: {:?}", &self.src[start..self.pos])))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::table::gen;

    #[test]
    fn simple_comparisons() {
        let p = parse_predicate("val > 50").unwrap();
        assert_eq!(p, Predicate::cmp("val", CmpOp::Gt, 50.0));
        let p = parse_predicate("x<=-2.5").unwrap();
        assert_eq!(p, Predicate::cmp("x", CmpOp::Le, -2.5));
        let p = parse_predicate("a == 1e3").unwrap();
        assert_eq!(p, Predicate::cmp("a", CmpOp::Eq, 1000.0));
        assert_eq!(parse_predicate("true").unwrap(), Predicate::True);
    }

    #[test]
    fn boolean_structure_and_precedence() {
        let p = parse_predicate("a > 1 && b < 2 || c == 3").unwrap();
        // && binds tighter: (a&&b) || c
        assert_eq!(
            p,
            Predicate::cmp("a", CmpOp::Gt, 1.0)
                .and(Predicate::cmp("b", CmpOp::Lt, 2.0))
                .or(Predicate::cmp("c", CmpOp::Eq, 3.0))
        );
        let p = parse_predicate("a > 1 && (b < 2 || c == 3)").unwrap();
        assert_eq!(
            p,
            Predicate::cmp("a", CmpOp::Gt, 1.0).and(
                Predicate::cmp("b", CmpOp::Lt, 2.0).or(Predicate::cmp("c", CmpOp::Eq, 3.0))
            )
        );
    }

    #[test]
    fn negation() {
        let p = parse_predicate("!(flag == 1)").unwrap();
        assert_eq!(p, Predicate::cmp("flag", CmpOp::Eq, 1.0).not());
        let p = parse_predicate("!a != 0").unwrap();
        assert_eq!(p, Predicate::cmp("a", CmpOp::Ne, 0.0).not());
    }

    #[test]
    fn parse_errors() {
        assert!(parse_predicate("").is_err());
        assert!(parse_predicate("a >").is_err());
        assert!(parse_predicate("a ~ 3").is_err());
        assert!(parse_predicate("(a > 1").is_err());
        assert!(parse_predicate("a > 1 extra").is_err());
        assert!(parse_predicate("> 5").is_err());
    }

    #[test]
    fn parsed_predicate_evaluates() {
        let b = gen::sensor_table(100, 1);
        let p = parse_predicate("flag == 1 || val > 80").unwrap();
        let mask = p.eval(&b).unwrap();
        let direct = Predicate::cmp("flag", CmpOp::Eq, 1.0)
            .or(Predicate::cmp("val", CmpOp::Gt, 80.0))
            .eval(&b)
            .unwrap();
        assert_eq!(mask, direct);
    }

    #[test]
    fn sort_specs() {
        assert_eq!(parse_sort("val").unwrap(), vec![SortKey::asc("val")]);
        assert_eq!(
            parse_sort("val desc, ts").unwrap(),
            vec![SortKey::desc("val"), SortKey::asc("ts")]
        );
        assert_eq!(
            parse_sort("a asc,b desc").unwrap(),
            vec![SortKey::asc("a"), SortKey::desc("b")]
        );
        assert!(parse_sort("").is_err());
        assert!(parse_sort("val up").is_err());
        assert!(parse_sort("val desc extra").is_err());
    }

    #[test]
    fn pipelines() {
        let q = parse_pipeline(
            "t",
            "filter val > 50 | select ts,val | sort val desc | limit 10",
        )
        .unwrap();
        assert_eq!(
            q,
            Query::scan("t")
                .filter(Predicate::cmp("val", CmpOp::Gt, 50.0))
                .select(&["ts", "val"])
                .sort_desc("val")
                .limit(10)
        );
        let q = parse_pipeline("t", "filter flag == 0 | agg sum:val,count:val | by sensor,flag")
            .unwrap();
        assert_eq!(q.aggregates.len(), 2);
        assert_eq!(q.group_by, vec!["sensor", "flag"]);
        let q = parse_pipeline("t", "topk 5 val desc").unwrap();
        assert_eq!(q, Query::scan("t").top_k("val", true, 5));
        // Illegal compositions surface the IR validation errors.
        assert!(parse_pipeline("t", "agg sum:val | filter val > 1").is_err());
        assert!(parse_pipeline("t", "limit 3 | sort val").is_err());
        assert!(parse_pipeline("t", "by sensor").is_err());
        assert!(parse_pipeline("t", "frobnicate 3").is_err());
        assert!(parse_pipeline("t", "topk 5").is_err());
        assert!(parse_pipeline("t", "limit many").is_err());
    }

    #[test]
    fn having_pipelines() {
        // `having` filters finalized groups; aggregate values are
        // addressed by display form, group keys by name.
        let q = parse_pipeline(
            "t",
            "filter flag == 0 | agg count:val,sum:val | by sensor \
             | having count(val) > 10 && sensor <= 50 | limit 5",
        )
        .unwrap();
        assert_eq!(q.group_by, vec!["sensor"]);
        assert_eq!(
            q.having,
            Predicate::cmp("count(val)", CmpOp::Gt, 10.0)
                .and(Predicate::cmp("sensor", CmpOp::Le, 50.0))
        );
        assert_eq!(q.limit, Some(5));
        assert_eq!(q.aggregates[0], Aggregate::new(AggFunc::Count, "val"));
        // `filter` after agg is the same operator (Filter above
        // Aggregate), validated the same way.
        let q2 = parse_pipeline(
            "t",
            "agg count:val | by sensor | filter count(val) > 10",
        )
        .unwrap();
        assert_eq!(q2.having, Predicate::cmp("count(val)", CmpOp::Gt, 10.0));
        // Rejected: having before agg, over scalar agg, unknown column.
        assert!(parse_pipeline("t", "having count(val) > 1 | agg count:val").is_err());
        assert!(parse_pipeline("t", "agg count:val | having count(val) > 1").is_err());
        assert!(parse_pipeline("t", "agg count:val | by sensor | having val > 1").is_err());
        // Call-shaped identifiers parse and display round-trips.
        let p = parse_predicate("mean(val) >= 2.5").unwrap();
        assert_eq!(p, Predicate::cmp("mean(val)", CmpOp::Ge, 2.5));
        assert_eq!(parse_predicate(&p.to_string()).unwrap(), p);
        assert!(parse_predicate("mean(val > 1").is_err());
    }

    #[test]
    fn aggregates() {
        let a = parse_aggregate("mean:val").unwrap();
        assert_eq!(a, Aggregate::new(AggFunc::Mean, "val"));
        let a = parse_aggregate("median: val ").unwrap();
        assert_eq!(a, Aggregate::new(AggFunc::Median, "val"));
        assert!(parse_aggregate("mean").is_err());
        assert!(parse_aggregate("pctl:val").is_err());
        assert!(parse_aggregate("sum:").is_err());
    }
}
