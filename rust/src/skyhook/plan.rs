//! Query planning: decomposability analysis, pushdown decisions
//! (§3.2 "Composability of Access Operations"), and zone-map pruning.
//!
//! A query is decomposed into one sub-query per row-group object. Before
//! anything is dispatched, the planner consults the per-group zone maps
//! recorded in [`RowGroupMeta::stats`]: a sub-query whose predicate
//! provably matches zero rows of its group ([`Predicate::prune`]) is
//! dropped *before any I/O is issued* — the request never reaches a
//! storage server. For the sub-queries that survive, the planner decides
//! *where* each sub-operation runs:
//!
//! - **Pushdown**: filter/project/aggregate execute in the Skyhook-
//!   Extension on the OSD; only results cross the network. Algebraic
//!   aggregates return constant-size partials; holistic ones (median)
//!   must ship the filtered raw values back.
//! - **ClientSide**: the worker reads the object (projected columns
//!   only, on columnar layouts) and computes locally — the baseline the
//!   paper improves on.

use super::query::{Predicate, Query};
use crate::dataset::metadata::{DatasetMeta, RowGroupMeta};
use crate::dataset::{DType, Layout, TableSchema};
use crate::error::{Error, Result};

/// Where a sub-query executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Object-class extension on the storage server.
    Pushdown,
    /// Worker reads the object and computes client-side.
    ClientSide,
}

/// One per-object sub-query.
#[derive(Clone, Debug)]
pub struct SubQuery {
    pub object: String,
    pub mode: ExecMode,
    /// Physical layout of the object (from dataset metadata) — lets the
    /// client-side path skip the ranged-read probing for Row objects,
    /// which must be read whole anyway.
    pub layout: Layout,
    /// For aggregate pushdown: must the extension return raw values
    /// (holistic finalization at the driver)?
    pub keep_values: bool,
    /// May the storage-side handler consult the object's zone-map xattr?
    /// False when the plan was built with pruning disabled, so the
    /// unpruned baseline does real reads end to end.
    pub zone_maps: bool,
}

/// A planned query.
#[derive(Clone, Debug)]
pub struct QueryPlan {
    pub query: Query,
    /// Dataset schema (used to synthesize empty results when every
    /// sub-query is pruned).
    pub schema: TableSchema,
    /// Execution mode of every sub-query (kept here too so it stays
    /// known when pruning drops all of them).
    pub mode: ExecMode,
    pub subqueries: Vec<SubQuery>,
    /// True if every aggregate decomposes into constant-size partials.
    pub decomposable: bool,
    /// Sub-queries dropped by zone-map pruning before any I/O.
    pub objects_pruned: usize,
    /// Serialized bytes of the pruned objects — I/O and decode work the
    /// query provably did not need.
    pub bytes_skipped: u64,
}

impl QueryPlan {
    /// Human-readable planning summary (for the CLI's EXPLAIN).
    pub fn explain(&self) -> String {
        let mode = format!("{:?}", self.mode);
        format!(
            "{} over {} objects ({} pruned), mode={}, decomposable={}, keep_values={}",
            if self.query.is_aggregate() {
                "aggregate"
            } else {
                "row-scan"
            },
            self.subqueries.len(),
            self.objects_pruned,
            mode,
            self.decomposable,
            self.subqueries.first().map(|s| s.keep_values).unwrap_or(false),
        )
    }
}

/// Build a plan for `query` against a dataset's metadata, with zone-map
/// pruning enabled.
///
/// `force_mode` overrides the planner's choice (used by the benches to
/// compare pushdown against client-side execution on identical queries).
pub fn plan(query: &Query, meta: &DatasetMeta, force_mode: Option<ExecMode>) -> Result<QueryPlan> {
    plan_opts(query, meta, force_mode, true)
}

/// [`plan`] with zone-map pruning optionally disabled (`prune = false`),
/// so benches can measure the pruned fast path against an identical
/// unpruned execution.
pub fn plan_opts(
    query: &Query,
    meta: &DatasetMeta,
    force_mode: Option<ExecMode>,
    prune: bool,
) -> Result<QueryPlan> {
    let DatasetMeta::Table {
        schema,
        layout,
        row_groups,
        ..
    } = meta
    else {
        return Err(Error::Query(format!(
            "{} is an array dataset; table query expected",
            query.dataset
        )));
    };
    let names = meta.object_names(&query.dataset);
    // Validate referenced columns exist up front (fail fast at the driver
    // rather than on every OSD). Pruning never skips this, so invalid
    // queries fail identically with and without pruning.
    let all: Vec<String> = schema.columns.iter().map(|c| c.name.clone()).collect();
    for col in query.needed_columns(&all) {
        schema.col_index(&col)?;
    }
    if query.group_by.is_some() && query.aggregates.len() != 1 {
        return Err(Error::Query(
            "group_by requires exactly one aggregate".into(),
        ));
    }

    // Error parity: a query that would fail during evaluation (string-
    // typed predicate or aggregate column, non-i64 group key) must fail
    // identically with pruning on, so pruning is disabled for it — the
    // sub-queries run and report the error the usual way.
    let dtype_of = |name: &str| schema.col_index(name).ok().map(|i| schema.col(i).dtype);
    let evaluable = !query
        .predicate
        .columns()
        .into_iter()
        .any(|c| dtype_of(c) == Some(DType::Str))
        && !query.aggregates.iter().any(|a| dtype_of(&a.col) == Some(DType::Str))
        && query
            .group_by
            .as_deref()
            .map_or(true, |g| dtype_of(g) == Some(DType::I64));
    let prune = prune && evaluable;

    let decomposable = query.is_decomposable();
    // Default policy: always push down — filter/project reduction happens
    // at the data. Holistic aggregates still push the *filter* down and
    // ship values back (keep_values).
    let mode = force_mode.unwrap_or(ExecMode::Pushdown);
    let keep_values = query.is_aggregate() && !decomposable;
    let mut subqueries = Vec::with_capacity(names.len());
    let mut objects_pruned = 0usize;
    let mut bytes_skipped = 0u64;
    for (i, object) in names.into_iter().enumerate() {
        let rg = &row_groups[i];
        if prune && group_prunes(&query.predicate, schema, rg) {
            objects_pruned += 1;
            bytes_skipped += rg.bytes;
            continue;
        }
        subqueries.push(SubQuery {
            object,
            mode,
            layout: *layout,
            keep_values,
            zone_maps: prune,
        });
    }
    Ok(QueryPlan {
        query: query.clone(),
        schema: schema.clone(),
        mode,
        subqueries,
        decomposable,
        objects_pruned,
        bytes_skipped,
    })
}

/// Zone-map test for one row group: does the predicate provably match
/// zero of its rows? Empty groups always prune; groups without recorded
/// stats prune only via `rows == 0`.
fn group_prunes(pred: &Predicate, schema: &TableSchema, rg: &RowGroupMeta) -> bool {
    if rg.rows == 0 {
        return true;
    }
    if rg.stats.is_empty() {
        return false;
    }
    pred.prune(&|col: &str| {
        schema
            .col_index(col)
            .ok()
            .and_then(|ci| rg.stats.get(ci))
            .and_then(|s| s.range())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::layout::Layout;
    use crate::dataset::metadata::ColumnStats;
    use crate::skyhook::query::{AggFunc, CmpOp};

    fn meta(groups: usize) -> DatasetMeta {
        DatasetMeta::Table {
            schema: TableSchema::new(&[("ts", DType::I64), ("val", DType::F32)]),
            layout: Layout::Col,
            row_groups: (0..groups)
                .map(|_| RowGroupMeta {
                    rows: 10,
                    bytes: 100,
                    stats: vec![],
                })
                .collect(),
            localities: vec![String::new(); groups],
        }
    }

    /// Meta with zone maps: group i has ts in [10i, 10i+9], val constant.
    fn meta_with_stats(groups: usize) -> DatasetMeta {
        DatasetMeta::Table {
            schema: TableSchema::new(&[("ts", DType::I64), ("val", DType::F32)]),
            layout: Layout::Col,
            row_groups: (0..groups)
                .map(|i| RowGroupMeta {
                    rows: 10,
                    bytes: 100,
                    stats: vec![
                        ColumnStats {
                            min: (i * 10) as f64,
                            max: (i * 10 + 9) as f64,
                        },
                        ColumnStats { min: 5.0, max: 5.0 },
                    ],
                })
                .collect(),
            localities: vec![String::new(); groups],
        }
    }

    #[test]
    fn plan_one_subquery_per_object() {
        let q = Query::scan("ds").filter(Predicate::cmp("val", CmpOp::Gt, 0.0));
        let p = plan(&q, &meta(5), None).unwrap();
        assert_eq!(p.subqueries.len(), 5);
        assert!(p.subqueries.iter().all(|s| s.mode == ExecMode::Pushdown));
        assert!(p.decomposable);
        assert!(!p.subqueries[0].keep_values);
        assert_eq!(p.subqueries[0].object, "ds/t/00000000");
    }

    #[test]
    fn holistic_aggregate_keeps_values() {
        let q = Query::scan("ds").aggregate(AggFunc::Median, "val");
        let p = plan(&q, &meta(3), None).unwrap();
        assert!(!p.decomposable);
        assert!(p.subqueries.iter().all(|s| s.keep_values));
        // Algebraic does not.
        let q = Query::scan("ds").aggregate(AggFunc::Mean, "val");
        let p = plan(&q, &meta(3), None).unwrap();
        assert!(p.decomposable);
        assert!(!p.subqueries[0].keep_values);
    }

    #[test]
    fn force_mode_overrides() {
        let q = Query::scan("ds");
        let p = plan(&q, &meta(2), Some(ExecMode::ClientSide)).unwrap();
        assert!(p.subqueries.iter().all(|s| s.mode == ExecMode::ClientSide));
    }

    #[test]
    fn plan_validates_columns() {
        let q = Query::scan("ds").filter(Predicate::cmp("nope", CmpOp::Gt, 0.0));
        assert!(plan(&q, &meta(2), None).is_err());
        let q = Query::scan("ds").select(&["missing"]);
        assert!(plan(&q, &meta(2), None).is_err());
        let q = Query::scan("ds").aggregate(AggFunc::Sum, "ghost");
        assert!(plan(&q, &meta(2), None).is_err());
    }

    #[test]
    fn plan_prunes_with_zone_maps() {
        // ts < 25 can only match groups 0–2 of [0,9], [10,19], [20,29]...
        let q = Query::scan("ds").filter(Predicate::cmp("ts", CmpOp::Lt, 25.0));
        let p = plan(&q, &meta_with_stats(10), None).unwrap();
        assert_eq!(p.subqueries.len(), 3);
        assert_eq!(p.objects_pruned, 7);
        assert_eq!(p.bytes_skipped, 700);
        assert_eq!(p.subqueries[0].object, "ds/t/00000000");
        assert_eq!(p.subqueries[2].object, "ds/t/00000002");
        // Pruning disabled: every group dispatched.
        let p = plan_opts(&q, &meta_with_stats(10), None, false).unwrap();
        assert_eq!(p.subqueries.len(), 10);
        assert_eq!(p.objects_pruned, 0);
        assert_eq!(p.bytes_skipped, 0);
        // Constant-column equality prunes everything.
        let q = Query::scan("ds")
            .filter(Predicate::cmp("val", CmpOp::Ne, 5.0))
            .aggregate(AggFunc::Count, "val");
        let p = plan(&q, &meta_with_stats(4), None).unwrap();
        assert!(p.subqueries.is_empty());
        assert_eq!(p.objects_pruned, 4);
        assert_eq!(p.mode, ExecMode::Pushdown);
        // The mode survives even when every sub-query is pruned.
        let p = plan_opts(&q, &meta_with_stats(4), Some(ExecMode::ClientSide), true).unwrap();
        assert!(p.subqueries.is_empty());
        assert_eq!(p.mode, ExecMode::ClientSide);
        // Without stats, value predicates never prune.
        let q = Query::scan("ds").filter(Predicate::cmp("ts", CmpOp::Lt, -1.0));
        let p = plan(&q, &meta(5), None).unwrap();
        assert_eq!(p.subqueries.len(), 5);
        assert_eq!(p.objects_pruned, 0);
    }

    #[test]
    fn plan_prunes_empty_groups_even_without_stats() {
        let m = DatasetMeta::Table {
            schema: TableSchema::new(&[("ts", DType::I64), ("val", DType::F32)]),
            layout: Layout::Col,
            row_groups: vec![
                RowGroupMeta {
                    rows: 10,
                    bytes: 100,
                    stats: vec![],
                },
                RowGroupMeta {
                    rows: 0,
                    bytes: 40,
                    stats: vec![],
                },
            ],
            localities: vec![String::new(); 2],
        };
        let p = plan(&Query::scan("ds"), &m, None).unwrap();
        assert_eq!(p.subqueries.len(), 1);
        assert_eq!(p.objects_pruned, 1);
        assert_eq!(p.bytes_skipped, 40);
    }

    #[test]
    fn pruned_plan_still_validates_columns() {
        // Validation failures are identical with and without pruning.
        let q = Query::scan("ds").filter(Predicate::cmp("ghost", CmpOp::Lt, 0.0));
        assert!(plan(&q, &meta_with_stats(3), None).is_err());
        assert!(plan_opts(&q, &meta_with_stats(3), None, false).is_err());
    }

    #[test]
    fn plan_rejects_array_dataset() {
        let m = DatasetMeta::Array {
            space: crate::dataset::Dataspace::new(&[4]).unwrap(),
            chunk: vec![2],
        };
        assert!(plan(&Query::scan("ds"), &m, None).is_err());
    }

    #[test]
    fn group_by_needs_one_aggregate() {
        let q = Query::scan("ds").group("ts");
        assert!(plan(&q, &meta(1), None).is_err());
        let q = Query::scan("ds")
            .group("ts")
            .aggregate(AggFunc::Mean, "val")
            .aggregate(AggFunc::Sum, "val");
        assert!(plan(&q, &meta(1), None).is_err());
        let q = Query::scan("ds").group("ts").aggregate(AggFunc::Mean, "val");
        assert!(plan(&q, &meta(1), None).is_ok());
    }

    #[test]
    fn explain_mentions_shape() {
        let q = Query::scan("ds").aggregate(AggFunc::Median, "val");
        let p = plan(&q, &meta(4), None).unwrap();
        let e = p.explain();
        assert!(e.contains("aggregate"));
        assert!(e.contains("4 objects"));
        assert!(e.contains("decomposable=false"));
    }
}
